"""Campaign planner (Δ-volume DP) + anchor-chain sharing contracts.

Covers the two PR-5 subsystems of core/window.py:

* ``optimal_campaigns`` / ``campaign_volume`` — the auto campaign
  partition: DP optimality vs every fixed width (property-tested), model
  consistency (realized run volumes equal the plan's predictions), the
  ``"auto"`` sentinel plumbing, and lane-budget/mesh-extent handling.
* ``AnchorChain`` — overlapping streams sharing one chain of nested
  anchor states: strictly fewer total rebuilds than solo runs with
  bit-identical values, pin/unpin refcounting against both LRU eviction
  and explicit ``release``, cover/selection rules, and lifecycle errors.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    AnchorChain,
    SnapshotStore,
    WindowStream,
    campaign_volume,
    optimal_campaigns,
    run_window_stream_batched,
    select_chain,
    slide_windows,
    stream_campaigns,
)
from repro.core.window import _stream_qkey
from repro.graph import make_evolving_sequence
from repro.graph.semiring import ALL_SEMIRINGS

SNAPS = 8


def _store(n=300, e=2400, snaps=SNAPS, changes=150, seed=11, granule=128,
           **kw):
    return SnapshotStore(make_evolving_sequence(n, e, snaps, changes,
                                                seed=seed),
                         granule=granule, **kw)


@pytest.fixture(scope="module")
def planner_store():
    """One shared store for the host-side planner tests (DP only, no jit)."""
    return _store()


def _qkey(sr):
    return _stream_qkey(sr, 0, 10_000, False, 1, False)


# -- campaign planner: DP + cost model ----------------------------------------

def test_optimal_campaigns_is_a_partition(planner_store):
    windows = slide_windows(SNAPS, 3)
    plan = optimal_campaigns(planner_store, windows, lane_budget=4)
    assert [w for c in plan.campaigns for w in c] == windows
    assert all(1 <= len(c) <= 4 for c in plan.campaigns)
    assert plan.widths == [len(c) for c in plan.campaigns]
    hi = windows[-1][1]
    assert plan.anchors == [(c[0][0], hi) for c in plan.campaigns]
    assert plan.total_edges == (plan.slide_edges + plan.anchor_edges
                                + plan.padding_edges)


def test_optimal_campaigns_validation(planner_store):
    with pytest.raises(ValueError):
        optimal_campaigns(planner_store, [])
    with pytest.raises(ValueError):
        optimal_campaigns(planner_store, [(2, 4), (0, 3)])  # not advancing
    with pytest.raises(ValueError):
        optimal_campaigns(planner_store, [(0, 2)], lane_budget=0)
    with pytest.raises(ValueError):
        campaign_volume(planner_store, [])
    with pytest.raises(ValueError):
        campaign_volume(planner_store, [[]])


def test_campaign_volume_anchor_edges_telescope(planner_store):
    """Anchor volume = first rebuild + hops = |T(last anchor)| exactly."""
    windows = slide_windows(SNAPS, 2)
    for width in (1, 3):
        plan = campaign_volume(planner_store,
                               stream_campaigns(windows, width))
        assert plan.anchor_edges == planner_store.window_size(
            *plan.anchors[-1])


def test_padding_volume_counts_masked_lanes(planner_store):
    """A 3-window campaign pads to 4 lanes; the masked lane is priced at
    the campaign's widest slide Δ — and a mesh extent widens the bucket."""
    windows = slide_windows(SNAPS, 3)[:3]
    plan = campaign_volume(planner_store, [windows])
    anchor = plan.anchors[0]
    deltas = [planner_store.window_size(*w)
              - planner_store.window_size(*anchor) for w in windows]
    assert plan.padding_edges == (4 - 3) * max(deltas)
    meshed = campaign_volume(planner_store, [windows], data_extent=8)
    assert meshed.padding_edges == (8 - 3) * max(deltas)


@st.composite
def advancing_windows(draw, snaps=SNAPS, max_windows=10):
    n = draw(st.integers(1, max_windows))
    lo = draw(st.integers(0, snaps - 1))
    hi = draw(st.integers(lo, snaps - 1))
    out = [(lo, hi)]
    for _ in range(n - 1):
        lo = draw(st.integers(lo, snaps - 1))
        hi = draw(st.integers(max(lo, hi), snaps - 1))
        out.append((lo, hi))
    return out


@settings(max_examples=60, deadline=None)
@given(windows=advancing_windows(), data_extent=st.sampled_from([1, 2, 4]))
def test_optimal_campaigns_never_worse_than_any_fixed_width(
        planner_store, windows, data_extent):
    """The acceptance property: the DP's modeled Δ-volume is ≤ every fixed
    campaign width's on the same windows (fixed-width chunkings are points
    in its search space)."""
    plan = optimal_campaigns(planner_store, windows, lane_budget=8,
                             data_extent=data_extent)
    assert [w for c in plan.campaigns for w in c] == windows
    for width in (1, 2, 4, 8):
        fixed = campaign_volume(planner_store,
                                stream_campaigns(windows, width),
                                data_extent=data_extent)
        assert plan.total_edges <= fixed.total_edges, (
            f"auto plan {plan.widths} costs {plan.total_edges} > fixed "
            f"width {width} at {fixed.total_edges} on {windows}")


def test_auto_run_realizes_planned_volumes():
    """campaign_width="auto" must stream exactly what its plan predicted:
    slide Δ == plan.slide_edges, anchor hops == plan.anchor_edges minus the
    first rebuild — and stay bit-identical to a fixed-width run."""
    sr = ALL_SEMIRINGS["sssp"]
    store = _store()
    auto = run_window_stream_batched(store, sr, 0, 3, campaign_width="auto")
    assert auto.plan is not None
    assert [w for c in auto.campaigns for w in c] == slide_windows(SNAPS, 3)
    assert auto.added_edges == auto.plan.slide_edges
    rebuild_volume = store.window_size(*auto.plan.anchors[0])
    assert auto.anchor_delta_edges == auto.plan.anchor_edges - rebuild_volume
    fixed = run_window_stream_batched(_store(), sr, 0, 3, campaign_width=2)
    assert set(auto.results) == set(fixed.results)
    for wnd in fixed.results:
        np.testing.assert_array_equal(np.asarray(auto.results[wnd]),
                                      np.asarray(fixed.results[wnd]))


def test_auto_respects_lane_budget():
    sr = ALL_SEMIRINGS["sssp"]
    run = run_window_stream_batched(_store(), sr, 0, 3,
                                    campaign_width="auto", lane_budget=2)
    assert run.plan.lane_budget == 2
    assert all(w <= 2 for w in run.plan.widths)
    with pytest.raises(ValueError):
        run_window_stream_batched(_store(), sr, 0, 3,
                                  campaign_width="auto", lane_budget=0)


def test_auto_stream_object_round_trip():
    """A WindowStream carrying the sentinel plans each drain it takes."""
    sr = ALL_SEMIRINGS["sssp"]
    store = _store()
    ws = WindowStream(campaign_width="auto",
                      windows=slide_windows(SNAPS, 3))
    run = run_window_stream_batched(store, sr, 0, stream=ws)
    assert run.plan is not None and run.results
    fixed = run_window_stream_batched(_store(), sr, 0, 3, campaign_width=2)
    for wnd in fixed.results:
        np.testing.assert_array_equal(np.asarray(run.results[wnd]),
                                      np.asarray(fixed.results[wnd]))


# -- the "auto" sentinel plumbing ---------------------------------------------

def test_stream_campaigns_rejects_auto_with_pointer():
    windows = slide_windows(SNAPS, 3)
    with pytest.raises(ValueError, match="optimal_campaigns"):
        stream_campaigns(windows, "auto")
    with pytest.raises(ValueError, match='"auto"'):
        stream_campaigns(windows, 0)
    with pytest.raises(ValueError, match='"auto"'):
        stream_campaigns(windows, "wide")


def test_window_stream_accepts_auto_rejects_junk():
    assert WindowStream(campaign_width="auto").campaign_width == "auto"
    with pytest.raises(ValueError, match='"auto"'):
        WindowStream(campaign_width=0)
    with pytest.raises(ValueError, match='"auto"'):
        WindowStream(campaign_width="wide")


def test_window_stream_names_are_unique_by_default():
    a, b = WindowStream(campaign_width=1), WindowStream(campaign_width=1)
    assert a.name != b.name
    assert WindowStream(campaign_width=1, name="fixed").name == "fixed"


# -- anchor chains: overlapping streams ---------------------------------------

def _overlapping_sets():
    """Two window sets over the same tail: B starts later, same stream_hi."""
    return slide_windows(SNAPS, 3), slide_windows(SNAPS, 2)[3:]


def test_overlapping_streams_share_chain_fewer_rebuilds():
    """The acceptance criterion: two streams sharing an AnchorChain perform
    strictly fewer anchor rebuilds than the sum of solo runs, with
    bit-identical per-window values."""
    sr = ALL_SEMIRINGS["sssp"]
    wa, wb = _overlapping_sets()
    store = _store()
    chain = AnchorChain(store, name="shared")
    a = WindowStream(campaign_width=2, windows=wa, name="A")
    b = WindowStream(campaign_width=2, windows=wb, name="B")
    chain.register(b)   # B not yet running: A's links must stay pinned
    ra = run_window_stream_batched(store, sr, 0, stream=a, chain=chain)
    rb = run_window_stream_batched(store, sr, 0, stream=b, chain=chain)
    solo_a = run_window_stream_batched(_store(), sr, 0, windows=wa,
                                       campaign_width=2)
    solo_b = run_window_stream_batched(_store(), sr, 0, windows=wb,
                                       campaign_width=2)
    assert (ra.anchor_rebuilds + rb.anchor_rebuilds
            < solo_a.anchor_rebuilds + solo_b.anchor_rebuilds)
    assert rb.anchor_rebuilds == 0          # B rode the chain entirely
    for run, solo in ((ra, solo_a), (rb, solo_b)):
        for wnd in solo.results:
            np.testing.assert_array_equal(np.asarray(run.results[wnd]),
                                          np.asarray(solo.results[wnd]))


def test_chain_pins_follow_registration_lifecycle():
    sr = ALL_SEMIRINGS["sssp"]
    wa, wb = _overlapping_sets()
    store = _store()
    chain = AnchorChain(store)
    a = WindowStream(campaign_width=2, windows=wa, name="A")
    b = WindowStream(campaign_width=2, windows=wb, name="B")
    chain.register(b)
    run_window_stream_batched(store, sr, 0, stream=a, chain=chain)
    # B is behind everything, so every link stays pinned after A finishes
    qkey = _qkey(sr)
    assert set(chain._pinned) == set(chain.links)
    assert {("AS", qkey, link) for link in chain.links} \
        <= store.pinned_tags()
    all_links = list(chain.links)
    run_window_stream_batched(store, sr, 0, stream=b, chain=chain)
    # links BOTH streams passed (A's early anchors) are pruned from the
    # chain and unpinned; the survivors are exactly the pinned set
    pruned = set(all_links) - set(chain.links)
    assert pruned
    assert set(chain._pinned) == set(chain.links)
    assert {("AS", qkey, link) for link in pruned}.isdisjoint(
        store.pinned_tags())
    chain.unregister(a)
    chain.unregister(b)
    # last stream out: links stay listed (select_chain discovery) but unpin
    assert chain.links and chain._pinned == set()
    assert store.pinned_tags() == set()
    with pytest.raises(ValueError, match="not registered"):
        chain.unregister(b)                 # already removed
    with pytest.raises(ValueError):
        chain.advance("B", chain.links[0])  # advancing unregistered stream


def test_pinned_links_survive_release_and_eviction():
    """The protection pinning buys: explicit release(("AS",)) and LRU
    pressure both skip pinned chain links, so a lagging stream still hops
    instead of rebuilding."""
    sr = ALL_SEMIRINGS["sssp"]
    wa, wb = _overlapping_sets()
    store = _store()
    chain = AnchorChain(store)
    a = WindowStream(campaign_width=2, windows=wa, name="A")
    b = WindowStream(campaign_width=2, windows=wb, name="B")
    chain.register(b)
    run_window_stream_batched(store, sr, 0, stream=a, chain=chain)
    qkey = _qkey(sr)
    freed = store.release()                  # drops everything unpinned
    assert freed > 0
    assert {t for t in store._blocks} == \
        {("AS", qkey, link) for link in chain.links}
    rb = run_window_stream_batched(store, sr, 0, stream=b, chain=chain)
    assert rb.anchor_rebuilds == 0           # links survived the release
    # without the chain, the same release forces B to rebuild cold
    bare = _store()
    run_window_stream_batched(bare, sr, 0, windows=wa, campaign_width=2)
    bare.release()
    cold = run_window_stream_batched(bare, sr, 0, windows=wb,
                                     campaign_width=2)
    assert cold.anchor_rebuilds > 0
    for wnd in cold.results:
        np.testing.assert_array_equal(np.asarray(rb.results[wnd]),
                                      np.asarray(cold.results[wnd]))
    chain.unregister(a)
    chain.unregister(b)


def test_release_AS_skips_pinned_anchor_states_with_refcounts():
    """``release(("AS",))`` drops only UNPINNED anchor states, and
    ``pin_count`` stays consistent through nested pin/unpin cycles — the
    store-level contract the chain's link protection is built on."""
    sr = ALL_SEMIRINGS["sssp"]
    store = _store()
    run_window_stream_batched(store, sr, 0, windows=slide_windows(SNAPS, 3),
                              campaign_width=2)
    as_tags = sorted(t for t in store._blocks if t[0] == "AS")
    assert len(as_tags) >= 2, "stream left too few anchor states to test"
    keep, dropped = as_tags[0], as_tags[1:]
    store.pin(keep)
    store.pin(keep)                          # pins nest (refcounted)
    assert store.pin_count(keep) == 2
    assert all(store.pin_count(t) == 0 for t in dropped)
    freed = store.release(("AS",))
    assert freed > 0
    assert {t for t in store._blocks if t[0] == "AS"} == {keep}
    # releasing never perturbs refcounts — of survivors or of the dropped
    assert store.pin_count(keep) == 2
    assert all(store.pin_count(t) == 0 for t in dropped)
    # the AS-family release left every other block family warm
    assert any(t[0] != "AS" for t in store._blocks)
    store.unpin(keep)                        # one unpin is not enough
    assert store.pin_count(keep) == 1
    store.release(("AS",))
    assert keep in store._blocks             # still pinned: still survives
    store.unpin(keep)                        # refcount drains to zero...
    assert store.pin_count(keep) == 0
    assert keep not in store.pinned_tags()
    store.release(("AS",))
    assert keep not in store._blocks         # ...and the next release drops it


def test_lru_eviction_skips_pinned_tags_with_exact_accounting():
    """Byte-budget eviction walks past pinned tags (evicting unpinned LRU
    entries instead) and cached_nbytes stays the exact sum either way."""
    store = _store(cache_bytes=256 * 1024)
    pinned_tag = ("T", 0, 0)
    store.window_block(0, 0)
    store.pin(pinned_tag)
    for i in range(SNAPS):
        for j in range(i, SNAPS):
            store.window_block(i, j)
    assert store.evictions > 0
    assert pinned_tag in store._blocks       # survived the pressure
    from repro.core.snapshots import _block_nbytes
    assert store.cached_nbytes == sum(_block_nbytes(b)
                                      for b in store._blocks.values())
    store.unpin(pinned_tag)
    with pytest.raises(ValueError):
        store.unpin(pinned_tag)              # refcount underflow


def test_chain_cover_and_select_tightest():
    sr = ALL_SEMIRINGS["sssp"]
    store = _store()
    chain = AnchorChain(store, name="one")
    run_window_stream_batched(store, sr, 0,
                              stream=WindowStream(campaign_width=2,
                                                  windows=slide_windows(
                                                      SNAPS, 3),
                                                  name="A"),
                              chain=chain)
    hi = SNAPS - 1
    lo = max(l for l, _ in chain.links)
    assert chain.cover((lo + 1, hi)) == (lo, hi)   # tightest, not widest
    assert chain.cover((0, hi)) is None or chain.cover((0, hi)) == (0, hi)
    assert chain.cover((lo, hi + 1)) is None       # wider tail: no cover
    empty = AnchorChain(store, name="empty")
    assert select_chain([empty, chain], (lo + 1, hi)) is chain
    assert select_chain([empty], (lo + 1, hi)) is None
    # qkey filter: a chain bound to another query is not eligible
    other_qkey = _qkey(ALL_SEMIRINGS["sswp"])
    assert select_chain([chain], (lo + 1, hi), qkey=other_qkey) is None


def test_chain_misuse_raises():
    sr = ALL_SEMIRINGS["sssp"]
    store = _store()
    chain = AnchorChain(store)
    with pytest.raises(ValueError, match="requires stream="):
        run_window_stream_batched(store, sr, 0, 3, chain=chain)
    with pytest.raises(ValueError, match="SnapshotStore"):
        run_window_stream_batched(
            _store(), sr, 0, chain=chain,
            stream=WindowStream(campaign_width=2,
                                windows=slide_windows(SNAPS, 3)))
    ws = WindowStream(campaign_width=2, windows=slide_windows(SNAPS, 3))
    run_window_stream_batched(store, sr, 0, stream=ws, chain=chain)
    with pytest.raises(ValueError, match="bound to query key"):
        chain.bind(_qkey(ALL_SEMIRINGS["sswp"]))
    chain.unregister(ws)

"""Kernel differential-test harness: kernels vs jnp oracles, bit-exact.

Every pallas kernel ships with a pure-jnp ``ref.py`` oracle; this suite is
the differential gate that the kernels are BIT-IDENTICAL to their oracles —
not merely close — across fuzzed edge sets (duplicate dsts, all-padding
blocks, empty frontiers, single-node graphs, identity-valued weights) and
all five registered semirings, in two execution modes:

* ``interpret`` — the pallas interpret-mode kernel dispatched through the
  normal jit path (how the engine runs it on this CPU-only container);
* ``lowered`` — the same kernel explicitly AOT-lowered and compiled to a
  CPU executable (``jitted.lower(...).compile()``) — the closest this
  container gets to the real-device launch pipeline.

``KERNEL_DIFF_MODE`` selects ``interpret`` / ``lowered`` / ``all``
(default); CI runs one matrix leg per mode. The reusable comparator is
:func:`assert_kernel_matches_ref`.

The fused multi-sweep kernel additionally carries the engine contract:
``relax_sweep_fused(k)`` (both the reference while-loop and the pallas
path) must equal ``k`` sequential ``relax_sweep`` applications — values,
parents, frontier, sweep count and edge work — including early exit when
the frontier empties mid-chunk, and ``run_to_fixpoint`` must be invariant
in ``fused_k``.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.graph.edgeset import EdgeView, make_block
from repro.graph.engine import relax_sweep, relax_sweep_fused, run_to_fixpoint
from repro.graph.semiring import ALL_SEMIRINGS
from repro.kernels import edge_relax, relax_multi, segment_reduce
from repro.kernels.edge_relax.edge_relax import (
    BLOCK_E,
    KERNEL_OP_FOR,
    SEMIRING_OPS,
    UnsupportedSemiring,
    edge_relax_pallas,
    ops_for,
)
from repro.kernels.edge_relax.ref import edge_relax_ref
from repro.kernels.edge_relax_multi import relax_multi_ref
from repro.kernels.edge_relax_multi.edge_relax_multi import relax_multi_pallas
from repro.kernels.segment_reduce.segment_reduce import segment_reduce_pallas
from repro.kernels.segment_reduce.ref import segment_reduce_ref

_MODE = os.environ.get("KERNEL_DIFF_MODE", "all")
MODES = ("interpret", "lowered") if _MODE == "all" else (_MODE,)
SEMIRINGS = sorted(ALL_SEMIRINGS)
FUSED_KS = (1, 2, 3, 7)


def _call(kernel_fn, args, kwargs, mode: str):
    """Dispatch a jitted kernel wrapper through the selected execution leg."""
    if mode == "interpret":
        return kernel_fn(*args, **kwargs)
    if mode == "lowered":
        compiled = kernel_fn.lower(*args, **kwargs).compile()
        return compiled(*args)
    raise ValueError(f"unknown KERNEL_DIFF_MODE leg {mode!r}")


def assert_kernel_matches_ref(kernel_fn, ref_fn, args, kwargs=None, *,
                              mode: str, ref_kwargs=None):
    """Run kernel and oracle on identical inputs; assert bit-equality.

    The kernel runs through the selected execution leg; the oracle runs
    plain. Outputs are compared leaf-by-leaf with assert_array_equal — no
    tolerance: min/max/scatter semiring reductions are order-invariant, so
    any ULP of drift is a real kernel bug. Returns the kernel output.
    """
    kwargs = dict(kwargs or {})
    got = _call(kernel_fn, args, kwargs, mode)
    ref = ref_fn(*args, **(kwargs if ref_kwargs is None else ref_kwargs))
    got_leaves = jax.tree_util.tree_leaves(got)
    ref_leaves = jax.tree_util.tree_leaves(ref)
    assert len(got_leaves) == len(ref_leaves), (got, ref)
    for i, (g, r) in enumerate(zip(got_leaves, ref_leaves)):
        np.testing.assert_array_equal(
            np.asarray(g), np.asarray(r),
            err_msg=f"kernel/ref leaf {i} diverged (mode={mode})")
    return got


def _edges(n, e, seed, *, dup_heavy=False, unit_w=False):
    """A fuzzed edge set; dup_heavy funnels dsts into few targets."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, max(1, n // 8) if dup_heavy else n, e).astype(
        np.int32)
    w = (np.ones(e, np.float32) if unit_w
         else (rng.random(e) + 0.01).astype(np.float32))
    return jnp.asarray(src), jnp.asarray(dst), jnp.asarray(w)


def _mixed_values(sr, n, seed):
    """Converged-looking values: a mix of reached vertices and identity."""
    rng = np.random.default_rng(seed + 7)
    vals = (rng.random(n) * 4 + 0.5).astype(np.float32)
    vals[rng.random(n) < 0.3] = np.float32(sr.identity)
    vals[0] = np.float32(sr.source_value)
    return jnp.asarray(vals)


def _state(sr, n, seed, *, frontier="mixed"):
    """(values, parent, frontier) triple for the fused-kernel inputs."""
    rng = np.random.default_rng(seed + 13)
    values = _mixed_values(sr, n, seed)
    parent = jnp.asarray(rng.integers(-1, n, n).astype(np.int32))
    if frontier == "empty":
        fro = jnp.zeros((n,), bool)
    elif frontier == "source":
        fro = jnp.zeros((n,), bool).at[0].set(True)
    else:
        fro = jnp.asarray(rng.random(n) < 0.4)
    return values, parent, fro


# -- registry completeness: the kernel semiring surface -----------------------


def test_kernel_semiring_registry_complete():
    """Every registered semiring has a kernel op; unknown ops fail loud."""
    assert set(KERNEL_OP_FOR) == set(ALL_SEMIRINGS)
    assert set(KERNEL_OP_FOR.values()) <= set(SEMIRING_OPS)
    for op in SEMIRING_OPS:
        combine, reduce, ident = ops_for(op)
        assert callable(combine) and reduce in ("min", "max")
    with pytest.raises(UnsupportedSemiring, match="softmin"):
        ops_for("softmin")


@pytest.mark.parametrize("name", SEMIRINGS)
def test_kernel_ops_agree_with_semiring(name):
    """The kernel-side (combine, reduce, identity) matches the Semiring."""
    sr = ALL_SEMIRINGS[name]
    combine, reduce, ident = ops_for(KERNEL_OP_FOR[name])
    assert reduce == sr.reduce
    assert float(ident) == float(sr.identity) or (
        np.isinf(ident) and np.isinf(sr.identity)
        and np.sign(ident) == np.sign(sr.identity))
    v = jnp.float32(2.5)
    w = jnp.float32(0.75)
    np.testing.assert_array_equal(np.float32(combine(v, w)),
                                  np.float32(sr.combine(v, w)))


# -- negative tests: block misalignment fails loud, not silently --------------


def test_edge_relax_pallas_rejects_misaligned_edge_count():
    n, e = 8, 5
    values = jnp.zeros((n,), jnp.float32)
    src = jnp.zeros((e,), jnp.int32)
    dst = jnp.full((e,), n, jnp.int32)
    w = jnp.ones((e,), jnp.float32)
    with pytest.raises(ValueError, match=rf"edge count {e}.*{BLOCK_E}"):
        edge_relax_pallas(values, src, dst, w, op="min_plus", num_nodes=n)


def test_segment_reduce_pallas_rejects_misaligned_message_count():
    data = jnp.zeros((3, 4), jnp.float32)
    seg = jnp.zeros((3,), jnp.int32)
    with pytest.raises(ValueError, match=r"edge count 3.*BLOCK_E"):
        segment_reduce_pallas(data, seg, num_segments=4, reduce="sum")


def test_relax_multi_pallas_rejects_misaligned_and_bad_k():
    n, e = 4, 7
    values, parent, frontier = _state(ALL_SEMIRINGS["sssp"], n, 0)
    src = jnp.zeros((e,), jnp.int32)
    dst = jnp.full((e,), n, jnp.int32)
    w = jnp.ones((e,), jnp.float32)
    with pytest.raises(ValueError, match=rf"edge count {e}"):
        relax_multi_pallas(values, parent, frontier, src, dst, w,
                           jnp.int32(1), op="min_plus", num_nodes=n, k=1)
    ok = jnp.zeros((BLOCK_E,), jnp.int32)
    with pytest.raises(ValueError, match=r"k"):
        relax_multi_pallas(values, parent, frontier, ok,
                           jnp.full((BLOCK_E,), n, jnp.int32),
                           jnp.ones((BLOCK_E,), jnp.float32),
                           jnp.int32(0), op="min_plus", num_nodes=n, k=0)


# -- single-hop kernels vs oracles, fuzzed ------------------------------------


@pytest.mark.parametrize("mode", MODES)
@given(n=st.integers(1, 200), e=st.integers(1, 1500), seed=st.integers(0, 99),
       dup=st.booleans(), unit_w=st.booleans())
@settings(max_examples=4, deadline=None)
def test_edge_relax_matches_ref_fuzzed(mode, n, e, seed, dup, unit_w):
    src, dst, w = _edges(n, e, seed, dup_heavy=dup, unit_w=unit_w)
    for name in SEMIRINGS:
        values = _mixed_values(ALL_SEMIRINGS[name], n, seed)
        assert_kernel_matches_ref(
            edge_relax, edge_relax_ref, (values, src, dst, w),
            dict(op=KERNEL_OP_FOR[name], num_nodes=n), mode=mode)


@pytest.mark.parametrize("mode", MODES)
@given(n=st.integers(1, 120), e=st.integers(1, 1200), d=st.integers(1, 24),
       seed=st.integers(0, 99), red=st.sampled_from(["sum", "min", "max"]))
@settings(max_examples=4, deadline=None)
def test_segment_reduce_matches_ref_fuzzed(mode, n, e, d, seed, red):
    rng = np.random.default_rng(seed)
    data = jnp.asarray(rng.standard_normal((e, d)).astype(np.float32))
    seg = jnp.asarray(rng.integers(0, n, e).astype(np.int32))
    assert_kernel_matches_ref(
        segment_reduce, segment_reduce_ref, (data, seg),
        dict(num_segments=n, reduce=red), mode=mode)


def test_edge_relax_all_padding_block(mode=MODES[0]):
    """Sentinel dst == n must never contaminate real nodes (any semiring)."""
    n = 16
    src = jnp.zeros((BLOCK_E,), jnp.int32)
    dst = jnp.full((BLOCK_E,), n, jnp.int32)
    w = jnp.ones((BLOCK_E,), jnp.float32)
    for name in SEMIRINGS:
        sr = ALL_SEMIRINGS[name]
        values = _mixed_values(sr, n, 3)
        got = assert_kernel_matches_ref(
            edge_relax, edge_relax_ref, (values, src, dst, w),
            dict(op=KERNEL_OP_FOR[name], num_nodes=n), mode=mode)
        np.testing.assert_array_equal(
            np.asarray(got), np.full(n, np.float32(sr.identity)))


# -- the fused multi-sweep kernel vs its oracle, fuzzed -----------------------


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("name", SEMIRINGS)
@given(n=st.integers(1, 150), e=st.integers(0, 1200), seed=st.integers(0, 99),
       k=st.sampled_from(FUSED_KS), layout=st.sampled_from(["edge", "csr"]),
       frontier=st.sampled_from(["mixed", "empty", "source"]),
       dup=st.booleans(), unit_w=st.booleans())
@settings(max_examples=3, deadline=None)
def test_relax_multi_matches_ref_fuzzed(mode, name, n, e, seed, k, layout,
                                        frontier, dup, unit_w):
    sr = ALL_SEMIRINGS[name]
    src, dst, w = _edges(n, e, seed, dup_heavy=dup, unit_w=unit_w)
    values, parent, fro = _state(sr, n, seed, frontier=frontier)
    assert_kernel_matches_ref(
        relax_multi, relax_multi_ref,
        (values, parent, fro, src, dst, w),
        dict(op=KERNEL_OP_FOR[name], num_nodes=n, k=k), mode=mode,
        ref_kwargs=dict(op=KERNEL_OP_FOR[name], num_nodes=n, k=k))
    # layout is a pallas-side knob the oracle has no analogue for: csr
    # (dst-sorted segment-reduce layout) must be bit-identical to edge.
    if layout == "csr":
        base = dict(op=KERNEL_OP_FOR[name], num_nodes=n, k=k)
        by_edge = _call(relax_multi, (values, parent, fro, src, dst, w),
                        base, mode)
        by_csr = _call(relax_multi, (values, parent, fro, src, dst, w),
                       dict(base, layout="csr"), mode)
        for g, r in zip(by_edge, by_csr):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(r))


@pytest.mark.parametrize("mode", MODES)
def test_relax_multi_all_padding_and_single_node(mode):
    """e=0 pads to one all-padding block; n=1 graphs only self-loop."""
    for name in SEMIRINGS:
        sr = ALL_SEMIRINGS[name]
        for n, e in ((9, 0), (1, 0), (1, 5)):
            src, dst, w = _edges(n, e, seed=n + e)
            values, parent, fro = _state(sr, n, seed=e)
            assert_kernel_matches_ref(
                relax_multi, relax_multi_ref,
                (values, parent, fro, src, dst, w),
                dict(op=KERNEL_OP_FOR[name], num_nodes=n, k=3), mode=mode)


# -- engine contract: fused(k) == k sequential relax_sweep applications -------


def _engine_fixture(sr, n=24, e=64, seed=5):
    """A reachable graph + freshly-seeded engine state (source frontier)."""
    rng = np.random.default_rng(seed)
    src = np.concatenate([np.arange(n - 1), rng.integers(0, n, e)])
    dst = np.concatenate([np.arange(1, n), rng.integers(0, n, e)])
    w = (rng.random(src.size) + 0.01).astype(np.float32)
    block = make_block(src.astype(np.int32), dst.astype(np.int32), w, n)
    values = jnp.full((n,), jnp.float32(sr.identity)).at[0].set(
        jnp.float32(sr.source_value))
    parent = jnp.full((n,), -1, jnp.int32)
    frontier = jnp.zeros((n,), bool).at[0].set(True)
    return (block,), values, parent, frontier


def _sequential_chunk(sr, n, values, parent, frontier, blocks, k):
    """The oracle for one fused chunk: k relax_sweeps with early exit."""
    sweeps, work = 0, np.float32(0.0)
    for _ in range(k):
        if not bool(np.any(np.asarray(frontier))):
            break
        values, parent, frontier, dw = relax_sweep(
            sr, n, values, parent, frontier, blocks)
        sweeps += 1
        work = np.float32(work + np.float32(dw))
    return values, parent, frontier, sweeps, work


@pytest.mark.parametrize("name", SEMIRINGS)
@pytest.mark.parametrize("k", FUSED_KS)
def test_fused_chunk_equals_k_sequential_sweeps(name, k):
    """Both fused paths == k relax_sweeps, through convergence (early exit:
    the path graph converges well before 7 chained chunks of k sweeps)."""
    sr = ALL_SEMIRINGS[name]
    n = 24
    blocks, values, parent, frontier = _engine_fixture(sr, n=n)
    for chunk in range(64):
        expect = _sequential_chunk(sr, n, values, parent, frontier, blocks, k)
        for use_pallas in (False, True):
            got = relax_sweep_fused(sr, n, values, parent, frontier, blocks,
                                    k=k, use_pallas=use_pallas)
            for i, (g, r) in enumerate(zip(got, expect)):
                np.testing.assert_array_equal(
                    np.asarray(g), np.asarray(r),
                    err_msg=f"fused(k={k}) leaf {i} != {k} sweeps "
                            f"(semiring={name}, use_pallas={use_pallas})")
        values, parent, frontier = expect[0], expect[1], expect[2]
        if not bool(np.any(np.asarray(frontier))):
            break
    # ran to convergence: the final chunk observed the frontier empty
    assert not bool(np.any(np.asarray(frontier))), "did not converge in 64"


def test_fused_chunk_empty_frontier_is_noop():
    """A chunk seeded with an empty frontier runs zero sweeps, zero work."""
    sr = ALL_SEMIRINGS["sssp"]
    n = 12
    blocks, values, parent, _ = _engine_fixture(sr, n=n)
    empty = jnp.zeros((n,), bool)
    for use_pallas in (False, True):
        vals, par, fro, sweeps, work = relax_sweep_fused(
            sr, n, values, parent, empty, blocks, k=7,
            use_pallas=use_pallas)
        np.testing.assert_array_equal(np.asarray(vals), np.asarray(values))
        np.testing.assert_array_equal(np.asarray(par), np.asarray(parent))
        assert not bool(np.any(np.asarray(fro)))
        assert int(sweeps) == 0 and float(work) == 0.0


@pytest.mark.parametrize("name", SEMIRINGS)
def test_run_to_fixpoint_invariant_in_fused_k(name):
    """fused_k is a pure launch-shape knob: values, parents, iteration count
    and edge work are bit-identical for every chunk size."""
    sr = ALL_SEMIRINGS[name]
    blocks, *_ = _engine_fixture(sr, n=32, e=90, seed=11)
    view = EdgeView(blocks, 32)
    base = run_to_fixpoint(view, sr, 0, track_parents=True)
    for fk in FUSED_KS[1:]:
        res = run_to_fixpoint(view, sr, 0, track_parents=True, fused_k=fk)
        np.testing.assert_array_equal(np.asarray(res.values),
                                      np.asarray(base.values))
        np.testing.assert_array_equal(np.asarray(res.parent),
                                      np.asarray(base.parent))
        assert int(res.iterations) == int(base.iterations)
        assert float(res.edge_work) == float(base.edge_work)

"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see 1 device
(the 512-device override belongs exclusively to launch/dryrun.py).

Also installs the deterministic `hypothesis` fallback (tests/_hypothesis_fallback.py)
when the real package is absent, so collection works in hermetic containers;
CI installs the real hypothesis via the `test` extra.
"""

import importlib.util
import pathlib
import sys

try:
    import hypothesis  # noqa: F401 — prefer the real package when available
except ModuleNotFoundError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        pathlib.Path(__file__).with_name("_hypothesis_fallback.py"))
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies

import numpy as np
import pytest


@pytest.fixture(autouse=True, scope="module")
def _release_compiled_executables():
    """Drop jax's compiled-executable caches after every test module.

    Every live XLA:CPU executable holds mmap'd code pages, and the kernel
    caps mappings per process (``vm.max_map_count``, 65530 by default).
    The suite compiles enough distinct programs that keeping them ALL
    alive walks the process into the cap and the next compile segfaults
    inside XLA — deterministically, hundreds of tests after the cause.
    Clearing per module bounds the peak at the largest single module
    while keeping intra-module cache reuse (where nearly all hits are).
    """
    yield
    import jax

    jax.clear_caches()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def forced_cpu_mesh_run():
    """Run a Python snippet in a subprocess with 4 forced CPU devices.

    The multi-device sharding tests need XLA_FLAGS set before jax first
    initializes, which the in-process suite must not do (see the module
    docstring) — so they ship their assertions to a child interpreter and
    assert on its exit status. Returns the child's stdout.
    """
    import os
    import subprocess

    def run(script: str) -> str:
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        proc = subprocess.run([sys.executable, "-c", script], env=env,
                              capture_output=True, text=True, timeout=540)
        assert proc.returncode == 0, (
            f"forced-mesh subprocess failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}")
        return proc.stdout

    return run

"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see 1 device
(the 512-device override belongs exclusively to launch/dryrun.py).

Also installs the deterministic `hypothesis` fallback (tests/_hypothesis_fallback.py)
when the real package is absent, so collection works in hermetic containers;
CI installs the real hypothesis via the `test` extra.
"""

import importlib.util
import pathlib
import sys

try:
    import hypothesis  # noqa: F401 — prefer the real package when available
except ModuleNotFoundError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        pathlib.Path(__file__).with_name("_hypothesis_fallback.py"))
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)

"""Level-synchronous batched TG executor: equivalence + stacking invariants.

The monotone-fixpoint guarantee promises `run_plan_batched` results that are
bit-identical to the sequential `run_plan` (same edge sets per lane, same
start states), which in turn match per-snapshot from-scratch fixpoints.
"""

import numpy as np
import pytest

from repro.core import (
    SnapshotStore,
    bisection_plan,
    direct_hop_plan,
    optimal_plan,
    plan_levels,
    run_direct_hop,
    run_direct_hop_batched,
    run_plan,
    run_plan_batched,
)
from repro.graph import make_evolving_sequence, run_to_fixpoint
from repro.graph.edgeset import stack_delta_blocks
from repro.graph.semiring import ALL_SEMIRINGS


def _store(n=300, e=2400, snaps=6, changes=150, seed=11, granule=128):
    return SnapshotStore(make_evolving_sequence(n, e, snaps, changes, seed=seed),
                         granule=granule)


def _plans(store):
    n = store.seq.num_snapshots
    return {"direct_hop": direct_hop_plan(n=n),
            "bisection": bisection_plan(n=n),
            "optimal": optimal_plan(store)}


# one min-order and one max-order semiring cover both reduce directions
@pytest.mark.parametrize("alg", ["sssp", "sswp"])
def test_batched_plan_identical_to_sequential_and_scratch(alg):
    store = _store()
    sr = ALL_SEMIRINGS[alg]
    n_snap = store.seq.num_snapshots
    scratch = [run_to_fixpoint(store.snapshot_view(i), sr, 0).values
               for i in range(n_snap)]
    for name, plan in _plans(store).items():
        seq_run = run_plan(store, plan, sr, 0)
        bat_run = run_plan_batched(store, plan, sr, 0)
        assert sorted(bat_run.results) == list(range(n_snap))
        for i in range(n_snap):
            np.testing.assert_array_equal(
                np.asarray(bat_run.results[i]), np.asarray(seq_run.results[i]),
                err_msg=f"{name}/{alg}/snapshot {i}: batched != sequential")
            np.testing.assert_allclose(
                np.asarray(bat_run.results[i]), np.asarray(scratch[i]),
                rtol=1e-6, err_msg=f"{name}/{alg}/snapshot {i} vs scratch")


@pytest.mark.parametrize("alg", ["sssp", "viterbi"])
def test_batched_plan_empty_delta_hops(alg):
    """batch_changes=0 → identical snapshots → every hop Δ is empty."""
    store = _store(n=150, e=900, snaps=4, changes=0, seed=3, granule=64)
    sr = ALL_SEMIRINGS[alg]
    for plan in _plans(store).values():
        bat = run_plan_batched(store, plan, sr, 0)
        seq = run_plan(store, plan, sr, 0)
        for i in range(4):
            np.testing.assert_array_equal(np.asarray(bat.results[i]),
                                          np.asarray(seq.results[i]))


def test_batched_plan_single_snapshot_window():
    store = _store(n=120, e=700, snaps=1, changes=0, seed=5, granule=64)
    sr = ALL_SEMIRINGS["sssp"]
    bat = run_plan_batched(store, direct_hop_plan(n=1), sr, 0)
    ref = run_to_fixpoint(store.snapshot_view(0), sr, 0)
    assert list(bat.results) == [0]
    np.testing.assert_array_equal(np.asarray(bat.results[0]),
                                  np.asarray(ref.values))


def test_batched_plan_tracks_parents_and_edge_work():
    """Options parity at the WorkSharingRun level: per-plan total edge work
    of the batched run equals the sequential run's (same seeding, same
    frontier evolution, padding excluded from the work counter)."""
    store = _store(snaps=5, seed=17)
    sr = ALL_SEMIRINGS["sssp"]
    for name, plan in _plans(store).items():
        seq_run = run_plan(store, plan, sr, 0, track_parents=True)
        bat_run = run_plan_batched(store, plan, sr, 0, track_parents=True)
        seq_work = sum(s.edge_work for s in seq_run.hop_stats)
        bat_work = sum(s.edge_work for s in bat_run.hop_stats)
        assert seq_work == pytest.approx(bat_work), name


@pytest.mark.parametrize("gated,cg_split,track_parents",
                         [(True, 4, True), (True, 1, False), (False, 4, True)])
def test_direct_hop_batched_honors_options(gated, cg_split, track_parents):
    """Regression: the batched twin must honor gated/cg_split/track_parents
    (it used to silently ignore all three)."""
    store = _store(snaps=4, seed=23)
    sr = ALL_SEMIRINGS["sssp"]
    dh = run_direct_hop(store, sr, 0, gated=gated, cg_split=cg_split,
                        track_parents=track_parents)
    dhb = run_direct_hop_batched(store, sr, 0, gated=gated, cg_split=cg_split,
                                 track_parents=track_parents)
    for i in range(4):
        np.testing.assert_array_equal(np.asarray(dhb.results[i]),
                                      np.asarray(dh.results[i]))


def test_batched_plan_on_snapshot_mesh():
    """The --shard path: lanes placed over a 1-D data mesh (single device in
    CI, so every level divides and the device_put branch executes)."""
    from repro.launch.mesh import make_snapshot_mesh
    store = _store(n=200, e=1400, snaps=4, changes=100, seed=29, granule=64)
    sr = ALL_SEMIRINGS["sssp"]
    plan = optimal_plan(store)
    bat = run_plan_batched(store, plan, sr, 0, mesh=make_snapshot_mesh())
    seq = run_plan(store, plan, sr, 0)
    for i in range(4):
        np.testing.assert_array_equal(np.asarray(bat.results[i]),
                                      np.asarray(seq.results[i]))


def test_plan_levels_shape():
    plan = bisection_plan(n=8)
    levels = plan_levels(plan)
    assert [len(lv) for lv in levels] == [2, 4, 8]
    # parent lane indices point into the previous level
    for prev_len, level in zip([1] + [len(lv) for lv in levels], levels):
        assert all(0 <= pi < prev_len for pi, _ in level)
    # star plan: exactly one level with every snapshot as a lane
    assert [len(lv) for lv in plan_levels(direct_hop_plan(n=6))] == [6]


def test_stack_delta_blocks_bucketing():
    """Ragged lanes land in ONE bucketed width: jit trace shapes depend only
    on (num_lanes, bucket), not the exact ragged sizes."""
    rng = np.random.default_rng(0)

    def lanes(sizes):
        out = []
        for s in sizes:
            src = rng.integers(0, 50, size=s).astype(np.int32)
            dst = (src + 1) % 50
            out.append((src, dst.astype(np.int32),
                        np.ones(s, np.float32)))
        return out

    ragged = lanes([3, 17, 9])
    a = stack_delta_blocks(ragged, 50, granule=16, pad_pow2=True)
    b = stack_delta_blocks(lanes([1, 30, 25]), 50, granule=16, pad_pow2=True)
    assert a.src.shape == b.src.shape == (3, 32)
    # padding convention: sentinel dst rows, in-bounds src
    assert int(a.dst.max()) == 50 and int(a.src.max()) < 50
    with pytest.raises(ValueError):
        stack_delta_blocks([], 50)
    # lane-axis bucketing: trailing masked lanes are pure padding
    c = stack_delta_blocks(ragged, 50, granule=16, pad_pow2=True,
                           num_lanes=8)
    assert c.src.shape == (8, 32)
    np.testing.assert_array_equal(np.asarray(c.src[:3]), np.asarray(a.src))
    assert int(np.asarray(c.dst[3:]).min()) == 50   # all-sentinel lanes
    assert int(np.asarray(c.src[3:]).max()) == 0    # PAD_SRC
    with pytest.raises(ValueError):
        stack_delta_blocks(lanes([3, 17]), 50, num_lanes=1)


def test_lane_bucket():
    """pow2 of the lane count, and always divisible by the data extent."""
    from repro.graph.edgeset import lane_bucket
    assert [lane_bucket(n) for n in (1, 2, 3, 5, 8, 9)] == [1, 2, 4, 8, 8, 16]
    assert lane_bucket(5, 4) == 8       # pow2 extents stay pow2
    assert lane_bucket(2, 8) == 8       # small levels round up to the mesh
    assert lane_bucket(3, 6) == 6       # non-pow2 extents: minimal multiple
    assert lane_bucket(9, 6) == 12      # pow2 lanes-per-device x extent
    for n in (1, 3, 5, 9):
        for d in (1, 2, 4, 6, 8):
            b = lane_bucket(n, d)
            assert b >= n and b % d == 0
    with pytest.raises(ValueError):
        lane_bucket(0)
    with pytest.raises(ValueError):
        lane_bucket(1, 0)


def test_delta_stack_lane_bucket_trace_key_and_results():
    """delta_stack(num_lanes=bucket) caches by bucketed lane count, and the
    batched executor's results/edge-work are invariant to the padding lanes
    (mesh=None still buckets: a 5-lane star level runs as 8 lanes)."""
    store = _store(snaps=5, seed=17)
    sr = ALL_SEMIRINGS["sssp"]
    plan = direct_hop_plan(n=5)
    hops = [((0, 4), (k, k)) for k in range(5)]
    stacked = store.delta_stack(hops, num_lanes=8)
    assert stacked.src.shape[0] == 8
    assert store.delta_stack(hops, num_lanes=8) is stacked  # cache hit
    assert store.delta_stack(hops).src.shape[0] == 5        # distinct tag
    seq_run = run_plan(store, plan, sr, 0)
    bat_run = run_plan_batched(store, plan, sr, 0)
    for i in range(5):
        np.testing.assert_array_equal(np.asarray(bat_run.results[i]),
                                      np.asarray(seq_run.results[i]))
    seq_work = sum(s.edge_work for s in seq_run.hop_stats)
    bat_work = sum(s.edge_work for s in bat_run.hop_stats)
    assert seq_work == pytest.approx(bat_work)


_FORCED_MESH_PLAN_SCRIPT = """
import warnings

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

assert len(jax.devices()) == 4, jax.devices()

from repro.core import SnapshotStore, direct_hop_plan, optimal_plan, \\
    plan_levels, run_plan, run_plan_batched
from repro.core.trigrid import _shard_snapshot_axis
from repro.graph import make_evolving_sequence
from repro.graph.edgeset import lane_bucket
from repro.graph.semiring import ALL_SEMIRINGS
from repro.launch.mesh import make_snapshot_mesh

store = SnapshotStore(make_evolving_sequence(150, 900, 5, 120, seed=11),
                      granule=64)
sr = ALL_SEMIRINGS["sssp"]
mesh = make_snapshot_mesh()
assert mesh.shape["data"] == 4

# sharding-spec assertion: the bucketed lane axis splits over `data`
bucket = lane_bucket(5, 4)
assert bucket == 8
v = jnp.zeros((bucket, store.num_nodes))
p = jnp.zeros((bucket, store.num_nodes), jnp.int32)
v, p, _, lv = _shard_snapshot_axis(mesh, v, p, (), jnp.arange(bucket) < 5)
assert v.sharding.spec == PartitionSpec("data"), v.sharding
assert not v.sharding.is_fully_replicated
assert lv.sharding.spec == PartitionSpec("data")

plans = {"optimal": optimal_plan(store), "direct_hop": direct_hop_plan(n=5)}
# the point of the test: at least one level's lane count does NOT divide 4
assert any(len(level) % 4
           for plan in plans.values() for level in plan_levels(plan))
for name, plan in plans.items():
    seq_run = run_plan(store, plan, sr, 0, track_parents=True)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        bat_run = run_plan_batched(store, plan, sr, 0, track_parents=True,
                                   mesh=mesh)
    ours = [w for w in caught
            if issubclass(w.category, UserWarning) and "repro" in w.filename]
    assert not ours, [str(w.message) for w in ours]
    for i in range(5):
        np.testing.assert_array_equal(np.asarray(bat_run.results[i]),
                                      np.asarray(seq_run.results[i]),
                                      err_msg=f"{name}/snapshot {i}")
    seq_work = sum(s.edge_work for s in seq_run.hop_stats)
    bat_work = sum(s.edge_work for s in bat_run.hop_stats)
    assert abs(seq_work - bat_work) < 1e-6, (name, seq_work, bat_work)
print("MESH-OK")
"""


def test_batched_plan_shards_on_forced_multidevice_mesh(forced_cpu_mesh_run):
    """The fixed --shard path on a real 4-device data mesh: non-dividing
    levels shard via pow2 lane bucketing (no replicated-fallback warning),
    results stay bit-identical to sequential, and masked padding lanes do
    not change edge-work totals."""
    assert "MESH-OK" in forced_cpu_mesh_run(_FORCED_MESH_PLAN_SCRIPT)

"""CI perf-regression gate (scripts/bench_gate.py) behaviour.

Pure-JSON tests: a clean run passes, an injected synthetic regression
(exact-field drift, a wall-time blowout, or a ratio field — qps/latency —
drifting outside the two-sided tolerance) fails the gate, and structural
drift (missing/extra benches, rows or ratio keys) demands a baseline
refresh.
"""

import copy
import importlib.util
import json
import pathlib

SCRIPT = pathlib.Path(__file__).parent.parent / "scripts" / "bench_gate.py"
_spec = importlib.util.spec_from_file_location("bench_gate", SCRIPT)
bench_gate = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(bench_gate)

TREND = pathlib.Path(__file__).parent.parent / "scripts" / "bench_trend.py"
_tspec = importlib.util.spec_from_file_location("bench_trend", TREND)
bench_trend = importlib.util.module_from_spec(_tspec)
_tspec.loader.exec_module(bench_trend)


BASE = {
    "bench": "window_stream",
    "schema_version": 2,
    "generated_unix": 0.0,
    "status": "ok",
    "error": None,
    "rows": [
        {"name": "window_stream/width2", "us_per_call": 1000.0,
         "derived": "campaigns=3 rebuilds=1+2hops vs cold 3",
         "exact": {"campaigns": 3, "rebuilds_stream": 1,
                   "rebuilds_cold": 3, "edge_work": 8706}},
        {"name": "window_stream/width3", "us_per_call": 2000.0,
         "derived": "campaigns=2 rebuilds=1+1hops vs cold 2",
         "exact": {"campaigns": 2, "rebuilds_stream": 1,
                   "rebuilds_cold": 2, "edge_work": 7446}},
    ],
}


def _write(dirpath, doc):
    dirpath.mkdir(parents=True, exist_ok=True)
    (dirpath / f"BENCH_{doc['bench']}.json").write_text(json.dumps(doc))


def _dirs(tmp_path, run_doc):
    base_dir, run_dir = tmp_path / "baselines", tmp_path / "run"
    _write(base_dir, BASE)
    _write(run_dir, run_doc)
    return base_dir, run_dir


def _gate(tmp_path, run_doc, time_tol=4.0):
    base_dir, run_dir = _dirs(tmp_path, run_doc)
    return bench_gate.gate(run_dir, base_dir, time_tol)


def test_gate_passes_identical_run(tmp_path):
    assert _gate(tmp_path, copy.deepcopy(BASE)) == []


def test_gate_tolerates_wall_time_noise(tmp_path):
    run = copy.deepcopy(BASE)
    run["rows"][0]["us_per_call"] *= 3.5      # noisy but under 4x
    run["rows"][1]["us_per_call"] *= 0.1      # speedups always pass
    assert _gate(tmp_path, run) == []


def test_gate_fails_wall_time_regression(tmp_path):
    run = copy.deepcopy(BASE)
    run["rows"][1]["us_per_call"] *= 10       # injected 10x slowdown
    problems = _gate(tmp_path, run)
    assert len(problems) == 1
    assert "width3" in problems[0] and "exceeds" in problems[0]
    # a looser tolerance waves the same run through
    assert _gate(tmp_path, run, time_tol=20.0) == []


def test_gate_fails_exact_field_drift(tmp_path):
    run = copy.deepcopy(BASE)
    # the synthetic regression of the acceptance criterion: anchor reuse
    # silently broken -> rebuild count drifts -> gate must fail
    run["rows"][0]["exact"]["rebuilds_stream"] = 3
    problems = _gate(tmp_path, run)
    assert len(problems) == 1
    assert "rebuilds_stream" in problems[0]
    assert "run 3" in problems[0] and "baseline 1" in problems[0]


RATIO_BASE = {
    "bench": "serve",
    "schema_version": 2,
    "generated_unix": 0.0,
    "status": "ok",
    "error": None,
    "rows": [
        {"name": "serve/load", "us_per_call": 100_000.0,
         "derived": "4 clients 14 queries",
         "exact": {"completed": 14, "rebuilds_service": 3,
                   "bit_identical": True},
         "ratio": {"queries_per_sec": 100.0, "p50_us": 5_000.0,
                   "p99_us": 20_000.0}},
    ],
}


def _gate_ratio(tmp_path, run_doc, time_tol=4.0):
    base_dir, run_dir = tmp_path / "baselines", tmp_path / "run"
    _write(base_dir, RATIO_BASE)
    _write(run_dir, run_doc)
    return bench_gate.gate(run_dir, base_dir, time_tol)


def test_gate_ratio_fields_tolerate_noise_both_ways(tmp_path):
    run = copy.deepcopy(RATIO_BASE)
    run["rows"][0]["ratio"]["queries_per_sec"] = 350.0   # 3.5x faster
    run["rows"][0]["ratio"]["p50_us"] = 17_000.0         # 3.4x slower
    assert _gate_ratio(tmp_path, run) == []
    # identical ratios self-gate even at a razor-thin tolerance
    assert _gate_ratio(tmp_path, copy.deepcopy(RATIO_BASE),
                       time_tol=1.0001) == []


def test_gate_ratio_fields_fail_outside_tolerance_both_directions(tmp_path):
    run = copy.deepcopy(RATIO_BASE)
    run["rows"][0]["ratio"]["p99_us"] = 100_000.0        # 5x latency blowup
    problems = _gate_ratio(tmp_path, run)
    assert len(problems) == 1
    assert "p99_us" in problems[0] and "two-sided" in problems[0]
    # a 5x "improvement" fails the SAME way: baselines must track reality
    run = copy.deepcopy(RATIO_BASE)
    run["rows"][0]["ratio"]["queries_per_sec"] = 500.0
    problems = _gate_ratio(tmp_path, run)
    assert len(problems) == 1
    assert "queries_per_sec" in problems[0] and "two-sided" in problems[0]


def test_gate_ratio_key_set_drift_fails(tmp_path):
    run = copy.deepcopy(RATIO_BASE)
    del run["rows"][0]["ratio"]["p50_us"]                # run lost a field
    run["rows"][0]["ratio"]["p90_us"] = 9_000.0          # and grew another
    problems = _gate_ratio(tmp_path, run)
    assert any("'p50_us' missing from run" in p for p in problems)
    assert any("'p90_us' missing from baseline" in p for p in problems)


def test_gate_rows_without_ratio_still_gate(tmp_path):
    """BASE's rows carry no ratio key at all (pre-serving benches): the
    ratio class is opt-in per row and absent keys compare clean."""
    run = copy.deepcopy(BASE)
    run["rows"][0]["us_per_call"] *= 2.0
    assert _gate(tmp_path, run) == []


def test_gate_fails_failed_bench(tmp_path):
    run = copy.deepcopy(BASE)
    run["status"], run["error"], run["rows"] = "failed", "boom", []
    problems = _gate(tmp_path, run)
    assert len(problems) == 1 and "status='failed'" in problems[0]


def test_gate_fails_row_set_drift(tmp_path):
    run = copy.deepcopy(BASE)
    run["rows"][0]["name"] = "window_stream/width99"
    problems = _gate(tmp_path, run)
    assert any("missing from run" in p for p in problems)
    assert any("no baseline" in p for p in problems)


def test_gate_fails_missing_and_extra_bench_files(tmp_path):
    base_dir, run_dir = _dirs(tmp_path, copy.deepcopy(BASE))
    extra = dict(copy.deepcopy(BASE), bench="novel")
    _write(run_dir, extra)                     # run-only bench
    other = dict(copy.deepcopy(BASE), bench="gone")
    _write(base_dir, other)                    # baseline-only bench
    problems = bench_gate.gate(run_dir, base_dir, 4.0)
    assert any("BENCH_gone.json" in p and "emitted no" in p
               for p in problems)
    assert any("BENCH_novel.json" in p and "no committed baseline" in p
               for p in problems)


def test_gate_fails_missing_baseline_dir(tmp_path):
    problems = bench_gate.gate(tmp_path / "run", tmp_path / "nothing", 4.0)
    assert len(problems) == 1
    assert "baseline directory" in problems[0]
    assert "does not exist" in problems[0]
    assert "benchmarks/baselines/smoke" in problems[0]  # the remedy


def test_gate_fails_empty_baseline_dir(tmp_path):
    empty = tmp_path / "empty"
    empty.mkdir()
    problems = bench_gate.gate(tmp_path / "run", empty, 4.0)
    assert len(problems) == 1 and "no BENCH_*.json baselines" in problems[0]


def test_gate_fails_missing_run_dir(tmp_path):
    base_dir = tmp_path / "baselines"
    _write(base_dir, BASE)
    problems = bench_gate.gate(tmp_path / "never-emitted", base_dir, 4.0)
    assert len(problems) == 1
    assert "run directory" in problems[0]
    assert "does not exist" in problems[0]
    assert "re-emit the run artifacts" in problems[0]


def test_gate_names_corrupt_baseline_json(tmp_path):
    base_dir, run_dir = _dirs(tmp_path, copy.deepcopy(BASE))
    (base_dir / "BENCH_window_stream.json").write_text('{"bench": trunc')
    problems = bench_gate.gate(run_dir, base_dir, 4.0)
    assert len(problems) == 1
    assert "BENCH_window_stream.json" in problems[0]
    assert "baseline is not valid JSON" in problems[0]
    assert "line 1" in problems[0]                       # parse position
    assert "refresh the committed baselines" in problems[0]


def test_gate_names_corrupt_run_json(tmp_path):
    base_dir, run_dir = _dirs(tmp_path, copy.deepcopy(BASE))
    (run_dir / "BENCH_window_stream.json").write_text("")  # truncated upload
    problems = bench_gate.gate(run_dir, base_dir, 4.0)
    assert len(problems) == 1
    assert "run is not valid JSON" in problems[0]
    assert "re-emit the run artifacts" in problems[0]


def test_gate_names_unreadable_baseline_file(tmp_path):
    base_dir, run_dir = _dirs(tmp_path, copy.deepcopy(BASE))
    target = base_dir / "BENCH_window_stream.json"
    target.unlink()
    target.mkdir()                       # a directory where a file should be
    problems = bench_gate.gate(run_dir, base_dir, 4.0)
    assert len(problems) == 1
    assert "unreadable baseline file" in problems[0]


def test_gate_names_non_object_top_level(tmp_path):
    base_dir, run_dir = _dirs(tmp_path, copy.deepcopy(BASE))
    (run_dir / "BENCH_window_stream.json").write_text("[1, 2, 3]")
    problems = bench_gate.gate(run_dir, base_dir, 4.0)
    assert len(problems) == 1
    assert "top level must be a JSON object" in problems[0]
    assert "got list" in problems[0]


def test_gate_main_exit_codes(tmp_path, capsys):
    base_dir, run_dir = _dirs(tmp_path, copy.deepcopy(BASE))
    assert bench_gate.main(["--run-dir", str(run_dir),
                            "--baseline-dir", str(base_dir)]) == 0
    assert "bench gate: OK" in capsys.readouterr().out
    bad = copy.deepcopy(BASE)
    bad["rows"][0]["exact"]["edge_work"] += 1
    _write(run_dir, bad)
    assert bench_gate.main(["--run-dir", str(run_dir),
                            "--baseline-dir", str(base_dir)]) == 1
    assert "bench gate: FAIL" in capsys.readouterr().out


# -- nightly trend (scripts/bench_trend.py) -----------------------------------

def _trend_dirs(tmp_path, prev_doc, curr_doc):
    """Write the docs NESTED one level down, the way gh run download
    unpacks artifacts — flat globbing must not be assumed."""
    prev_dir = tmp_path / "prev" / "bench-json-nightly-1"
    curr_dir = tmp_path / "curr"
    _write(prev_dir, prev_doc)
    _write(curr_dir, curr_doc)
    return tmp_path / "prev", curr_dir


def test_trend_steady_run_reports_nothing_and_exits_zero(tmp_path, capsys):
    prev_dir, curr_dir = _trend_dirs(tmp_path, BASE, copy.deepcopy(BASE))
    assert bench_trend.main(["--prev", str(prev_dir),
                             "--curr", str(curr_dir)]) == 0
    assert "steady" in capsys.readouterr().out


def test_trend_reports_exact_drift_and_exits_one(tmp_path, capsys):
    curr = copy.deepcopy(BASE)
    curr["rows"][0]["exact"]["edge_work"] = 7000
    prev_dir, curr_dir = _trend_dirs(tmp_path, BASE, curr)
    assert bench_trend.main(["--prev", str(prev_dir),
                             "--curr", str(curr_dir)]) == 1
    out = capsys.readouterr().out
    assert "exact 'edge_work': 8706 -> 7000" in out
    assert "behaviour changed" in out


def test_trend_reports_wall_moves_without_failing(tmp_path, capsys):
    curr = copy.deepcopy(BASE)
    curr["rows"][0]["us_per_call"] *= 3.0       # beyond the 1.5x default
    prev_dir, curr_dir = _trend_dirs(tmp_path, BASE, curr)
    assert bench_trend.main(["--prev", str(prev_dir),
                             "--curr", str(curr_dir)]) == 0
    out = capsys.readouterr().out
    assert "moved >1.5x" in out and "(3.00x)" in out
    # a looser tolerance mutes the same move
    assert bench_trend.main(["--prev", str(prev_dir), "--curr",
                             str(curr_dir), "--move-tol", "4"]) == 0
    assert "steady" in capsys.readouterr().out


def test_trend_missing_side_skips_cleanly(tmp_path, capsys):
    curr_dir = tmp_path / "curr"
    _write(curr_dir, BASE)
    # nonexistent --prev directory: first nightly ever
    assert bench_trend.main(["--prev", str(tmp_path / "nope"),
                             "--curr", str(curr_dir)]) == 0
    assert "skipping" in capsys.readouterr().out
    # existing but empty --prev directory: artifacts expired
    (tmp_path / "empty").mkdir()
    assert bench_trend.main(["--prev", str(tmp_path / "empty"),
                             "--curr", str(curr_dir)]) == 0
    assert "nothing to compare" in capsys.readouterr().out


def test_trend_row_and_file_set_changes_are_informational(tmp_path, capsys):
    curr = copy.deepcopy(BASE)
    curr["rows"][1]["name"] = "window_stream/width9"
    prev_dir, curr_dir = _trend_dirs(tmp_path, BASE, curr)
    _write(curr_dir, dict(copy.deepcopy(BASE), bench="novel"))
    assert bench_trend.main(["--prev", str(prev_dir),
                             "--curr", str(curr_dir)]) == 0
    out = capsys.readouterr().out
    assert "width3 disappeared" in out
    assert "width9 is new" in out
    assert "BENCH_novel.json: new tonight" in out


def test_run_out_dir_created_when_missing(tmp_path):
    """benchmarks/run.py must create --out-dir (parents included) instead
    of erroring on fresh CI runners, and fail clearly on a file collision."""
    import pytest
    run_path = pathlib.Path(__file__).parent.parent / "benchmarks" / "run.py"
    spec = importlib.util.spec_from_file_location("bench_run", run_path)
    bench_run = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(bench_run)
    out = tmp_path / "deeply" / "nested" / "artifacts"
    path = bench_run.write_bench_json(out, "demo", "ok",
                                      [("demo/x", 1.0, "d", {"k": 1})], None)
    assert path.exists() and out.is_dir()
    doc = json.loads(path.read_text())
    assert doc["rows"] == [{"name": "demo/x", "us_per_call": 1.0,
                            "derived": "d", "exact": {"k": 1}}]
    clash = tmp_path / "file"
    clash.write_text("")
    with pytest.raises(SystemExit, match="collides"):
        bench_run.ensure_out_dir(clash / "sub")


def test_committed_smoke_baselines_self_consistent():
    """The committed baselines must gate-pass against themselves (guards
    against committing a failed/failed-status baseline)."""
    baseline_dir = pathlib.Path(__file__).parent.parent / "benchmarks" / \
        "baselines" / "smoke"
    problems = bench_gate.gate(baseline_dir, baseline_dir, 1.0001)
    assert problems == []
    docs = [json.loads(p.read_text())
            for p in baseline_dir.glob("BENCH_*.json")]
    assert docs, "no committed smoke baselines"
    assert all(d["status"] == "ok" for d in docs)
    assert all(d["schema_version"] == 2 for d in docs)

"""CI perf-regression gate (scripts/bench_gate.py) behaviour.

Pure-JSON tests: a clean run passes, an injected synthetic regression
(exact-field drift, a wall-time blowout, or a ratio field — qps/latency —
drifting outside the two-sided tolerance) fails the gate, and structural
drift (missing/extra benches, rows or ratio keys) demands a baseline
refresh.
"""

import copy
import importlib.util
import json
import pathlib

SCRIPT = pathlib.Path(__file__).parent.parent / "scripts" / "bench_gate.py"
_spec = importlib.util.spec_from_file_location("bench_gate", SCRIPT)
bench_gate = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(bench_gate)


BASE = {
    "bench": "window_stream",
    "schema_version": 2,
    "generated_unix": 0.0,
    "status": "ok",
    "error": None,
    "rows": [
        {"name": "window_stream/width2", "us_per_call": 1000.0,
         "derived": "campaigns=3 rebuilds=1+2hops vs cold 3",
         "exact": {"campaigns": 3, "rebuilds_stream": 1,
                   "rebuilds_cold": 3, "edge_work": 8706}},
        {"name": "window_stream/width3", "us_per_call": 2000.0,
         "derived": "campaigns=2 rebuilds=1+1hops vs cold 2",
         "exact": {"campaigns": 2, "rebuilds_stream": 1,
                   "rebuilds_cold": 2, "edge_work": 7446}},
    ],
}


def _write(dirpath, doc):
    dirpath.mkdir(parents=True, exist_ok=True)
    (dirpath / f"BENCH_{doc['bench']}.json").write_text(json.dumps(doc))


def _dirs(tmp_path, run_doc):
    base_dir, run_dir = tmp_path / "baselines", tmp_path / "run"
    _write(base_dir, BASE)
    _write(run_dir, run_doc)
    return base_dir, run_dir


def _gate(tmp_path, run_doc, time_tol=4.0):
    base_dir, run_dir = _dirs(tmp_path, run_doc)
    return bench_gate.gate(run_dir, base_dir, time_tol)


def test_gate_passes_identical_run(tmp_path):
    assert _gate(tmp_path, copy.deepcopy(BASE)) == []


def test_gate_tolerates_wall_time_noise(tmp_path):
    run = copy.deepcopy(BASE)
    run["rows"][0]["us_per_call"] *= 3.5      # noisy but under 4x
    run["rows"][1]["us_per_call"] *= 0.1      # speedups always pass
    assert _gate(tmp_path, run) == []


def test_gate_fails_wall_time_regression(tmp_path):
    run = copy.deepcopy(BASE)
    run["rows"][1]["us_per_call"] *= 10       # injected 10x slowdown
    problems = _gate(tmp_path, run)
    assert len(problems) == 1
    assert "width3" in problems[0] and "exceeds" in problems[0]
    # a looser tolerance waves the same run through
    assert _gate(tmp_path, run, time_tol=20.0) == []


def test_gate_fails_exact_field_drift(tmp_path):
    run = copy.deepcopy(BASE)
    # the synthetic regression of the acceptance criterion: anchor reuse
    # silently broken -> rebuild count drifts -> gate must fail
    run["rows"][0]["exact"]["rebuilds_stream"] = 3
    problems = _gate(tmp_path, run)
    assert len(problems) == 1
    assert "rebuilds_stream" in problems[0]
    assert "run 3" in problems[0] and "baseline 1" in problems[0]


RATIO_BASE = {
    "bench": "serve",
    "schema_version": 2,
    "generated_unix": 0.0,
    "status": "ok",
    "error": None,
    "rows": [
        {"name": "serve/load", "us_per_call": 100_000.0,
         "derived": "4 clients 14 queries",
         "exact": {"completed": 14, "rebuilds_service": 3,
                   "bit_identical": True},
         "ratio": {"queries_per_sec": 100.0, "p50_us": 5_000.0,
                   "p99_us": 20_000.0}},
    ],
}


def _gate_ratio(tmp_path, run_doc, time_tol=4.0):
    base_dir, run_dir = tmp_path / "baselines", tmp_path / "run"
    _write(base_dir, RATIO_BASE)
    _write(run_dir, run_doc)
    return bench_gate.gate(run_dir, base_dir, time_tol)


def test_gate_ratio_fields_tolerate_noise_both_ways(tmp_path):
    run = copy.deepcopy(RATIO_BASE)
    run["rows"][0]["ratio"]["queries_per_sec"] = 350.0   # 3.5x faster
    run["rows"][0]["ratio"]["p50_us"] = 17_000.0         # 3.4x slower
    assert _gate_ratio(tmp_path, run) == []
    # identical ratios self-gate even at a razor-thin tolerance
    assert _gate_ratio(tmp_path, copy.deepcopy(RATIO_BASE),
                       time_tol=1.0001) == []


def test_gate_ratio_fields_fail_outside_tolerance_both_directions(tmp_path):
    run = copy.deepcopy(RATIO_BASE)
    run["rows"][0]["ratio"]["p99_us"] = 100_000.0        # 5x latency blowup
    problems = _gate_ratio(tmp_path, run)
    assert len(problems) == 1
    assert "p99_us" in problems[0] and "two-sided" in problems[0]
    # a 5x "improvement" fails the SAME way: baselines must track reality
    run = copy.deepcopy(RATIO_BASE)
    run["rows"][0]["ratio"]["queries_per_sec"] = 500.0
    problems = _gate_ratio(tmp_path, run)
    assert len(problems) == 1
    assert "queries_per_sec" in problems[0] and "two-sided" in problems[0]


def test_gate_ratio_key_set_drift_fails(tmp_path):
    run = copy.deepcopy(RATIO_BASE)
    del run["rows"][0]["ratio"]["p50_us"]                # run lost a field
    run["rows"][0]["ratio"]["p90_us"] = 9_000.0          # and grew another
    problems = _gate_ratio(tmp_path, run)
    assert any("'p50_us' missing from run" in p for p in problems)
    assert any("'p90_us' missing from baseline" in p for p in problems)


def test_gate_rows_without_ratio_still_gate(tmp_path):
    """BASE's rows carry no ratio key at all (pre-serving benches): the
    ratio class is opt-in per row and absent keys compare clean."""
    run = copy.deepcopy(BASE)
    run["rows"][0]["us_per_call"] *= 2.0
    assert _gate(tmp_path, run) == []


def test_gate_fails_failed_bench(tmp_path):
    run = copy.deepcopy(BASE)
    run["status"], run["error"], run["rows"] = "failed", "boom", []
    problems = _gate(tmp_path, run)
    assert len(problems) == 1 and "status='failed'" in problems[0]


def test_gate_fails_row_set_drift(tmp_path):
    run = copy.deepcopy(BASE)
    run["rows"][0]["name"] = "window_stream/width99"
    problems = _gate(tmp_path, run)
    assert any("missing from run" in p for p in problems)
    assert any("no baseline" in p for p in problems)


def test_gate_fails_missing_and_extra_bench_files(tmp_path):
    base_dir, run_dir = _dirs(tmp_path, copy.deepcopy(BASE))
    extra = dict(copy.deepcopy(BASE), bench="novel")
    _write(run_dir, extra)                     # run-only bench
    other = dict(copy.deepcopy(BASE), bench="gone")
    _write(base_dir, other)                    # baseline-only bench
    problems = bench_gate.gate(run_dir, base_dir, 4.0)
    assert any("BENCH_gone.json" in p and "emitted no" in p
               for p in problems)
    assert any("BENCH_novel.json" in p and "no committed baseline" in p
               for p in problems)


def test_gate_fails_empty_baseline_dir(tmp_path):
    problems = bench_gate.gate(tmp_path / "run", tmp_path / "nothing", 4.0)
    assert len(problems) == 1 and "no BENCH_*.json baselines" in problems[0]


def test_gate_main_exit_codes(tmp_path, capsys):
    base_dir, run_dir = _dirs(tmp_path, copy.deepcopy(BASE))
    assert bench_gate.main(["--run-dir", str(run_dir),
                            "--baseline-dir", str(base_dir)]) == 0
    assert "bench gate: OK" in capsys.readouterr().out
    bad = copy.deepcopy(BASE)
    bad["rows"][0]["exact"]["edge_work"] += 1
    _write(run_dir, bad)
    assert bench_gate.main(["--run-dir", str(run_dir),
                            "--baseline-dir", str(base_dir)]) == 1
    assert "bench gate: FAIL" in capsys.readouterr().out


def test_run_out_dir_created_when_missing(tmp_path):
    """benchmarks/run.py must create --out-dir (parents included) instead
    of erroring on fresh CI runners, and fail clearly on a file collision."""
    import pytest
    run_path = pathlib.Path(__file__).parent.parent / "benchmarks" / "run.py"
    spec = importlib.util.spec_from_file_location("bench_run", run_path)
    bench_run = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(bench_run)
    out = tmp_path / "deeply" / "nested" / "artifacts"
    path = bench_run.write_bench_json(out, "demo", "ok",
                                      [("demo/x", 1.0, "d", {"k": 1})], None)
    assert path.exists() and out.is_dir()
    doc = json.loads(path.read_text())
    assert doc["rows"] == [{"name": "demo/x", "us_per_call": 1.0,
                            "derived": "d", "exact": {"k": 1}}]
    clash = tmp_path / "file"
    clash.write_text("")
    with pytest.raises(SystemExit, match="collides"):
        bench_run.ensure_out_dir(clash / "sub")


def test_committed_smoke_baselines_self_consistent():
    """The committed baselines must gate-pass against themselves (guards
    against committing a failed/failed-status baseline)."""
    baseline_dir = pathlib.Path(__file__).parent.parent / "benchmarks" / \
        "baselines" / "smoke"
    problems = bench_gate.gate(baseline_dir, baseline_dir, 1.0001)
    assert problems == []
    docs = [json.loads(p.read_text())
            for p in baseline_dir.glob("BENCH_*.json")]
    assert docs, "no committed smoke baselines"
    assert all(d["status"] == "ok" for d in docs)
    assert all(d["schema_version"] == 2 for d in docs)

"""Per-kernel interpret-mode validation: shape/dtype sweeps vs pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import edge_relax, embedding_bag_fused, segment_reduce
from repro.kernels.edge_relax.ref import edge_relax_ref
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.segment_reduce.ref import segment_reduce_ref


def _graph(n, e, seed=0):
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 4)
    vals = jax.random.uniform(ks[0], (n,)) * 10
    src = jax.random.randint(ks[1], (e,), 0, n)
    dst = jax.random.randint(ks[2], (e,), 0, n)
    w = jax.random.uniform(ks[3], (e,)) + 0.01
    return vals, src, dst, w


@pytest.mark.parametrize("op", ["min_plus", "max_min", "min_max", "max_times"])
@pytest.mark.parametrize("n,e", [(64, 100), (1000, 4096), (777, 9000)])
def test_edge_relax_matches_ref(op, n, e):
    vals, src, dst, w = _graph(n, e, seed=n + e)
    got = np.asarray(edge_relax(vals, src, dst, w, op=op, num_nodes=n))
    ref = np.asarray(edge_relax_ref(vals, src, dst, w, op=op, num_nodes=n))
    fin = np.isfinite(ref)
    np.testing.assert_array_equal(np.isfinite(got), fin)
    np.testing.assert_allclose(got[fin], ref[fin], rtol=1e-6)


@given(n=st.integers(8, 300), e=st.integers(1, 2000), seed=st.integers(0, 99))
@settings(max_examples=10, deadline=None)
def test_edge_relax_property(n, e, seed):
    vals, src, dst, w = _graph(n, e, seed)
    got = np.asarray(edge_relax(vals, src, dst, w, op="min_plus", num_nodes=n))
    ref = np.asarray(edge_relax_ref(vals, src, dst, w, op="min_plus", num_nodes=n))
    fin = np.isfinite(ref)
    np.testing.assert_allclose(got[fin], ref[fin], rtol=1e-6)


@pytest.mark.parametrize("red", ["sum", "min", "max"])
@pytest.mark.parametrize("d", [1, 18, 75, 128, 200])
def test_segment_reduce_matches_ref(red, d):
    n, e = 333, 2500
    k = jax.random.PRNGKey(d)
    data = jax.random.normal(k, (e, d))
    seg = jax.random.randint(jax.random.PRNGKey(d + 1), (e,), 0, n)
    got = np.asarray(segment_reduce(data, seg, num_segments=n, reduce=red))
    ref = np.asarray(segment_reduce_ref(data, seg, num_segments=n, reduce=red))
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_reduce_dtypes(dtype):
    n, e, d = 100, 1024, 32
    data = jax.random.normal(jax.random.PRNGKey(0), (e, d)).astype(dtype)
    seg = jax.random.randint(jax.random.PRNGKey(1), (e,), 0, n)
    got = segment_reduce(data, seg, num_segments=n, reduce="sum")
    ref = segment_reduce_ref(data, seg, num_segments=n, reduce="sum")
    assert got.dtype == ref.dtype == dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("v,d,n_ids,b",
                         [(100, 18, 500, 16), (4096, 36, 10_000, 256),
                          (777, 7, 3000, 33)])
def test_embedding_bag_matches_ref(v, d, n_ids, b):
    k = jax.random.PRNGKey(v)
    table = jax.random.normal(k, (v, d))
    ids = jax.random.randint(jax.random.PRNGKey(1), (n_ids,), 0, v)
    bags = jax.random.randint(jax.random.PRNGKey(2), (n_ids,), 0, b)
    wts = jax.random.uniform(jax.random.PRNGKey(3), (n_ids,))
    got = np.asarray(embedding_bag_fused(table, ids, bags, wts, n_bags=b))
    ref = np.asarray(embedding_bag_ref(table, ids, bags, wts, n_bags=b))
    np.testing.assert_allclose(got, ref, rtol=3e-5, atol=3e-5)


def test_embedding_bag_large_table_falls_back():
    """Tables over the VMEM budget must stream via the XLA path (same result)."""
    v, d = 200_000, 64  # 51 MB > budget
    table = jax.random.normal(jax.random.PRNGKey(0), (v, d))
    ids = jax.random.randint(jax.random.PRNGKey(1), (2048,), 0, v)
    bags = jax.random.randint(jax.random.PRNGKey(2), (2048,), 0, 64)
    wts = jnp.ones((2048,))
    got = np.asarray(embedding_bag_fused(table, ids, bags, wts, n_bags=64))
    ref = np.asarray(embedding_bag_ref(table, ids, bags, wts, n_bags=64))
    np.testing.assert_allclose(got, ref, rtol=1e-6)


def test_edge_relax_empty_and_padding_edges():
    """Sentinel dst == n must never contaminate real segments."""
    n = 32
    vals = jnp.arange(n, dtype=jnp.float32)
    src = jnp.array([0, 1], jnp.int32)
    dst = jnp.array([n, n], jnp.int32)  # all padding
    w = jnp.ones((2,), jnp.float32)
    got = np.asarray(edge_relax(vals, src, dst, w, op="min_plus", num_nodes=n))
    assert np.all(np.isinf(got))  # nothing relaxed

"""Sliding-window executors + SnapshotStore block-cache eviction.

Covers the core/window.py contract (batched slide bit-identical to the
sequential slide, both exact vs from-scratch per-window fixpoints) and the
SnapshotStore LRU/explicit-release guarantees (eviction frees delta_stack
buffers; re-fetch rebuilds bit-identical blocks; results never change).
"""

import numpy as np
import pytest

from repro.core import (
    SnapshotStore,
    run_window_slide,
    run_window_slide_batched,
    slide_windows,
    window_anchor,
)
from repro.core.snapshots import _block_nbytes
from repro.graph import EdgeView, make_evolving_sequence, run_to_fixpoint
from repro.graph.semiring import ALL_SEMIRINGS


def _store(n=300, e=2400, snaps=6, changes=150, seed=11, granule=128,
           **kw):
    return SnapshotStore(make_evolving_sequence(n, e, snaps, changes,
                                                seed=seed),
                         granule=granule, **kw)


# -- window plan construction -------------------------------------------------

def test_slide_windows_construction():
    assert slide_windows(6, 3) == [(0, 2), (1, 3), (2, 4), (3, 5)]
    assert slide_windows(6, 3, step=2) == [(0, 2), (2, 4)]
    assert slide_windows(6, 3, start=2) == [(2, 4), (3, 5)]
    assert slide_windows(6, 1) == [(i, i) for i in range(6)]
    # degenerate: width covering the whole sequence -> exactly one window
    assert slide_windows(6, 6) == [(0, 5)]
    with pytest.raises(ValueError):
        slide_windows(6, 7)
    with pytest.raises(ValueError):
        slide_windows(6, 0)
    with pytest.raises(ValueError):
        slide_windows(6, 3, step=0)


def test_window_anchor_is_span():
    assert window_anchor([(1, 3), (2, 4), (3, 5)]) == (1, 5)
    assert window_anchor([(2, 2)]) == (2, 2)
    with pytest.raises(ValueError):
        window_anchor([])


# -- batched-vs-sequential equivalence on random evolving graphs --------------

# one min-order and one max-order semiring cover both reduce directions
@pytest.mark.parametrize("alg", ["sssp", "sswp"])
@pytest.mark.parametrize("seed", [11, 37])
def test_window_slide_batched_identical_and_exact(alg, seed):
    store = _store(seed=seed)
    sr = ALL_SEMIRINGS[alg]
    for width in (2, 4):
        seq_run = run_window_slide(store, sr, 0, width)
        bat_run = run_window_slide_batched(store, sr, 0, width)
        windows = slide_windows(store.seq.num_snapshots, width)
        assert list(seq_run.results) == list(bat_run.results) == windows
        assert seq_run.anchor == bat_run.anchor == window_anchor(windows)
        for wnd in windows:
            np.testing.assert_array_equal(
                np.asarray(bat_run.results[wnd]),
                np.asarray(seq_run.results[wnd]),
                err_msg=f"{alg}/width {width}/window {wnd}: batched != seq")
            ref = run_to_fixpoint(
                EdgeView((store.window_block(*wnd),), store.num_nodes), sr, 0)
            np.testing.assert_allclose(
                np.asarray(bat_run.results[wnd]), np.asarray(ref.values),
                rtol=1e-6, err_msg=f"{alg}/width {width}/window {wnd} vs scratch")


def test_window_slide_edge_work_parity():
    """Padding excluded from work: batched totals equal sequential totals."""
    store = _store(seed=5)
    sr = ALL_SEMIRINGS["sssp"]
    for width in (2, 3):
        seq_run = run_window_slide(store, sr, 0, width, track_parents=True)
        bat_run = run_window_slide_batched(store, sr, 0, width,
                                           track_parents=True)
        seq_work = sum(h.edge_work for h in seq_run.hop_stats)
        bat_work = sum(h.edge_work for h in bat_run.hop_stats)
        assert seq_work == pytest.approx(bat_work)


def test_window_slide_degenerate_single_window():
    """width == num_snapshots: one window == the anchor, empty Δ, anchor
    state returned unchanged."""
    store = _store(snaps=4, seed=3)
    sr = ALL_SEMIRINGS["sssp"]
    bat = run_window_slide_batched(store, sr, 0, 4)
    assert list(bat.results) == [(0, 3)]
    assert bat.anchor == (0, 3)
    assert bat.added_edges == 0
    ref = run_to_fixpoint(store.common_graph_view(0, 3), sr, 0)
    np.testing.assert_array_equal(np.asarray(bat.results[(0, 3)]),
                                  np.asarray(ref.values))


def test_window_slide_explicit_windows_and_anchor():
    """Non-contiguous windows + explicit anchor; anchor must be a
    super-window of every window."""
    store = _store(snaps=6, seed=19)
    sr = ALL_SEMIRINGS["sssp"]
    windows = [(1, 2), (3, 4)]
    seq_run = run_window_slide(store, sr, 0, windows=windows, anchor=(0, 5))
    bat_run = run_window_slide_batched(store, sr, 0, windows=windows,
                                       anchor=(0, 5))
    for wnd in windows:
        np.testing.assert_array_equal(np.asarray(bat_run.results[wnd]),
                                      np.asarray(seq_run.results[wnd]))
    with pytest.raises(ValueError):  # anchor not a super-window of (1,2)
        run_window_slide_batched(store, sr, 0, windows=windows, anchor=(2, 5))


def test_window_slide_on_snapshot_mesh():
    """--shard --window-batch path: window lanes over a 1-D data mesh."""
    from repro.launch.mesh import make_snapshot_mesh
    store = _store(n=200, e=1400, snaps=5, changes=100, seed=29, granule=64)
    sr = ALL_SEMIRINGS["sssp"]
    bat = run_window_slide_batched(store, sr, 0, 2,
                                   mesh=make_snapshot_mesh())
    seq = run_window_slide(store, sr, 0, 2)
    for wnd in slide_windows(5, 2):
        np.testing.assert_array_equal(np.asarray(bat.results[wnd]),
                                      np.asarray(seq.results[wnd]))


_FORCED_MESH_SLIDE_SCRIPT = """
import warnings

import numpy as np
import jax

assert len(jax.devices()) == 4, jax.devices()

from repro.core import SnapshotStore, run_window_slide, \\
    run_window_slide_batched, slide_windows
from repro.graph import make_evolving_sequence
from repro.graph.semiring import ALL_SEMIRINGS
from repro.launch.mesh import make_snapshot_mesh

store = SnapshotStore(make_evolving_sequence(150, 900, 5, 120, seed=11),
                      granule=64)
sr = ALL_SEMIRINGS["sssp"]
mesh = make_snapshot_mesh()
assert mesh.shape["data"] == 4

windows = slide_windows(5, 3)
assert len(windows) == 3 and len(windows) % 4  # 3 lanes do not divide 4
seq_run = run_window_slide(store, sr, 0, 3, track_parents=True)
with warnings.catch_warnings(record=True) as caught:
    warnings.simplefilter("always")
    bat_run = run_window_slide_batched(store, sr, 0, 3, track_parents=True,
                                       mesh=mesh)
ours = [w for w in caught
        if issubclass(w.category, UserWarning) and "repro" in w.filename]
assert not ours, [str(w.message) for w in ours]
for wnd in windows:
    np.testing.assert_array_equal(np.asarray(bat_run.results[wnd]),
                                  np.asarray(seq_run.results[wnd]),
                                  err_msg=f"window {wnd}")
seq_work = sum(h.edge_work for h in seq_run.hop_stats)
bat_work = sum(h.edge_work for h in bat_run.hop_stats)
assert abs(seq_work - bat_work) < 1e-6, (seq_work, bat_work)
print("MESH-OK")
"""


def test_window_slide_shards_on_forced_multidevice_mesh(forced_cpu_mesh_run):
    """A 3-window slide on a real 4-device data mesh: the window-lane axis
    buckets to 4 (one masked lane), shards without any replicated-fallback
    warning, and stays bit-identical to the sequential slide with unchanged
    edge-work totals."""
    assert "MESH-OK" in forced_cpu_mesh_run(_FORCED_MESH_SLIDE_SCRIPT)


# -- SnapshotStore block-cache eviction ---------------------------------------

def _stack_arrays(blk):
    return [np.asarray(a).copy() for a in blk]


def test_store_lru_eviction_frees_delta_stacks():
    """A byte budget evicts least-recently-used blocks (delta_stack lane
    buffers included) and re-fetching rebuilds bit-identical arrays."""
    unbounded = _store(seed=7)
    first = _stack_arrays(unbounded.slide_stack(slide_windows(6, 2)))
    one_stack = _block_nbytes(unbounded.slide_stack(slide_windows(6, 2)))

    store = _store(seed=7, cache_bytes=one_stack)  # room for ~one stack
    blk = store.slide_stack(slide_windows(6, 2))
    for x, y in zip(first, _stack_arrays(blk)):
        np.testing.assert_array_equal(x, y)
    tag = next(t for t in store._blocks if t[0] == "DS")
    store.slide_stack(slide_windows(6, 3))   # pushes the budget over
    store.slide_stack(slide_windows(6, 4))
    assert store.evictions > 0
    assert tag not in store._blocks          # the width-2 stack was evicted
    # re-fetch rebuilds a bit-identical stack from the retained key arrays
    rebuilt = _stack_arrays(store.slide_stack(slide_windows(6, 2)))
    for x, y in zip(first, rebuilt):
        np.testing.assert_array_equal(x, y)


def test_store_lru_keeps_newest_block_even_over_budget():
    store = _store(seed=7, cache_bytes=1)    # absurdly tight budget
    blk = store.slide_stack(slide_windows(6, 2))
    assert len(store._blocks) == 1           # the block just built is kept
    again = store.slide_stack(slide_windows(6, 2))
    assert again is blk                      # and it is a cache hit


def test_store_explicit_release_by_family():
    store = _store(seed=7)
    store.window_block(0, 5)                         # "T" family
    store.delta_block((0, 5), (1, 2))                # "D" family
    store.slide_stack(slide_windows(6, 2))           # "DS" family
    before = store.cached_nbytes
    freed = store.release(("DS",))
    assert freed > 0
    assert store.cached_nbytes == before - freed
    assert all(t[0] != "DS" for t in store._blocks)
    assert any(t[0] == "T" for t in store._blocks)   # others stay warm
    assert any(t[0] == "D" for t in store._blocks)
    rest = store.release()                           # drop everything
    assert store.cached_nbytes == 0 and not store._blocks
    assert rest > 0


def test_cache_put_overwrite_subtracts_displaced_bytes():
    """Re-inserting an existing tag must displace the old entry's bytes:
    cached_nbytes always equals the sum over cached blocks, so the LRU
    budget never sees phantom bytes (which caused spurious evictions)."""
    store = _store(seed=7)

    def actual():
        return sum(_block_nbytes(b) for b in store._blocks.values())

    for _ in range(3):  # repeated put/release cycles
        store.window_block(0, 5)
        blk = store.slide_stack(slide_windows(6, 2))
        assert store.cached_nbytes == actual()
        # overwrite the same tag directly (the drift the LRU used to suffer)
        tag = next(t for t in store._blocks if t[0] == "DS")
        before = store.cached_nbytes
        store._cache_put(tag, blk)
        assert store.cached_nbytes == before == actual()
        store.release(("DS",))
        assert store.cached_nbytes == actual()
    store.release()
    assert store.cached_nbytes == 0


def test_window_slide_results_unchanged_under_eviction():
    """End-to-end: a memory-tight store (constant rebuilds) returns results
    bit-identical to an unbounded store's."""
    sr = ALL_SEMIRINGS["sssp"]
    free = _store(seed=13)
    tight = _store(seed=13, cache_bytes=64 * 1024)
    for width in (2, 3):
        a = run_window_slide_batched(free, sr, 0, width)
        b = run_window_slide_batched(tight, sr, 0, width)
        for wnd in a.results:
            np.testing.assert_array_equal(np.asarray(a.results[wnd]),
                                          np.asarray(b.results[wnd]))
    assert tight.evictions > 0

"""Stable-vertex analysis contract (graph/stability.py).

The exactness property every executor's instability seeding rests on:
for each registered semiring, seeding from the pruned instability
frontier is BIT-IDENTICAL to full-Δ seeding — values, parents,
iterations and the instability counts all agree; only the
frontier-masked ``edge_work`` drops (strictly, whenever some Δ edge
leaves an unreached vertex). Property-checked here across the single
and batched engine paths, the TG plan executors, the window
slide/stream executors and the query service, plus unit coverage of
``seed_mask`` / ``stable_fraction_milli`` and mode validation.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    QueryService,
    SnapshotStore,
    optimal_plan,
    run_plan,
    run_plan_batched,
    run_window_slide,
    run_window_stream_batched,
)
from repro.graph import (
    incremental_additions,
    incremental_additions_batched,
    make_evolving_sequence,
    run_to_fixpoint,
    seed_mask,
    seed_state,
    stable_fraction_milli,
)
from repro.graph.engine import gather_lane_states
from repro.graph.semiring import ALL_SEMIRINGS, BFS, SSSP

SEMIRINGS = sorted(ALL_SEMIRINGS)


def _store(n=250, e=1800, snaps=6, changes=120, seed=7):
    return SnapshotStore(make_evolving_sequence(n, e, snaps, changes,
                                                seed=seed))


def _hop_inputs(store, semiring, source=0):
    """Anchor state + one real slide hop off it (the generic Δ seeding)."""
    anchor = (0, store.seq.num_snapshots - 1)
    view = store.common_graph_view(*anchor)
    base = run_to_fixpoint(view, semiring, source, track_parents=True)
    wnd = (0, 1)
    delta = store.slide_block(wnd, anchor)
    return view.extended(delta), delta, base


# -- the exactness property, engine paths -------------------------------------

@pytest.mark.parametrize("name", SEMIRINGS)
def test_incremental_bit_identical_across_seed_modes(name):
    semiring = ALL_SEMIRINGS[name]
    store = _store()
    view, delta, base = _hop_inputs(store, semiring)
    inst = incremental_additions(view, delta, semiring, base.values,
                                 base.parent, track_parents=True)
    full = incremental_additions(view, delta, semiring, base.values,
                                 base.parent, track_parents=True,
                                 seed="delta")
    assert jnp.array_equal(inst.values, full.values)
    assert jnp.array_equal(inst.parent, full.parent)
    assert int(inst.iterations) == int(full.iterations)
    assert int(inst.unstable) == int(full.unstable)
    # pruning can only remove seed work, never add it
    assert float(inst.edge_work) <= float(full.edge_work)


@pytest.mark.parametrize("name", SEMIRINGS)
def test_instability_seeding_strictly_cheaper_with_unreached(name):
    # a source-0 query on a loose RMAT graph leaves vertices unreached, so
    # some Δ edges have inert sources and the masked seed sweep must win
    semiring = ALL_SEMIRINGS[name]
    store = _store(n=400, e=1600, seed=0)
    view, delta, base = _hop_inputs(store, semiring)
    assert not bool(jnp.all(seed_mask(semiring, base.values)))
    inst = incremental_additions(view, delta, semiring, base.values,
                                 base.parent, track_parents=True)
    full = incremental_additions(view, delta, semiring, base.values,
                                 base.parent, track_parents=True,
                                 seed="delta")
    assert jnp.array_equal(inst.values, full.values)
    assert float(inst.edge_work) < float(full.edge_work)


@pytest.mark.parametrize("name", SEMIRINGS)
def test_batched_incremental_bit_identical_across_seed_modes(name):
    semiring = ALL_SEMIRINGS[name]
    store = _store()
    anchor = (0, store.seq.num_snapshots - 1)
    view = store.common_graph_view(*anchor)
    base = run_to_fixpoint(view, semiring, 0, track_parents=True)
    windows = [(0, 1), (1, 2), (2, 3), (3, 4)]
    stacked = store.slide_stack(windows, anchor, num_lanes=4)
    values, parent = gather_lane_states(base.values[None], base.parent[None],
                                        [0] * 4)
    kwargs = dict(shared_blocks=tuple(view.blocks), delta_blocks=(stacked,),
                  track_parents=True, seed_blocks=(stacked,))
    inst = incremental_additions_batched(store.num_nodes, semiring, values,
                                         parent, **kwargs)
    full = incremental_additions_batched(store.num_nodes, semiring, values,
                                         parent, seed="delta", **kwargs)
    assert jnp.array_equal(inst.values, full.values)
    assert jnp.array_equal(inst.parent, full.parent)
    assert jnp.array_equal(inst.unstable, full.unstable)
    assert float(jnp.sum(inst.edge_work)) <= float(jnp.sum(full.edge_work))


# -- the exactness property, executor paths -----------------------------------

@pytest.mark.parametrize("name", SEMIRINGS)
def test_trigrid_executors_bit_identical_across_seed_modes(name):
    semiring = ALL_SEMIRINGS[name]
    store = _store()
    plan = optimal_plan(store)
    inst = run_plan_batched(store, plan, semiring, 0)
    full = run_plan_batched(store, plan, semiring, 0, seed="delta")
    seq = run_plan(store, plan, semiring, 0, seed="delta")
    for k in inst.results:
        assert jnp.array_equal(inst.results[k], full.results[k])
        assert jnp.array_equal(inst.results[k], seq.results[k])
    assert inst.stable_milli == full.stable_milli == seq.stable_milli > 0
    inst_work = sum(h.edge_work for h in inst.hop_stats)
    full_work = sum(h.edge_work for h in full.hop_stats)
    assert inst_work <= full_work


@pytest.mark.parametrize("name", SEMIRINGS)
def test_window_stream_bit_identical_across_seed_modes(name):
    semiring = ALL_SEMIRINGS[name]
    store = _store()
    inst = run_window_stream_batched(store, semiring, 0, 3,
                                     campaign_width="auto")
    store.release(("AS",))
    full = run_window_stream_batched(store, semiring, 0, 3,
                                     campaign_width="auto", seed="delta")
    seq = run_window_slide(store, semiring, 0, 3, seed="delta")
    for w in inst.results:
        assert jnp.array_equal(inst.results[w], full.results[w])
        assert jnp.array_equal(inst.results[w], seq.results[w])
    assert inst.stable_milli == full.stable_milli > 0
    assert inst.campaigns == full.campaigns  # seeding never moves the cuts


def test_service_bit_identical_across_seed_modes():
    store = _store()

    def serve(seed):
        store.release(("AS",))
        svc = QueryService(store, lane_budget=8, seed=seed)
        c1 = svc.register(SSSP, 0, campaign_width=3)
        c2 = svc.register(BFS, 5, campaign_width=2)
        svc.submit(c1, [(0, 2), (1, 3), (2, 4), (3, 5)])
        svc.submit(c2, [(0, 3), (1, 4), (2, 5)])
        metrics = svc.drain()
        svc.unregister(c1)
        svc.unregister(c2)
        return (c1, c2), metrics

    (a1, a2), inst = serve("instability")
    (b1, b2), full = serve("delta")
    for got, want in ((a1, b1), (a2, b2)):
        assert got.results.keys() == want.results.keys()
        for w in got.results:
            assert jnp.array_equal(got.results[w], want.results[w])
    # launch composition and stability accounting are seed-mode invariant
    assert (inst.launches, inst.lanes, inst.completed) == \
        (full.launches, full.lanes, full.completed)
    assert inst.stable_fraction_milli == full.stable_fraction_milli > 0
    assert inst.edge_work <= full.edge_work


# -- unit surface -------------------------------------------------------------

def test_seed_mask_marks_reached_vertices():
    values = jnp.float32([SSSP.identity, 0.0, 3.5, SSSP.identity])
    assert seed_mask(SSSP, values).tolist() == [False, True, True, False]


def test_seed_state_rejects_unknown_mode():
    store = _store()
    view, delta, base = _hop_inputs(store, SSSP)
    with pytest.raises(ValueError, match="unknown seed mode"):
        seed_state(SSSP, store.num_nodes, base.values, base.parent, (delta,),
                   mode="everything")


def test_seed_state_unstable_counts_frontier():
    store = _store()
    _view, delta, base = _hop_inputs(store, SSSP)
    seeded = seed_state(SSSP, store.num_nodes, base.values, base.parent,
                        (delta,))
    assert int(seeded.unstable) == int(jnp.sum(seeded.frontier))
    full = seed_state(SSSP, store.num_nodes, base.values, base.parent,
                      (delta,), mode="delta")
    assert jnp.array_equal(seeded.frontier, full.frontier)
    assert jnp.array_equal(seeded.values, full.values)


def test_stable_fraction_milli_aggregation():
    # 2 lanes of 100 vertices, 10 + 40 unstable -> 150/200 stable = 750‰
    assert stable_fraction_milli([10, 40], 100) == 750
    assert stable_fraction_milli([0, 0], 100) == 1000
    assert stable_fraction_milli([100], 100) == 0
    assert stable_fraction_milli([], 100) == 0           # no lanes
    assert stable_fraction_milli([5], 0) == 0            # degenerate
    # padding lanes excluded via lane_valid
    assert stable_fraction_milli([10, 40, 0, 0], 100,
                                 lane_valid=[1, 1, 0, 0]) == 750
    # accepts device arrays and nested sequences
    assert stable_fraction_milli(jnp.int32([10, 40]), 100) == 750
    assert stable_fraction_milli([np.int32(10), np.int32(40)], 100) == 750

"""Streaming-campaign scheduler + anchor-state cache contract.

Covers the core/window.py stream contract (bit-identical to cold
per-campaign slides while performing strictly fewer anchor rebuilds) and
the SnapshotStore "AS" family guarantees (LRU participation with exact
byte accounting across overwrites, eviction mid-stream forcing a rebuild
that is bit-identical, explicit release, tightest-cover selection).
"""

import numpy as np
import pytest

from repro.core import (
    SnapshotStore,
    WindowStream,
    run_window_slide_batched,
    run_window_stream_batched,
    slide_windows,
    stream_campaigns,
)
from repro.core.snapshots import _block_nbytes
from repro.core.window import _stream_qkey
from repro.graph import QueryState, make_evolving_sequence
from repro.graph.semiring import ALL_SEMIRINGS


def _store(n=300, e=2400, snaps=8, changes=150, seed=11, granule=128, **kw):
    return SnapshotStore(make_evolving_sequence(n, e, snaps, changes,
                                                seed=seed),
                         granule=granule, **kw)


def _qkey(sr, track_parents=False):
    return _stream_qkey(sr, 0, 10_000, False, 1, track_parents)


# -- stream plan construction -------------------------------------------------

def test_stream_campaigns_partition():
    windows = slide_windows(8, 3)  # 6 windows
    assert stream_campaigns(windows, 2) == [windows[0:2], windows[2:4],
                                            windows[4:6]]
    assert stream_campaigns(windows, 4) == [windows[0:4], windows[4:6]]
    assert stream_campaigns(windows, 10) == [windows]
    with pytest.raises(ValueError):
        stream_campaigns(windows, 0)


def test_window_stream_object_buffers_and_drains():
    ws = WindowStream(campaign_width=2)
    ws.extend([(0, 2), (1, 3)])
    assert ws.pending() == [(0, 2), (1, 3)]
    assert ws.take() == [(0, 2), (1, 3)]
    assert ws.pending() == []
    ws.extend([(2, 4), (3, 5)])          # advancing past the drained tail
    assert ws.pending() == [(2, 4), (3, 5)]
    with pytest.raises(ValueError):       # steps backwards from (3, 5)
        ws.extend([(1, 4)])
    with pytest.raises(ValueError):
        WindowStream(campaign_width=0)
    with pytest.raises(ValueError):
        WindowStream(campaign_width=2, windows=[(2, 4), (0, 3)])


def test_window_stream_rejects_conflicting_inputs():
    store = _store(snaps=4)
    sr = ALL_SEMIRINGS["sssp"]
    with pytest.raises(ValueError):
        run_window_stream_batched(store, sr, 0)  # no width/windows/stream
    with pytest.raises(ValueError):
        run_window_stream_batched(store, sr, 0, 2,
                                  stream=WindowStream(campaign_width=1))
    with pytest.raises(ValueError):  # the stream carries its own width
        run_window_stream_batched(store, sr, 0, campaign_width=8,
                                  stream=WindowStream(campaign_width=1))
    with pytest.raises(ValueError):  # non-advancing explicit windows
        run_window_stream_batched(store, sr, 0, windows=[(2, 4), (0, 3)])


def test_window_stream_take_next_bounded_draw():
    """take_next consumes at most ``count`` windows in order — the query
    service's bounded per-turn draw — and composes with take()."""
    ws = WindowStream(campaign_width=2,
                      windows=[(0, 2), (1, 3), (2, 4), (3, 5)])
    assert ws.take_next(0) == []
    assert ws.take_next(2) == [(0, 2), (1, 3)]
    assert ws.pending() == [(2, 4), (3, 5)]
    assert ws.take_next(5) == [(2, 4), (3, 5)]   # clamps at the buffer end
    assert ws.take_next(1) == []
    ws.extend([(4, 6)])
    assert ws.take() == [(4, 6)]                 # drain-all still works


def test_window_stream_empty_pending_is_noop():
    store = _store(snaps=4)
    sr = ALL_SEMIRINGS["sssp"]
    run = run_window_stream_batched(store, sr, 0,
                                    stream=WindowStream(campaign_width=2))
    assert run.results == {} and run.campaigns == []
    assert run.anchor_rebuilds == 0


# -- bit-identity vs cold campaigns + strictly fewer rebuilds -----------------

@pytest.mark.parametrize("alg", ["sssp", "sswp"])
@pytest.mark.parametrize("track_parents", [False, True])
def test_window_stream_identical_to_cold_campaigns(alg, track_parents):
    """The acceptance criterion: streamed values == cold per-campaign
    values bit-for-bit, with 1 rebuild + K-1 hops vs K cold rebuilds."""
    sr = ALL_SEMIRINGS[alg]
    store = _store()
    run = run_window_stream_batched(store, sr, 0, 3, campaign_width=2,
                                    track_parents=track_parents)
    assert len(run.campaigns) == 3
    assert run.anchor_events == ["rebuild", "hop", "hop"]
    assert run.anchor_rebuilds == 1 < len(run.campaigns)
    assert run.anchor_hops == len(run.campaigns) - 1

    cold_store = _store()  # fresh: the cold path shares nothing
    for campaign, anchor in zip(run.campaigns, run.anchors):
        cold = run_window_slide_batched(cold_store, sr, 0, windows=campaign,
                                        anchor=anchor,
                                        track_parents=track_parents)
        for wnd in campaign:
            np.testing.assert_array_equal(
                np.asarray(run.results[wnd]), np.asarray(cold.results[wnd]),
                err_msg=f"{alg}/window {wnd}: stream != cold campaign")


def test_window_stream_campaign_launch_work_parity():
    """Given the same anchor state, a campaign's stacked launch performs
    exactly the cold launch's edge work (anchor savings are the ONLY
    difference between the paths)."""
    sr = ALL_SEMIRINGS["sssp"]
    store = _store(seed=5)
    run = run_window_stream_batched(store, sr, 0, 2, campaign_width=2)
    cold_store = _store(seed=5)
    for campaign, anchor, hop in zip(run.campaigns, run.anchors,
                                     run.hop_stats):
        cold = run_window_slide_batched(cold_store, sr, 0, windows=campaign,
                                        anchor=anchor)
        cold_work = sum(s.edge_work for s in cold.hop_stats)
        assert hop.edge_work == pytest.approx(cold_work)


def test_window_stream_matches_plain_slide_values():
    """Different anchors per campaign, same unique fixpoint: stream values
    equal the one-anchor batched slide's bit-for-bit."""
    sr = ALL_SEMIRINGS["sssp"]
    store = _store(seed=23)
    slide = run_window_slide_batched(store, sr, 0, 3)
    stream = run_window_stream_batched(store, sr, 0, 3, campaign_width=2)
    assert list(stream.results) == list(slide.results)
    for wnd in slide.results:
        np.testing.assert_array_equal(np.asarray(stream.results[wnd]),
                                      np.asarray(slide.results[wnd]))


def test_window_stream_cg_split_hops_stay_identical():
    """cg_split > 1 splits the anchor view on every acquisition path
    (rebuild, hop, hit) — block partitioning never changes values."""
    sr = ALL_SEMIRINGS["sssp"]
    plain = run_window_stream_batched(_store(seed=31), sr, 0, 3,
                                      campaign_width=2)
    split = run_window_stream_batched(_store(seed=31), sr, 0, 3,
                                      campaign_width=2, cg_split=3)
    assert split.anchor_events == plain.anchor_events
    for wnd in plain.results:
        np.testing.assert_array_equal(np.asarray(split.results[wnd]),
                                      np.asarray(plain.results[wnd]))


def test_window_stream_back_to_back_hits_memory():
    """Re-running the same campaigns must be pure cache hits: zero anchor
    rebuilds, zero hops, identical values."""
    sr = ALL_SEMIRINGS["sssp"]
    store = _store()
    first = run_window_stream_batched(store, sr, 0, 3, campaign_width=2)
    again = run_window_stream_batched(store, sr, 0, 3, campaign_width=2)
    assert again.anchor_events == ["hit"] * len(first.campaigns)
    assert again.anchor_rebuilds == 0 and again.anchor_hops == 0
    for wnd in first.results:
        np.testing.assert_array_equal(np.asarray(again.results[wnd]),
                                      np.asarray(first.results[wnd]))


def test_window_stream_advancing_calls_rebuild_only_on_extension():
    """A later call whose stream extends past every cached anchor pays ONE
    rebuild (the soundness boundary: a wider stream's anchor is not
    reachable from a narrower one's by additions), then hops again."""
    sr = ALL_SEMIRINGS["sssp"]
    store = _store(snaps=8)
    ws = WindowStream(campaign_width=2)
    ws.extend(slide_windows(8, 3)[:4])          # windows up to (3, 5)
    first = run_window_stream_batched(store, sr, 0, stream=ws)
    assert first.anchor_events == ["rebuild", "hop"]
    ws.extend(slide_windows(8, 3)[4:])          # arrivals extend to (5, 7)
    second = run_window_stream_batched(store, sr, 0, stream=ws)
    assert second.anchor_events[0] == "rebuild"  # hi advanced: no cover
    assert set(second.anchor_events[1:]) <= {"hop", "hit"}
    # every window still bit-identical to a cold campaign run
    cold_store = _store(snaps=8)
    for run in (first, second):
        for campaign, anchor in zip(run.campaigns, run.anchors):
            cold = run_window_slide_batched(cold_store, sr, 0,
                                            windows=campaign, anchor=anchor)
            for wnd in campaign:
                np.testing.assert_array_equal(
                    np.asarray(run.results[wnd]),
                    np.asarray(cold.results[wnd]))


def test_window_stream_on_snapshot_mesh():
    """--shard --stream path: campaign lanes over a 1-D data mesh."""
    from repro.launch.mesh import make_snapshot_mesh
    store = _store(n=200, e=1400, snaps=5, changes=100, seed=29, granule=64)
    sr = ALL_SEMIRINGS["sssp"]
    meshed = run_window_stream_batched(store, sr, 0, 2, campaign_width=2,
                                       mesh=make_snapshot_mesh())
    plain = run_window_stream_batched(_store(n=200, e=1400, snaps=5,
                                             changes=100, seed=29,
                                             granule=64),
                                      sr, 0, 2, campaign_width=2)
    for wnd in plain.results:
        np.testing.assert_array_equal(np.asarray(meshed.results[wnd]),
                                      np.asarray(plain.results[wnd]))


# -- anchor-state cache: LRU interplay ----------------------------------------

def test_anchor_state_cache_roundtrip_and_cover():
    sr = ALL_SEMIRINGS["sssp"]
    store = _store()
    run = run_window_stream_batched(store, sr, 0, 3, campaign_width=2)
    qkey = _qkey(sr)
    for anchor in run.anchors:
        state = store.anchor_state_get(qkey, anchor)
        assert isinstance(state, QueryState)
    # cover for a narrower interval picks the TIGHTEST cached super-window
    lo = max(a for a, _ in run.anchors)
    hi = run.anchors[0][1]
    cover_window, state = store.anchor_state_cover(qkey, (lo + 1, hi))
    assert cover_window == (lo, hi)          # tightest, not the widest
    assert isinstance(state, QueryState)
    assert store.anchor_state_cover(qkey, (0, hi)) is None  # nothing covers
    # a different query key shares nothing
    assert store.anchor_state_get(_qkey(ALL_SEMIRINGS["sswp"]),
                                  run.anchors[0]) is None


def test_anchor_state_eviction_mid_stream_forces_identical_rebuild():
    """A memory-tight store evicts cached anchor states between campaigns;
    the scheduler rebuilds (strictly more rebuilds than unbounded) and the
    results stay bit-identical."""
    sr = ALL_SEMIRINGS["sssp"]
    free = _store(seed=13)
    tight = _store(seed=13, cache_bytes=8 * 1024)
    a = run_window_stream_batched(free, sr, 0, 3, campaign_width=1)
    b = run_window_stream_batched(tight, sr, 0, 3, campaign_width=1)
    assert tight.evictions > 0
    assert a.anchor_rebuilds == 1
    assert b.anchor_rebuilds > a.anchor_rebuilds   # eviction cost = rebuilds
    for wnd in a.results:
        np.testing.assert_array_equal(np.asarray(a.results[wnd]),
                                      np.asarray(b.results[wnd]))


def test_anchor_state_lru_accounting_across_overwrites():
    """cached_nbytes must equal the exact sum over cached entries while
    anchor-state tags are inserted, overwritten and released."""
    sr = ALL_SEMIRINGS["sssp"]
    store = _store(seed=7)
    qkey = _qkey(sr)

    def actual():
        return sum(_block_nbytes(b) for b in store._blocks.values())

    for _ in range(3):
        run = run_window_stream_batched(store, sr, 0, 3, campaign_width=2)
        assert store.cached_nbytes == actual()
        anchor = run.anchors[0]
        state = store.anchor_state_get(qkey, anchor)
        before = store.cached_nbytes
        # overwrite the same AS tag: displaced bytes must be subtracted
        store.anchor_state_put(qkey, anchor, state)
        assert store.cached_nbytes == before == actual()
        freed = store.release(("AS",))
        assert freed > 0
        assert store.cached_nbytes == actual()
        assert all(t[0] != "AS" for t in store._blocks)
    store.release()
    assert store.cached_nbytes == 0


def test_release_AS_leaves_blocks_warm():
    sr = ALL_SEMIRINGS["sssp"]
    store = _store(seed=7)
    run_window_stream_batched(store, sr, 0, 2, campaign_width=2)
    assert any(t[0] == "AS" for t in store._blocks)
    assert any(t[0] == "DS" for t in store._blocks)
    store.release(("AS",))
    assert not any(t[0] == "AS" for t in store._blocks)
    assert any(t[0] == "DS" for t in store._blocks)  # stacks stay warm


# -- compaction vs pinned anchor states (live ingestion audit) ----------------

def _live_store(n=240, e=1800, snaps=8, changes=120, seed=11):
    """A store whose snapshots were born from a replayed firehose —
    compaction (core/ingest.py) only operates on live stores."""
    from repro.core import (EdgeLog, IngestMetrics, LiveSequence, Watermark,
                            events_from_sequence, replay_events)
    seq = make_evolving_sequence(n, e, snaps, changes, seed=seed)
    store = SnapshotStore(LiveSequence(seq.num_nodes,
                                       weight_seed=seq.weight_seed))
    log = EdgeLog(seq.num_nodes, metrics=IngestMetrics())
    replay_events(log, Watermark(log, store), events_from_sequence(seq))
    return store


def test_compact_never_retires_pinned_anchor_window():
    """The audit: compaction must clamp its horizon to every pinned "AS"
    link's window low — a pinned anchor state is a promise some stream
    will hop from it, and the hop needs that window's intersection."""
    from repro.core.snapshots import anchor_tag
    store = _live_store()
    qkey = _qkey(ALL_SEMIRINGS["sssp"])
    store.pin(anchor_tag(qkey, (2, 7)))
    stats = store.compact()              # wants 7; the pin clamps to 2
    assert stats.horizon == 2 and stats.retired == 2
    assert store.first_live == 2
    store.window_keys(2, 7)              # the pinned window still serves
    store.unpin(anchor_tag(qkey, (2, 7)))
    assert store.compact().retired == 5  # unpinned: the clamp lifts


def test_compact_clamps_to_anchor_chain_pins_of_lagging_stream():
    """End-to-end: an AnchorChain pins the links its registered streams
    are still behind; compaction respects them until the laggard advances
    (or unregisters), then retires — and the pinned anchor's state block
    survives the purge."""
    from repro.core import AnchorChain
    sr = ALL_SEMIRINGS["sssp"]
    store = _live_store()
    chain = AnchorChain(store, name="shared")
    chain.register("laggard")            # behind everything: pins every link
    lead = WindowStream(campaign_width=2, name="lead",
                        windows=slide_windows(8, 3))
    run_window_stream_batched(store, sr, 0, stream=lead, chain=chain)
    lows = sorted(w[0] for w in chain.links)
    assert len(lows) > 1
    assert store.compact().horizon == lows[0]   # laggard keeps everything
    pinned_tags = store.pinned_tags()
    assert pinned_tags and all(tag in store._blocks for tag in pinned_tags)
    chain.advance("laggard", chain.links[-1])   # at the newest link now
    stats = store.compact()
    assert stats.horizon == lows[-1] > lows[0]  # only that link clamps
    store.window_keys(lows[-1], store.seq.num_snapshots - 1)
    chain.unregister("laggard")
    chain.unregister("lead")
    assert store.compact().horizon == store.seq.num_snapshots - 1

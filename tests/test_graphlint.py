"""graphlint fixture corpus + self-check (src/repro/analysis).

Each rule gets a paired bad/good fixture: the bad snippet must trigger
exactly its rule (no cross-rule noise), the good snippet must be clean
under ALL rules. Fixtures are written into a tmp mini-repo (pyproject
marker + src/repro layout + docs/API.md) so root detection, dotted-name
derivation and the G006 doc lookup run exactly as they do on the real
tree — which the self-check at the bottom then asserts is clean.
"""

from __future__ import annotations

import json
import pathlib
import subprocess
import sys

import pytest

from repro.analysis import Linter, all_rules, get_rule
from repro.analysis.linter import Module, render_json

REPO = pathlib.Path(__file__).resolve().parent.parent

API_DOC = """# API reference

## `repro.core.documented`

### `covered(x)`
Documented and docstringed.
"""


def make_repo(tmp_path: pathlib.Path) -> pathlib.Path:
    """A minimal rooted repo skeleton fixtures are dropped into."""
    (tmp_path / "pyproject.toml").write_text("[project]\nname='fix'\n")
    (tmp_path / "docs").mkdir()
    (tmp_path / "docs" / "API.md").write_text(API_DOC)
    (tmp_path / "src" / "repro").mkdir(parents=True)
    return tmp_path


def lint_snippet(tmp_path, code, relpath="src/repro/mod.py", rules=None):
    root = make_repo(tmp_path)
    target = root / relpath
    target.parent.mkdir(parents=True, exist_ok=True)
    target.write_text(code)
    linter = Linter(rules=rules, root=root)
    return linter.lint([target])


def assert_only_rule(findings, rule_id, count=None):
    """The bad fixture discipline: found, and nothing but this rule."""
    assert findings, f"expected {rule_id} findings, got none"
    assert {f.rule for f in findings} == {rule_id}, findings
    if count is not None:
        assert len(findings) == count, findings


# -- G001: pallas_call location ----------------------------------------------

BAD_G001 = """\
import jax.experimental.pallas as pl

def sneaky(x):
    return pl.pallas_call(lambda ref: ref, out_shape=x)(x)
"""

GOOD_G001 = BAD_G001  # same code is legal inside kernels/


def test_g001_bad(tmp_path):
    findings = lint_snippet(tmp_path, BAD_G001,
                            relpath="src/repro/core/sneaky.py")
    assert_only_rule(findings, "G001", count=1)


def test_g001_good_inside_kernels(tmp_path):
    assert lint_snippet(tmp_path, GOOD_G001,
                        relpath="src/repro/kernels/fine.py") == []


def test_g001_flags_import_too(tmp_path):
    code = "from jax.experimental.pallas import pallas_call\n"
    findings = lint_snippet(tmp_path, code,
                            relpath="src/repro/launch/bad_import.py")
    assert_only_rule(findings, "G001", count=1)


# -- G002: lane_bucket discipline --------------------------------------------

BAD_G002 = """\
from repro.graph.edgeset import stack_delta_blocks

def stack_raw(lanes, n):
    return stack_delta_blocks(lanes, n, num_lanes=7)

def stack_unbucketed(lanes, n):
    k = len(lanes)
    return stack_delta_blocks(lanes, n, num_lanes=k)

def launch_unbucketed(view, state, stacked):
    from repro.graph.engine import incremental_additions_batched
    return incremental_additions_batched(view, state, stacked)
"""

GOOD_G002 = """\
from repro.graph.edgeset import lane_bucket, stack_delta_blocks
from repro.graph.engine import incremental_additions_batched

def stack_bucketed(lanes, n, extent):
    bucket = lane_bucket(len(lanes), extent)
    return stack_delta_blocks(lanes, n, num_lanes=bucket)

def stack_inline(lanes, n, extent):
    return stack_delta_blocks(lanes, n,
                              num_lanes=lane_bucket(len(lanes), extent))

def forwarding_wrapper(lanes, n, num_lanes=None):
    # pass-through: the caller owns the bucketing obligation
    return stack_delta_blocks(lanes, n, num_lanes=num_lanes)

def launch_bucketed(view, state, lanes, extent):
    bucket = lane_bucket(len(lanes), extent)
    def inner(stacked):
        return incremental_additions_batched(view, state, stacked)
    return inner, bucket
"""


def test_g002_bad(tmp_path):
    findings = lint_snippet(tmp_path, BAD_G002)
    assert_only_rule(findings, "G002", count=3)


def test_g002_good(tmp_path):
    assert lint_snippet(tmp_path, GOOD_G002) == []


def test_g002_missing_num_lanes(tmp_path):
    code = ("from repro.graph.edgeset import stack_delta_blocks\n"
            "def f(lanes, n):\n"
            "    return stack_delta_blocks(lanes, n)\n")
    findings = lint_snippet(tmp_path, code)
    assert_only_rule(findings, "G002", count=1)
    assert "without num_lanes" in findings[0].message


# -- G003: canonical cache tags ----------------------------------------------

BAD_G003 = """\
def hold(store, qkey, link):
    store.pin(("AS", qkey, link))

def peek(store, key):
    return store._cache_get(("T", 0, 3))
"""

GOOD_G003 = """\
from repro.core.snapshots import anchor_tag

def hold(store, qkey, link):
    store.pin(anchor_tag(qkey, link))

def stacked(store, hops, num_lanes):
    return store.delta_stack(hops, num_lanes=num_lanes)
"""


def test_g003_bad(tmp_path):
    findings = lint_snippet(tmp_path, BAD_G003)
    assert_only_rule(findings, "G003", count=2)


def test_g003_good(tmp_path):
    assert lint_snippet(tmp_path, GOOD_G003) == []


def test_g003_exempts_canonical_module(tmp_path):
    code = ("class SnapshotStore:\n"
            "    '''The canonical tag module.'''\n"
            "    def anchor_state_get(self, qkey, window):\n"
            "        '''doc'''\n"
            "        return self._cache_get(('AS', qkey, tuple(window)))\n")
    assert lint_snippet(tmp_path, code,
                        rules=[get_rule("G003")]) == []


# -- G004: host-sync discipline ----------------------------------------------

BAD_G004_JIT = """\
import functools
import jax
import numpy as np

@functools.partial(jax.jit, static_argnums=(0,))
def hot(n, values):
    host = np.asarray(values)
    values.block_until_ready()
    return values
"""

BAD_G004_BARE = """\
def time_things(values):
    values.block_until_ready()
    return values
"""

GOOD_G004 = """\
import jax
import numpy as np
from repro.graph.engine import host_sync

def relax_sweep(view, values):
    return values + 1

def timed_driver(values):
    host_sync(values)
    return values

def host_side_report(result):
    # not reachable from any jitted function: np.asarray is fine
    return np.asarray(result)
"""


def test_g004_inside_jit(tmp_path):
    findings = lint_snippet(tmp_path, BAD_G004_JIT)
    assert_only_rule(findings, "G004", count=2)
    assert any("jitted" in f.message for f in findings)


def test_g004_bare_sync(tmp_path):
    findings = lint_snippet(tmp_path, BAD_G004_BARE)
    assert_only_rule(findings, "G004", count=1)
    assert "host_sync" in findings[0].message


def test_g004_good(tmp_path):
    assert lint_snippet(tmp_path, GOOD_G004) == []


def test_g004_benchmarks_allowlisted(tmp_path):
    assert lint_snippet(tmp_path, BAD_G004_BARE,
                        relpath="benchmarks/bench_thing.py") == []


def test_g004_hot_path_closure(tmp_path):
    # relax_sweep -> helper chain: a sync two calls away is still hot.
    code = ("def helper(values):\n"
            "    return values.item()\n"
            "def middle(values):\n"
            "    return helper(values)\n"
            "def relax_sweep(view, values):\n"
            "    return middle(values)\n")
    findings = lint_snippet(tmp_path, code)
    assert_only_rule(findings, "G004", count=1)


def test_g004_jit_wrapped_lambda(tmp_path):
    code = ("import jax\n"
            "import numpy as np\n"
            "def make(cfg):\n"
            "    return jax.jit(lambda v: np.asarray(v))\n")
    findings = lint_snippet(tmp_path, code)
    assert_only_rule(findings, "G004", count=1)


# -- G005: semiring contract surface -----------------------------------------

BAD_G005 = """\
from repro.graph.semiring import Semiring

GOOD = Semiring(name="bfs", reduce="min", identity=1.0,
                source_value=0.0, combine="add")
PARTIAL = Semiring(name="oops", reduce="min")
SOFTMIN = Semiring(name="soft", reduce="softmin", identity=0.0,
                   source_value=0.0, combine="add")

ALL_SEMIRINGS = {s.name: s for s in (GOOD, PARTIAL)}
"""

GOOD_G005 = """\
from repro.graph.semiring import Semiring

BFS = Semiring(name="bfs", reduce="min", identity=1.0,
               source_value=0.0, combine="add_unit")
SSSP = Semiring(name="sssp", reduce="min", identity=1.0,
                source_value=0.0, combine="add")
SSWP = Semiring(name="sswp", reduce="max", identity=0.0,
                source_value=1.0, combine="min")
SSNP = Semiring(name="ssnp", reduce="min", identity=1.0,
                source_value=0.0, combine="max")
VITERBI = Semiring(name="viterbi", reduce="max", identity=0.0,
                   source_value=1.0, combine="mul")

ALL_SEMIRINGS = {s.name: s for s in (BFS, SSSP, SSWP, SSNP, VITERBI)}
"""


def test_g005_bad(tmp_path):
    findings = lint_snippet(tmp_path, BAD_G005)
    # PARTIAL misses fields, SOFTMIN has a non-literal-min/max reduce AND
    # is unregistered — three findings, all G005.
    assert_only_rule(findings, "G005", count=3)
    messages = " | ".join(f.message for f in findings)
    assert "missing required field" in messages
    assert '"min" or "max"' in messages
    assert "ALL_SEMIRINGS" in messages


def test_g005_good(tmp_path):
    assert lint_snippet(tmp_path, GOOD_G005) == []


# -- G006: API.md coverage + docstrings --------------------------------------

BAD_G006 = """\
def covered(x):
    return x

def newcomer(x):
    '''Docstringed but absent from API.md.'''
    return x
"""

GOOD_G006 = """\
def covered(x):
    '''Documented and docstringed.'''
    return x

def _helper(x):
    return x
"""


def test_g006_bad(tmp_path):
    findings = lint_snippet(tmp_path, BAD_G006,
                            relpath="src/repro/core/documented.py")
    # covered() lacks a docstring; newcomer() lacks an API.md entry.
    assert_only_rule(findings, "G006", count=2)
    messages = " | ".join(f.message for f in findings)
    assert "no docstring" in messages
    assert "undocumented" in messages


def test_g006_good(tmp_path):
    assert lint_snippet(tmp_path, GOOD_G006,
                        relpath="src/repro/core/documented.py") == []


def test_g006_stale_entry_flagged_in_api_md(tmp_path):
    # Module exists but no longer defines covered(): the stale entry is
    # reported against docs/API.md, not the source file.
    findings = lint_snippet(tmp_path, "def other(x):\n    '''doc'''\n",
                            relpath="src/repro/core/documented.py")
    g006 = [f for f in findings if f.rule == "G006"]
    stale = [f for f in g006 if "stale" in f.message]
    assert stale and stale[0].path == "docs/API.md"


def test_g006_out_of_scope_module_skipped(tmp_path):
    # No API.md section for repro.mod: the docstring gate does not apply.
    assert lint_snippet(tmp_path, "def undocumented(x):\n    return x\n",
                        rules=[get_rule("G006")]) == []


# -- G007: service sync boundary ---------------------------------------------

BAD_G007 = """\
from repro.graph.engine import host_sync

def schedule_turn(service, pending):
    for query in pending:
        res = service.launch_one(query)
        host_sync(res.values)
        service.latencies.append(res.wall)
    return service

def account(results):
    return [r.edge_work.item() for r in results]
"""

GOOD_G007 = """\
from repro.graph.engine import host_sync

def _packed_launch(store, windows, states):
    '''One batched launch; the campaign-boundary sync lives here.'''
    res = store.run(windows, states)
    host_sync(res.values)
    return res

def schedule_turn(service, launches):
    return [_packed_launch(service.store, w, s) for (w, s) in launches]
"""


def test_g007_bad(tmp_path):
    # a per-query host_sync in the scheduling loop + a per-result .item()
    findings = lint_snippet(tmp_path, BAD_G007,
                            relpath="src/repro/core/service.py")
    assert_only_rule(findings, "G007", count=2)
    assert all("_launch" in f.message for f in findings)


def test_g007_good(tmp_path):
    assert lint_snippet(tmp_path, GOOD_G007,
                        relpath="src/repro/core/service.py") == []


def test_g007_scoped_to_service_modules(tmp_path):
    # same code elsewhere answers to G004's discipline, not G007's
    assert lint_snippet(tmp_path, BAD_G007,
                        relpath="src/repro/core/scheduler.py",
                        rules=[get_rule("G007")]) == []


def test_g007_method_call_form_flagged(tmp_path):
    code = ("def poll(engine, res):\n"
            "    engine.host_sync(res.values)\n")
    findings = lint_snippet(tmp_path, code,
                            relpath="src/repro/launch/service.py")
    assert_only_rule(findings, "G007", count=1)


# -- G008: stability-layer seeding discipline --------------------------------

BAD_G008 = """\
from repro.graph.engine import relax_sweep

def seed_from_raw_delta(semiring, n, values, parent, delta_blocks):
    frontier = values == values  # all-on: the raw Delta endpoint seeding
    return relax_sweep(semiring, n, values, parent, frontier, delta_blocks)
"""

GOOD_G008 = """\
from repro.graph.stability import seed_state

def seed_properly(semiring, n, values, parent, delta_blocks):
    return seed_state(semiring, n, values, parent, delta_blocks)
"""


def test_g008_bad(tmp_path):
    findings = lint_snippet(tmp_path, BAD_G008,
                            relpath="src/repro/core/executor.py")
    assert_only_rule(findings, "G008", count=1)
    assert "seed_state" in findings[0].message


def test_g008_good(tmp_path):
    assert lint_snippet(tmp_path, GOOD_G008,
                        relpath="src/repro/core/executor.py") == []


def test_g008_stability_module_exempt(tmp_path):
    # the analysis itself owns the one sanctioned seeding call site
    assert lint_snippet(tmp_path, BAD_G008,
                        relpath="src/repro/graph/stability.py",
                        rules=[get_rule("G008")]) == []


def test_g008_engine_fixpoint_exempt(tmp_path):
    # _fixpoint's per-sweep relax_sweep is iteration, not seeding — but a
    # relax_sweep anywhere else in the engine module is still flagged.
    code = ("def relax_sweep(semiring, n, values, parent, frontier, blocks):\n"
            "    '''the sweep primitive itself'''\n"
            "    return values\n"
            "def _fixpoint(semiring, n, values, parent, frontier, blocks):\n"
            "    def body(carry):\n"
            "        return relax_sweep(semiring, n, *carry, blocks)\n"
            "    return body\n"
            "def rogue_seed(semiring, n, values, parent, frontier, blocks):\n"
            "    return relax_sweep(semiring, n, values, parent, frontier,\n"
            "                       blocks)\n")
    findings = lint_snippet(tmp_path, code,
                            relpath="src/repro/graph/engine.py",
                            rules=[get_rule("G008")])
    assert_only_rule(findings, "G008", count=1)
    assert findings[0].line > 7  # only the rogue call, not _fixpoint's


# -- G009: watermark cut discipline ------------------------------------------

BAD_G009 = """\
import numpy as np

def sneak_snapshot(store, keys):
    store.ingest_cut(keys, np.empty(0, np.int64), np.empty(0, np.int64))

def grow_directly(store, keys):
    store.seq.snapshot_keys.append(keys)

def plant_cache_entry(store, i, j, keys):
    store._t[(i, j)] = keys
"""

GOOD_G009 = """\
def serve_live(watermark, ts):
    watermark.advance(ts)
    return watermark.cut()

def retire_old(watermark):
    return watermark.compact()
"""


def test_g009_bad(tmp_path):
    # an ad-hoc ingest_cut, a direct sequence append, a planted cache entry
    findings = lint_snippet(tmp_path, BAD_G009,
                            relpath="src/repro/launch/firehose.py")
    assert_only_rule(findings, "G009", count=3)
    messages = " | ".join(f.message for f in findings)
    assert "Watermark.cut" in messages
    assert "window cache" in messages
    assert "pure-cache" in messages


def test_g009_good(tmp_path):
    assert lint_snippet(tmp_path, GOOD_G009,
                        relpath="src/repro/launch/firehose.py") == []


def test_g009_ingest_cut_exempt_only_inside_cut(tmp_path):
    # In core/ingest.py: legal from a function named cut, flagged elsewhere.
    code = ("import numpy as np\n"
            "class Watermark:\n"
            "    '''doc'''\n"
            "    def cut(self):\n"
            "        '''doc'''\n"
            "        return self.store.ingest_cut(self.k, self.a, self.d)\n"
            "    def shortcut(self):\n"
            "        '''doc'''\n"
            "        return self.store.ingest_cut(self.k, self.a, self.d)\n")
    findings = lint_snippet(tmp_path, code,
                            relpath="src/repro/core/ingest.py",
                            rules=[get_rule("G009")])
    assert_only_rule(findings, "G009", count=1)
    assert findings[0].line > 6  # only shortcut's call, not cut's


def test_g009_canonical_module_exempt_for_cache_writes(tmp_path):
    code = ("class SnapshotStore:\n"
            "    '''the canonical store module'''\n"
            "    def ingest_cut(self, keys, added, deleted):\n"
            "        '''doc'''\n"
            "        self._t[(0, 0)] = keys\n"
            "        return 0\n")
    assert lint_snippet(tmp_path, code,
                        relpath="src/repro/core/snapshots.py",
                        rules=[get_rule("G009")]) == []


# -- G010: fused-launch discipline --------------------------------------------

BAD_G010 = """\
from repro.graph.engine import relax_sweep_fused, run_to_fixpoint

def hand_rolled_chunk(semiring, n, values, parent, frontier, blocks):
    return relax_sweep_fused(semiring, n, values, parent, frontier, blocks,
                             k=4)

def hardcoded_knob(view, semiring, source):
    return run_to_fixpoint(view, semiring, source, fused_k=8)
"""

GOOD_G010 = """\
from repro.graph.engine import run_to_fixpoint

def launch(view, semiring, source, options):
    return run_to_fixpoint(view, semiring, source,
                           fused_k=options.fused_k)

def launch_threaded(view, semiring, source, fused_k):
    return run_to_fixpoint(view, semiring, source, fused_k=fused_k)
"""


def test_g010_bad(tmp_path):
    # a direct fused-chunk launch + a literal fused_k at a call site
    findings = lint_snippet(tmp_path, BAD_G010,
                            relpath="src/repro/core/executor.py")
    assert_only_rule(findings, "G010", count=2)
    messages = " | ".join(f.message for f in findings)
    assert "launch option" in messages
    assert "fused_k=8" in messages


def test_g010_good(tmp_path):
    assert lint_snippet(tmp_path, GOOD_G010,
                        relpath="src/repro/core/executor.py") == []


def test_g010_stability_module_may_call_fused(tmp_path):
    # the seed sweep (k=1 fused chunk) is stability's sanctioned call —
    # but k= is not the fused_k knob, so only the call-site grant matters
    code = ("from repro.graph.engine import relax_sweep_fused\n"
            "def seed_state(semiring, n, values, parent, frontier, blocks):\n"
            "    return relax_sweep_fused(semiring, n, values, parent,\n"
            "                             frontier, blocks, k=1)\n")
    assert lint_snippet(tmp_path, code,
                        relpath="src/repro/graph/stability.py",
                        rules=[get_rule("G010")]) == []


def test_g010_engine_fixpoint_exempt(tmp_path):
    # _fixpoint's chunked body consumes fused chunks; a fused launch
    # anywhere else in the engine module is still flagged.
    code = ("def relax_sweep_fused(semiring, n, values, parent, frontier,\n"
            "                      blocks, k=1):\n"
            "    '''the fused chunk primitive itself'''\n"
            "    return values\n"
            "def _fixpoint(semiring, n, values, parent, frontier, blocks,\n"
            "              fused_k=1):\n"
            "    def chunk(carry):\n"
            "        return relax_sweep_fused(semiring, n, *carry, blocks,\n"
            "                                 k=fused_k)\n"
            "    return chunk\n"
            "def rogue(semiring, n, values, parent, frontier, blocks):\n"
            "    return relax_sweep_fused(semiring, n, values, parent,\n"
            "                             frontier, blocks, k=2)\n")
    findings = lint_snippet(tmp_path, code,
                            relpath="src/repro/graph/engine.py",
                            rules=[get_rule("G010")])
    assert_only_rule(findings, "G010", count=1)
    assert findings[0].line > 10  # only the rogue launch, not _fixpoint's


def test_g010_engine_module_may_default_the_knob(tmp_path):
    # engine plumbing forwards fused_k between its own entry points; the
    # literal-knob check applies outside the engine module only.
    code = ("def run_to_fixpoint(view, semiring, source, fused_k=1):\n"
            "    '''doc'''\n"
            "    return _fixpoint_jit(view, semiring, source, fused_k=1)\n")
    assert lint_snippet(tmp_path, code,
                        relpath="src/repro/graph/engine.py",
                        rules=[get_rule("G010")]) == []


# -- suppressions, engine plumbing, CLI --------------------------------------

def test_line_suppression(tmp_path):
    code = BAD_G004_BARE.replace(
        "values.block_until_ready()",
        "values.block_until_ready()  # graphlint: disable=G004")
    assert lint_snippet(tmp_path, code) == []


def test_file_suppression(tmp_path):
    code = "# graphlint: disable-file=G004\n" + BAD_G004_BARE
    assert lint_snippet(tmp_path, code) == []


def test_suppression_is_per_rule(tmp_path):
    code = BAD_G004_BARE.replace(
        "values.block_until_ready()",
        "values.block_until_ready()  # graphlint: disable=G001")
    findings = lint_snippet(tmp_path, code)
    assert_only_rule(findings, "G004", count=1)


def test_rule_registry_complete():
    assert [r.id for r in all_rules()] == \
        ["G001", "G002", "G003", "G004", "G005", "G006", "G007", "G008",
         "G009", "G010"]
    for rule in all_rules():
        assert rule.title and rule.contract
    with pytest.raises(KeyError):
        get_rule("G999")


def test_module_dotted_name(tmp_path):
    root = make_repo(tmp_path)
    path = root / "src" / "repro" / "core" / "thing.py"
    path.parent.mkdir(parents=True, exist_ok=True)
    m = Module(path, "x = 1\n", root)
    assert m.dotted_name() == "repro.core.thing"
    assert m.rel == "src/repro/core/thing.py"


def test_render_json_shape(tmp_path):
    findings = lint_snippet(tmp_path, BAD_G004_BARE)
    payload = json.loads(render_json(findings, files_checked=1))
    assert payload["version"] == 1
    assert payload["count"] == 1
    assert payload["findings"][0]["rule"] == "G004"
    assert set(payload["findings"][0]) == \
        {"rule", "path", "line", "col", "message"}


def test_cli_json_exit_codes(tmp_path):
    root = make_repo(tmp_path)
    bad = root / "src" / "repro" / "bad.py"
    bad.write_text(BAD_G004_BARE)
    proc = subprocess.run(
        [sys.executable, str(REPO / "scripts" / "invariant_lint.py"),
         "--format", "json", str(bad)],
        capture_output=True, text=True)
    assert proc.returncode == 1, proc.stderr
    payload = json.loads(proc.stdout)
    assert payload["count"] == 1 and payload["findings"][0]["rule"] == "G004"

    proc = subprocess.run(
        [sys.executable, str(REPO / "scripts" / "invariant_lint.py"),
         "--select", "G001", str(bad)],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr


# -- the gate itself: the real tree is clean ---------------------------------

def test_graphlint_clean_on_real_src():
    linter = Linter(root=REPO)
    findings = linter.lint([REPO / "src"])
    assert findings == [], "\n".join(f.render() for f in findings)
    assert linter.files_checked > 50

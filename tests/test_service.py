"""Query-service contract: scheduling fairness, packing, pin hygiene.

The three service satellites of the serving tier (core/service.py):

* **Scheduling properties** — round-robin turns are starvation-free
  (every client with pending work at the start of a scheduling round is
  served within ``len(clients)`` turns), per-turn work is bounded, and
  drained results are bit-identical to running each client's stream solo
  — for every semiring, under property-sampled client mixes.
* **Concurrent-eviction soak** — under a byte budget small enough to
  force LRU evictions mid-service, every anchor-chain-pinned "AS" tag
  survives (tag pinned AND state still cached) after every turn, and all
  pins drain to refcount zero once the clients unregister.
* **Batch packing** — compatible queries coalesce into one launch
  (occupancy > 1, edge work identical to per-client solo slides at the
  same anchor), incompatible ``(semiring, width-bucket)`` pairs never
  share, and a lone campaign pads to a valid pow2 lane bucket.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    QueryService,
    SnapshotStore,
    run_window_slide_batched,
    run_window_stream_batched,
    slide_windows,
)
from repro.core.snapshots import anchor_tag
from repro.graph import make_evolving_sequence
from repro.graph.edgeset import lane_bucket
from repro.graph.semiring import ALL_SEMIRINGS

SNAPS = 7


def _store(n=250, e=1800, snaps=SNAPS, changes=120, seed=13, granule=128,
           **kw):
    return SnapshotStore(make_evolving_sequence(n, e, snaps, changes,
                                                seed=seed),
                         granule=granule, **kw)


_SHARED = None


def _shared_store():
    """One module-level store for the property tests (NOT a pytest fixture:
    @given re-runs the test body per example and function-scoped fixtures
    would trip hypothesis' health checks). Anchor-state reuse across
    examples is harmless — values are anchor-independent by the unique-
    fixpoint invariant, and cache hits only reduce rebuild counts."""
    global _SHARED
    if _SHARED is None:
        _SHARED = _store()
    return _SHARED


def _solo(store, client, windows, campaign_width):
    """The pre-service baseline: this client's stream alone, cold anchors."""
    store.release(("AS",))
    return run_window_stream_batched(
        store, client.semiring, client.source, windows=windows,
        campaign_width=campaign_width)


# -- scheduling: fairness + bit-identity --------------------------------------

def test_service_bit_identical_to_solo_every_semiring():
    """One client per semiring, drained together through packed launches:
    every window's values must equal the solo stream's bit-for-bit."""
    store = _store()
    svc = QueryService(store, lane_budget=8, turn_budget=4)
    windows = slide_windows(SNAPS, 3)
    clients = {name: svc.register(sr, 0, campaign_width=2, name=f"sr-{name}")
               for name, sr in ALL_SEMIRINGS.items()}
    for client in clients.values():
        svc.submit(client, windows)
    m = svc.drain()
    assert m.completed == m.admitted == len(ALL_SEMIRINGS) * len(windows)
    for client in clients.values():
        svc.unregister(client)
    for name, client in clients.items():
        solo = _solo(store, client, windows, campaign_width=2)
        for wnd in windows:
            np.testing.assert_array_equal(
                np.asarray(client.results[wnd]),
                np.asarray(solo.results[wnd]),
                err_msg=f"{name} diverged from solo at window {wnd}")
    assert store.pinned_tags() == set()


def test_shared_qkey_strictly_fewer_rebuilds_than_solo():
    """Clients sharing a query key share anchor states: the service does
    strictly fewer total rebuilds than each stream run solo with a cold
    anchor cache — same values."""
    store = _store()
    sr = ALL_SEMIRINGS["sssp"]
    svc = QueryService(store, lane_budget=8)
    windows = slide_windows(SNAPS, 2)
    clients = [svc.register(sr, 0, campaign_width=2, name=f"twin-{i}")
               for i in range(3)]
    for client in clients:
        svc.submit(client, windows)
    m = svc.drain()
    for client in clients:
        svc.unregister(client)
    solo_rebuilds = 0
    for client in clients:
        solo = _solo(store, client, windows, campaign_width=2)
        solo_rebuilds += solo.anchor_rebuilds
        for wnd in windows:
            np.testing.assert_array_equal(np.asarray(client.results[wnd]),
                                          np.asarray(solo.results[wnd]))
    assert m.anchor_rebuilds < solo_rebuilds
    assert m.anchor_rebuilds + m.anchor_hops + m.anchor_hits > 0


@settings(max_examples=12, deadline=None)
@given(num_clients=st.integers(2, 4),
       turn_budget=st.sampled_from([2, 3, None]),
       width=st.integers(1, 3),
       start=st.integers(0, 2))
def test_round_robin_is_starvation_free(num_clients, turn_budget, width,
                                        start):
    """Bounded-turn advancement: every client with pending work when a
    scheduling round begins has completed at least one more campaign
    within ``len(clients)`` turns — no mix of semirings, widths or turn
    budgets starves a stream. Per-turn lane draw stays bounded, and the
    drained results match solo bit-for-bit."""
    store = _shared_store()
    svc = QueryService(store, lane_budget=8, turn_budget=turn_budget)
    names = list(ALL_SEMIRINGS)
    windows = slide_windows(SNAPS, width, start=start)
    clients = [svc.register(ALL_SEMIRINGS[names[i % len(names)]], i % 2,
                            campaign_width=1 + i % 3, name=f"prop-{i}")
               for i in range(num_clients)]
    for client in clients:
        svc.submit(client, windows)
    # unbounded turns draw ≤ one campaign from EVERY ready client; bounded
    # turns stop at the budget (the first ready client is always served,
    # so a lone over-budget campaign_width is the other cap).
    widths = [c.stream.campaign_width for c in clients]
    lane_cap = (sum(widths) if turn_budget is None
                else max(turn_budget, max(widths)))
    while svc.pending():
        ready = [c for c in clients if c.pending()]
        before = {c.name: c.campaigns_done for c in ready}
        for _ in range(len(svc.clients)):
            if not svc.pending():
                break
            records = svc.turn()
            assert sum(r.lanes for r in records) <= lane_cap
        for client in ready:
            assert client.campaigns_done > before[client.name], \
                f"{client.name} starved for {len(svc.clients)} turns"
    for client in clients:
        assert not client.pending()
        svc.unregister(client)
    for client in clients:
        solo = _solo(store, client, windows,
                     campaign_width=client.stream.campaign_width)
        for wnd in windows:
            np.testing.assert_array_equal(np.asarray(client.results[wnd]),
                                          np.asarray(solo.results[wnd]))


# -- concurrent-eviction soak -------------------------------------------------

def test_eviction_soak_pins_hold_and_drain():
    """Bursty load under a byte budget small enough to evict mid-service:
    chain-pinned anchor tags are never evicted (tag still pinned AND its
    state still cached after every turn), eviction pressure really
    happened, and every pin drains to refcount zero after unregister."""
    store = _store(cache_bytes=48 * 1024)
    sr = ALL_SEMIRINGS["sssp"]
    svc = QueryService(store, lane_budget=8, turn_budget=4)
    clients = [svc.register(sr, 0, campaign_width=2, name="soak-a"),
               svc.register(sr, 0, campaign_width=2, name="soak-b"),
               svc.register(ALL_SEMIRINGS["bfs"], 3, campaign_width=2,
                            name="soak-c")]
    windows = slide_windows(SNAPS, 2)
    seen_tags = set()
    for burst in range(3):
        lo = 2 * burst
        for client in clients:
            svc.submit(client,
                       [w for w in windows if lo <= w[0] < lo + 2])
        while svc.pending():
            svc.turn()
            for qkey, chain in svc._chains.items():
                for link in chain._pinned:
                    tag = anchor_tag(qkey, link)
                    seen_tags.add(tag)
                    assert tag in store.pinned_tags()
                    assert store.anchor_state_get(qkey, link) is not None
    assert store.evictions > 0, "soak never pressured the LRU"
    assert seen_tags, "soak never pinned an anchor link"
    assert svc.metrics().completed == svc.metrics().admitted
    for client in clients:
        svc.unregister(client)
    assert store.pinned_tags() == set()
    assert all(store.pin_count(tag) == 0 for tag in seen_tags)


# -- admission / batch packing ------------------------------------------------

def test_packing_compatible_clients_share_one_launch():
    """Two clients with identical launch options and width bucket (but
    different sources, hence different anchor states) pack into ONE
    batched launch whose edge work equals the per-client solo slides at
    the same anchor — packing changes scheduling, never work."""
    store = _store()
    sr = ALL_SEMIRINGS["sssp"]
    svc = QueryService(store, lane_budget=8)
    a = svc.register(sr, 0, campaign_width=2, name="pack-a")
    b = svc.register(sr, 1, campaign_width=2, name="pack-b")
    windows = [(0, 2), (1, 3)]
    svc.submit(a, windows)
    svc.submit(b, windows)
    records = svc.turn()
    assert len(records) == 1
    rec = records[0]
    assert rec.lanes == 4 and rec.bucket == 4
    assert sorted(set(rec.clients)) == ["pack-a", "pack-b"]
    assert len(rec.anchor_events) == 2          # one per distinct qkey
    assert svc.metrics().batch_occupancy > 1
    solo_work = sum(
        stat.edge_work
        for source in (0, 1)
        for stat in run_window_slide_batched(
            store, sr, source, windows=windows,
            anchor=rec.anchor).hop_stats)
    np.testing.assert_allclose(rec.edge_work, solo_work, rtol=1e-6)


def test_packing_never_mixes_semirings():
    store = _store()
    svc = QueryService(store, lane_budget=8)
    a = svc.register(ALL_SEMIRINGS["sssp"], 0, campaign_width=2,
                     name="mix-sssp")
    b = svc.register(ALL_SEMIRINGS["bfs"], 0, campaign_width=2,
                     name="mix-bfs")
    windows = [(0, 2), (1, 3)]
    svc.submit(a, windows)
    svc.submit(b, windows)
    records = svc.turn()
    assert len(records) == 2
    for rec in records:
        assert len(set(rec.clients)) == 1       # no cross-semiring lanes
    assert {rec.group[0] for rec in records} == {"sssp", "bfs"}


def test_packing_never_mixes_width_buckets():
    """Same query key, wildly different slide-Δ: the horizon-wide window
    (Δ = 0 from the shared anchor) and the single-snapshot window (Δ near
    the full graph) land in different pow2 buckets, hence different
    launches — bucket mixing would blow up the padded trace shape."""
    store = _store()
    sr = ALL_SEMIRINGS["sssp"]
    svc = QueryService(store, lane_budget=8)
    wide = svc.register(sr, 0, campaign_width=1, name="bucket-wide")
    narrow = svc.register(sr, 0, campaign_width=1, name="bucket-narrow")
    svc.submit(wide, [(0, SNAPS - 1)])
    svc.submit(narrow, [(3, 3)])
    records = svc.turn()
    assert len(records) == 2
    buckets = {rec.group[1] for rec in records}
    assert len(buckets) == 2                    # distinct width buckets
    for rec in records:
        assert len(set(rec.clients)) == 1


def test_lone_campaign_pads_to_pow2_bucket():
    store = _store()
    svc = QueryService(store, lane_budget=8)
    only = svc.register(ALL_SEMIRINGS["sssp"], 0, campaign_width=3,
                        name="lone")
    svc.submit(only, [(0, 2), (1, 3), (2, 4)])
    rec, = svc.turn()
    assert rec.lanes == 3
    assert rec.bucket == lane_bucket(3) == 4
    assert svc.metrics().padded_lanes == 1


# -- service API contract -----------------------------------------------------

def test_service_register_and_submit_validation():
    store = _store()
    sr = ALL_SEMIRINGS["sssp"]
    svc = QueryService(store, lane_budget=4)
    with pytest.raises(ValueError):             # planner mode is solo-only
        svc.register(sr, 0, campaign_width="auto")
    with pytest.raises(ValueError):             # campaign must fit a launch
        svc.register(sr, 0, campaign_width=5)
    with pytest.raises(ValueError):
        svc.register(sr, 0, campaign_width=0)
    client = svc.register(sr, 0, name="dup", horizon=4)
    with pytest.raises(ValueError):             # names are unique
        svc.register(ALL_SEMIRINGS["bfs"], 1, name="dup")
    with pytest.raises(ValueError):             # window ends past horizon
        svc.submit(client, [(2, 5)])
    assert svc.submit(client, [(2, 4)]) == 1
    with pytest.raises(ValueError):             # pending work is never lost
        svc.unregister(client)
    svc.drain()
    svc.unregister(client)
    assert svc.clients == []
    with pytest.raises(ValueError):
        QueryService(store, lane_budget=0)
    with pytest.raises(ValueError):
        QueryService(store, turn_budget=0)


def test_idle_turn_is_uncounted_noop():
    store = _store()
    svc = QueryService(store)
    assert svc.turn() == []
    assert svc.metrics().turns == 0
    client = svc.register(ALL_SEMIRINGS["bfs"], 0, campaign_width=1)
    svc.submit(client, [(0, 1)])
    assert len(svc.turn()) == 1
    assert svc.metrics().turns == 1
    assert svc.turn() == []                     # drained again
    assert svc.metrics().turns == 1


def test_drain_raises_on_backlog_overrun():
    store = _store()
    svc = QueryService(store, turn_budget=1)
    client = svc.register(ALL_SEMIRINGS["bfs"], 0, campaign_width=1)
    svc.submit(client, slide_windows(SNAPS, 2))  # 6 one-lane turns needed
    with pytest.raises(RuntimeError):
        svc.drain(max_turns=2)

"""Engine invariants: incremental == from-scratch, gating/no-parent exactness,
batched executor equivalence (unit + hypothesis property tests)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import SnapshotStore
from repro.graph import (
    incremental_additions,
    incremental_additions_batched,
    make_evolving_sequence,
    run_to_fixpoint,
)
from repro.graph.edgeset import EdgeBlock, keys_to_edges, make_block
from repro.graph.semiring import ALL_SEMIRINGS, SSSP


@st.composite
def evolving(draw):
    n = draw(st.integers(30, 120))
    e = draw(st.integers(40, 400))
    snaps = draw(st.integers(2, 5))
    changes = draw(st.integers(2, 30)) * 2
    seed = draw(st.integers(0, 2**16))
    return n, e, snaps, changes, seed


@given(params=evolving(), alg=st.sampled_from(list(ALL_SEMIRINGS)))
@settings(max_examples=10, deadline=None)
def test_incremental_additions_reach_scratch_fixpoint(params, alg):
    """Property: warm-start + Δ additions converges to the exact from-scratch
    fixpoint (the monotonicity argument the whole paper rests on)."""
    n, e, snaps, changes, seed = params
    sr = ALL_SEMIRINGS[alg]
    seq = make_evolving_sequence(n, e, snaps, changes, seed=seed)
    store = SnapshotStore(seq, granule=64)
    window = (0, snaps - 1)
    cg = store.common_graph_view(*window)
    base = run_to_fixpoint(cg, sr, 0)
    for i in range(snaps):
        delta = store.delta_block(window, (i, i))
        view = cg.extended(delta)
        inc = incremental_additions(view, delta, sr, base.values, base.parent)
        ref = run_to_fixpoint(store.snapshot_view(i), sr, 0)
        np.testing.assert_allclose(np.asarray(inc.values), np.asarray(ref.values),
                                   rtol=1e-6)


@pytest.mark.parametrize("gated", [False, True])
@pytest.mark.parametrize("track_parents", [False, True])
def test_modes_are_exact(gated, track_parents):
    seq = make_evolving_sequence(300, 2500, 4, 150, seed=5)
    store = SnapshotStore(seq, granule=128)
    for alg in ("sssp", "viterbi"):
        sr = ALL_SEMIRINGS[alg]
        ref = run_to_fixpoint(store.snapshot_view(1), sr, 0)
        view = (store.window_view_split(1, 1, 4) if gated
                else store.snapshot_view(1))
        got = run_to_fixpoint(view, sr, 0, gated=gated,
                              track_parents=track_parents)
        np.testing.assert_allclose(np.asarray(got.values), np.asarray(ref.values))
        if track_parents and not gated:
            np.testing.assert_array_equal(np.asarray(got.parent),
                                          np.asarray(ref.parent))


def test_batched_equals_sequential():
    seq = make_evolving_sequence(250, 2000, 5, 120, seed=9)
    store = SnapshotStore(seq, granule=128)
    sr = SSSP
    window = (0, 4)
    cg = store.common_graph_view(*window)
    base = run_to_fixpoint(cg, sr, 0)
    deltas = [store.delta_keys(window, (i, i)) for i in range(5)]
    e_max = max(d.shape[0] for d in deltas)
    srcs, dsts, ws = [], [], []
    for dk in deltas:
        s, d = keys_to_edges(dk, store.num_nodes)
        blk = make_block(s, d, seq.weights_for(dk), store.num_nodes,
                         granule=max(e_max, 1))
        srcs.append(blk.src); dsts.append(blk.dst); ws.append(blk.w)
    stacked = EdgeBlock(jnp.stack(srcs), jnp.stack(dsts), jnp.stack(ws))
    values = jnp.broadcast_to(base.values, (5, store.num_nodes))
    parent = jnp.broadcast_to(base.parent, (5, store.num_nodes))
    res = incremental_additions_batched(store.num_nodes, sr, values, parent,
                                        tuple(cg.blocks), (stacked,))
    for i in range(5):
        ref = run_to_fixpoint(store.snapshot_view(i), sr, 0)
        np.testing.assert_allclose(np.asarray(res.values[i]),
                                   np.asarray(ref.values), rtol=1e-6)


def test_view_block_sharing_is_zero_copy():
    """The mutation-free representation: extended views share block objects."""
    seq = make_evolving_sequence(100, 600, 3, 40, seed=2)
    store = SnapshotStore(seq, granule=64)
    cg = store.common_graph_view()
    d0 = store.delta_block((0, 2), (0, 0))
    v0 = cg.extended(d0)
    v1 = cg.extended(store.delta_block((0, 2), (1, 1)))
    assert v0.blocks[0] is cg.blocks[0] and v1.blocks[0] is cg.blocks[0]
    assert store.delta_block((0, 2), (0, 0)) is d0  # cached, not rebuilt


def test_edge_work_counts_frontier_masked_edges():
    seq = make_evolving_sequence(200, 1500, 2, 80, seed=3)
    store = SnapshotStore(seq, granule=128)
    full = run_to_fixpoint(store.snapshot_view(0), SSSP, 0)
    # warm re-run from the fixpoint: nothing improves — one no-op sweep at most
    again = run_to_fixpoint(store.snapshot_view(0), SSSP, 0,
                            values=full.values, parent=full.parent)
    assert int(again.iterations) <= 1

"""Per-architecture reduced-config smoke tests (assignment deliverable f)
plus model-level equivalence checks (prefill/decode/chunked paths, MoE oracle)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch, reduced_config
from repro.data import DataCursor, dien_batch, gnn_full_batch, lm_batch
from repro.launch.train import _graphcastify
from repro.models.dien import dien_loss, dien_score_candidates, init_dien_params
from repro.models.gnn import gnn_forward, gnn_loss, init_gnn_params
from repro.models.transformer import (
    init_kv_cache,
    init_lm_params,
    lm_decode_step,
    lm_forward,
    lm_loss,
    lm_prefill,
)

LM_ARCHS = [a for a in ARCH_IDS if get_arch(a)[1] == "lm"]
GNN_ARCHS = [a for a in ARCH_IDS if get_arch(a)[1] == "gnn"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_arch_smoke(arch):
    """One forward/train step on CPU: output shapes + no NaNs (reduced config)."""
    cfg, _ = reduced_config(arch)
    params = init_lm_params(jax.random.PRNGKey(0), cfg)
    batch = lm_batch(DataCursor(0, 0), 2, 32, cfg.vocab)
    loss, grads = jax.value_and_grad(
        lambda p: lm_loss(cfg, p, batch["tokens"], batch["labels"]))(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0
    logits, cache = lm_prefill(cfg, params, batch["tokens"])
    assert logits.shape == (2, cfg.vocab)
    assert cache["k"].shape == (cfg.n_layers, 2, 32, cfg.n_kv_heads, cfg.head_dim)
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_decode_matches_forward(arch):
    cfg, _ = reduced_config(arch)
    params = init_lm_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 9), 0, cfg.vocab)
    _, pc = lm_prefill(cfg, params, toks[:, :8])
    cache = init_kv_cache(cfg, 1, 16, dtype=jnp.float32)
    cache = {k: cache[k].at[:, :, :8].set(pc[k].astype(jnp.float32))
             for k in ("k", "v")}
    logits, _ = lm_decode_step(cfg, params, cache, toks[:, 8:9], jnp.int32(8))
    x = lm_forward(cfg, params, toks)
    ref = jnp.einsum("d,dv->v", x[0, -1], params["lm_head"])
    np.testing.assert_allclose(np.asarray(logits[0]), np.asarray(ref),
                               rtol=5e-4, atol=5e-4)


def test_chunked_paths_equal_unchunked():
    base = reduced_config("stablelm-1.6b")[0]
    p = init_lm_params(jax.random.PRNGKey(0), base)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, base.vocab)
    ref = lm_loss(base, p, toks, toks)
    for kw in ({"attn_chunk": 8}, {"vocab_chunk": 8},
               {"attn_chunk": 16, "vocab_chunk": 16}):
        cfg = dataclasses.replace(base, **kw)
        np.testing.assert_allclose(float(lm_loss(cfg, p, toks, toks)),
                                   float(ref), rtol=3e-5)


def test_scan_unroll_is_equivalent():
    base = reduced_config("qwen3-moe-30b-a3b")[0]
    p = init_lm_params(jax.random.PRNGKey(0), base)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, base.vocab)
    a = lm_loss(base, p, toks, toks)
    b = lm_loss(dataclasses.replace(base, scan_unroll=True), p, toks, toks)
    np.testing.assert_allclose(float(a), float(b), rtol=1e-6)


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_arch_smoke(arch):
    cfg, _ = reduced_config(arch)
    n, e = 48, 200
    cfg = dataclasses.replace(
        cfg, d_in=12, d_out=5,
        task="node_class" if cfg.arch in ("gcn", "pna") else "node_reg",
        n_vars=6 if cfg.arch == "graphcast" else cfg.n_vars)
    if cfg.arch == "graphcast":
        cfg = dataclasses.replace(cfg, d_in=6, d_out=6)
    params = init_gnn_params(jax.random.PRNGKey(0), cfg)
    cur = DataCursor(0, 0)
    batch = gnn_full_batch(cur, n, e, cfg.d_in, cfg.d_out, cfg.task)
    if cfg.arch == "graphcast":
        batch = _graphcastify(batch, n, e, cfg, cur)
    loss, grads = jax.value_and_grad(lambda p: gnn_loss(cfg, p, batch))(params)
    assert np.isfinite(float(loss))
    out = gnn_forward(cfg, params, batch)
    exp_rows = n
    assert out.shape == (exp_rows, cfg.n_vars if cfg.arch == "graphcast" else cfg.d_out)
    assert not bool(jnp.any(jnp.isnan(out)))


def test_gnn_padding_edges_are_inert():
    """Edges with dst == n must not change any real node's output."""
    cfg, _ = reduced_config("gcn-cora")
    cfg = dataclasses.replace(cfg, d_in=8, d_out=3, task="node_class")
    params = init_gnn_params(jax.random.PRNGKey(0), cfg)
    n, e = 32, 100
    b = gnn_full_batch(DataCursor(0, 0), n, e, 8, 3, "node_class")
    out1 = gnn_forward(cfg, params, b)
    b2 = dict(b)
    b2["src"] = jnp.concatenate([b["src"], jnp.zeros((16,), jnp.int32)])
    b2["dst"] = jnp.concatenate([b["dst"], jnp.full((16,), n, jnp.int32)])
    out2 = gnn_forward(cfg, params, b2)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)


def test_dien_smoke_and_retrieval_consistency():
    cfg, _ = reduced_config("dien")
    params = init_dien_params(jax.random.PRNGKey(0), cfg)
    batch = dien_batch(DataCursor(0, 0), 8, cfg.seq_len, cfg.n_items, cfg.n_cats)
    loss = dien_loss(cfg, params, batch)
    assert np.isfinite(float(loss))
    # retrieval scoring == pointwise forward margin for the same candidate
    from repro.models.dien import dien_forward
    one = {k: v[:1] for k, v in batch.items()}
    cand = {"hist_items": one["hist_items"], "hist_cats": one["hist_cats"],
            "hist_mask": one["hist_mask"],
            "cand_items": one["target_item"], "cand_cats": one["target_cat"]}
    scores = dien_score_candidates(cfg, params, cand)
    logits, *_ = dien_forward(cfg, params, one)
    np.testing.assert_allclose(float(scores[0]),
                               float(logits[0, 1] - logits[0, 0]), rtol=1e-4)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_registry_resolves(arch):
    cfg, family = get_arch(arch)
    assert family in ("lm", "gnn", "recsys")
    rcfg, _ = reduced_config(arch)
    assert rcfg.name == cfg.name

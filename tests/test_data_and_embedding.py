"""Data-pipeline determinism + EmbeddingBag substrate properties."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.data import DataCursor, dien_batch, gnn_full_batch, lm_batch
from repro.models.embedding import embedding_bag, embedding_lookup


def test_lm_batch_deterministic_in_seed_step():
    a = lm_batch(DataCursor(3, 5), 4, 16, 100)
    b = lm_batch(DataCursor(3, 5), 4, 16, 100)
    c = lm_batch(DataCursor(3, 6), 4, 16, 100)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token shifted with last masked
    np.testing.assert_array_equal(np.asarray(a["labels"][:, :-1]),
                                  np.asarray(a["tokens"][:, 1:]))
    assert np.all(np.asarray(a["labels"][:, -1]) == -1)


def test_gnn_and_dien_batch_shapes():
    g = gnn_full_batch(DataCursor(0, 0), 10, 30, 8, 3, "node_class")
    assert g["x"].shape == (10, 8) and g["labels"].shape == (10,)
    d = dien_batch(DataCursor(0, 0), 4, 7, 100, 10)
    assert d["hist_items"].shape == (4, 7)
    assert int(jnp.max(d["hist_items"])) < 100


@given(v=st.integers(4, 64), d=st.integers(1, 16), n_ids=st.integers(1, 128),
       b=st.integers(1, 16), seed=st.integers(0, 99))
@settings(max_examples=15, deadline=None)
def test_embedding_bag_matches_loop(v, d, n_ids, b, seed):
    key = jax.random.PRNGKey(seed)
    table = jax.random.normal(key, (v, d))
    ids = jax.random.randint(jax.random.fold_in(key, 1), (n_ids,), 0, v)
    bags = jax.random.randint(jax.random.fold_in(key, 2), (n_ids,), 0, b)
    out = embedding_bag(table, ids, bags, b, mode="sum")
    ref = np.zeros((b, d), np.float32)
    for i in range(n_ids):
        ref[int(bags[i])] += np.asarray(table[int(ids[i])])
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4, atol=1e-5)


def test_embedding_bag_modes_and_padding():
    table = jnp.eye(4, dtype=jnp.float32)
    ids = jnp.array([0, 1, 2, 3], jnp.int32)
    bags = jnp.array([0, 0, 1, 2], jnp.int32)  # bag 2 gets id 3; pad to bag 3 (n_bags)
    out_sum = embedding_bag(table, ids, bags, 3, mode="sum")
    np.testing.assert_array_equal(np.asarray(out_sum[0]), [1, 1, 0, 0])
    out_mean = embedding_bag(table, ids, bags, 3, mode="mean")
    np.testing.assert_allclose(np.asarray(out_mean[0]), [0.5, 0.5, 0, 0])
    # padded lookups go to sentinel bag n_bags and are dropped
    ids2 = jnp.array([0, 3], jnp.int32)
    bags2 = jnp.array([0, 3], jnp.int32)
    out = embedding_bag(table, ids2, bags2, 3, mode="sum")
    np.testing.assert_array_equal(np.asarray(out[0]), [1, 0, 0, 0])
    assert np.all(np.asarray(out[1:]) == 0)


def test_embedding_lookup_shape():
    table = jnp.arange(20.0).reshape(10, 2)
    ids = jnp.array([[1, 2], [3, 4]], jnp.int32)
    out = embedding_lookup(table, ids)
    assert out.shape == (2, 2, 2)
    np.testing.assert_array_equal(np.asarray(out[0, 0]), [2.0, 3.0])

"""Runtime: checkpoint/restart, failure drills, stragglers, elastic, compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw_init, adamw_update
from repro.optim.compress import ef_compress_update
from repro.runtime import (
    CheckpointManager,
    FaultTolerantRunner,
    StragglerBalancer,
    reshard_state,
)


def test_checkpoint_roundtrip(tmp_path):
    ckpt = CheckpointManager(str(tmp_path))
    state = {"w": jnp.arange(12.0).reshape(3, 4), "step": 7,
             "nested": {"b": jnp.ones((2,))}}
    ckpt.save(7, state)
    back = ckpt.restore_latest()
    np.testing.assert_array_equal(back["w"], np.arange(12.0).reshape(3, 4))
    assert back["step"] == 7
    np.testing.assert_array_equal(back["nested"]["b"], np.ones((2,)))


def test_checkpoint_retention_and_latest(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ckpt.save(s, {"v": jnp.full((2,), float(s))})
    assert ckpt.latest_step() == 4
    assert ckpt.restore(1) is None  # evicted
    np.testing.assert_array_equal(ckpt.restore_latest()["v"], [4.0, 4.0])


def test_fault_tolerant_runner_replays_deterministically(tmp_path):
    """A failed step restores the checkpoint and replays to an identical state."""
    def step_fn(state, step):
        # deterministic pseudo-training: state folds in the step index
        return {"acc": state["acc"] + float(jax.random.uniform(
            jax.random.fold_in(jax.random.PRNGKey(0), step), ()))}

    # reference: failure-free run
    ref = {"acc": 0.0}
    for s in range(12):
        ref = step_fn(ref, s)

    ckpt = CheckpointManager(str(tmp_path))
    runner = FaultTolerantRunner(ckpt, ckpt_every=3)
    ckpt.save(0, {"acc": 0.0})
    state, replayed = runner.run({"acc": 0.0}, step_fn, 12,
                                 fail_at={5, 10})
    assert replayed, "drill must actually replay steps"
    np.testing.assert_allclose(state["acc"], ref["acc"], rtol=1e-7)


def test_fault_runner_gives_up_after_max_retries(tmp_path):
    ckpt = CheckpointManager(str(tmp_path))
    runner = FaultTolerantRunner(ckpt, ckpt_every=100, max_retries=2)
    ckpt.save(0, {"x": 0})
    from repro.runtime.fault import StepFailure
    def bad(state, step):
        raise StepFailure("always down")
    with pytest.raises(StepFailure):
        runner.run({"x": 0}, bad, 3)


def test_straggler_balancer_improves_imbalance():
    bal = StragglerBalancer(n_workers=4)
    costs = {i: (5.0 if i == 0 else 1.0) for i in range(16)}
    for b, c in costs.items():
        bal.observe(b, c)
    naive = {w: [b for b in range(16) if b % 4 == w] for w in range(4)}
    lpt = bal.assign(list(range(16)))
    assert bal.imbalance(lpt) <= bal.imbalance(naive)
    assert sorted(b for bs in lpt.values() for b in bs) == list(range(16))


def test_elastic_reshard_shrink_and_grow():
    state = {"params": np.ones((8, 3)), "batch_buf": np.arange(16.0)}
    small = reshard_state(state, old_data=4, new_data=2,
                          batch_linked=("batch_buf",))
    assert small["batch_buf"].shape[0] == 8
    np.testing.assert_array_equal(small["params"], state["params"])
    big = reshard_state(state, old_data=4, new_data=8,
                        batch_linked=("batch_buf",))
    assert big["batch_buf"].shape[0] == 32


def test_compression_error_feedback_preserves_signal():
    """Int8 EF compression: accumulated updates track the true sum closely."""
    key = jax.random.PRNGKey(0)
    true_sum = jnp.zeros((256,))
    sent_sum = jnp.zeros((256,))
    residual = {"g": jnp.zeros((256,))}
    for i in range(30):
        g = {"g": jax.random.normal(jax.random.fold_in(key, i), (256,)) * (1 + i % 3)}
        comp, residual = ef_compress_update(g, residual)
        true_sum = true_sum + g["g"]
        sent_sum = sent_sum + comp["g"]
    err = float(jnp.linalg.norm(true_sum - sent_sum) / jnp.linalg.norm(true_sum))
    assert err < 0.02, f"error-feedback drift too large: {err}"


def test_adamw_trains_quadratic():
    params = {"w": jnp.array([3.0, -2.0, 1.5])}
    opt = adamw_init(params)
    loss = lambda p: jnp.sum(jnp.square(p["w"]))
    l0 = float(loss(params))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(g, opt, params, lr=3e-2, weight_decay=0.0)
    assert float(loss(params)) < 1e-2 * l0


def test_adamw_clips_gradients():
    params = {"w": jnp.zeros((4,))}
    opt = adamw_init(params)
    g = {"w": jnp.full((4,), 1e6)}
    _, _, gnorm = adamw_update(g, opt, params, lr=0.0, max_norm=1.0)
    assert float(gnorm) > 1e5  # reported pre-clip norm

"""Deterministic fallback stand-in for `hypothesis` (tests-only).

The tier-1 suite property-tests with hypothesis, but hermetic containers may
not ship it (CI installs the real package via the ``test`` extra in
pyproject.toml). ``tests/conftest.py`` registers this module under the name
``hypothesis`` ONLY when the real package is missing, so collection never
breaks on the import.

It implements just the surface the suite uses — ``given``, ``settings``,
``strategies.{integers, floats, booleans, sampled_from, lists, composite}`` —
drawing a fixed number of deterministic pseudo-random samples per test, so
property tests still exercise many cases instead of being skipped wholesale.
No shrinking, no database, no health checks.
"""

from __future__ import annotations

import functools
import inspect
import random
import types

DEFAULT_MAX_EXAMPLES = 20

__version__ = "0.0.0-fallback"


class _Strategy:
    """A strategy is just `example(rng) -> value` here."""

    def __init__(self, sample):
        self._sample = sample

    def example(self, rng: random.Random):
        return self._sample(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float, max_value: float, **_kwargs) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5)


def sampled_from(options) -> _Strategy:
    opts = list(options)
    return _Strategy(lambda rng: opts[rng.randrange(len(opts))])


def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10,
          **_kwargs) -> _Strategy:
    return _Strategy(lambda rng: [elements.example(rng)
                                  for _ in range(rng.randint(min_size,
                                                             max_size))])


def composite(fn):
    def build(*args, **kwargs):
        def sample(rng):
            return fn(lambda strat: strat.example(rng), *args, **kwargs)
        return _Strategy(sample)
    return build


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, **_ignored):
    """Records max_examples; every other hypothesis knob is ignored."""
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def assume(condition) -> bool:
    """Real hypothesis retries; here a failed assumption just passes the case."""
    if not condition:
        raise _AssumptionFailed()
    return True


class _AssumptionFailed(Exception):
    pass


def given(**kw_strategies):
    """Run the test once per deterministic example (keyword strategies only)."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = (getattr(wrapper, "_fallback_max_examples", None)
                 or getattr(fn, "_fallback_max_examples", None)
                 or DEFAULT_MAX_EXAMPLES)
            for i in range(n):
                rng = random.Random(0xC0FFEE ^ (i * 2654435761))
                drawn = {k: s.example(rng) for k, s in kw_strategies.items()}
                try:
                    fn(*args, **{**kwargs, **drawn})
                except _AssumptionFailed:
                    continue

        # pytest must not mistake the drawn parameters for fixtures: expose a
        # signature with them removed (inspect stops unwrapping at
        # __signature__, so the @wraps __wrapped__ chain is not followed).
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(
            parameters=[p for name, p in sig.parameters.items()
                        if name not in kw_strategies])
        return wrapper
    return deco


# `from hypothesis import strategies as st` resolves this attribute; conftest
# additionally registers it as sys.modules["hypothesis.strategies"].
strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = integers
strategies.floats = floats
strategies.booleans = booleans
strategies.sampled_from = sampled_from
strategies.lists = lists
strategies.composite = composite

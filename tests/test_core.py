"""CommonGraph core: KS/DH/DHB/WS equivalence, TG plan properties, Table-1 sanity."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    SnapshotStore,
    bisection_plan,
    direct_hop_plan,
    optimal_plan,
    plan_added_edges,
    run_direct_hop,
    run_direct_hop_batched,
    run_kickstarter_stream,
    run_plan,
)
from repro.graph import make_evolving_sequence, run_to_fixpoint
from repro.graph.semiring import ALL_SEMIRINGS


@pytest.fixture(scope="module")
def store():
    seq = make_evolving_sequence(400, 3000, 6, 200, seed=7)
    return SnapshotStore(seq, granule=256)


@pytest.mark.parametrize("alg", list(ALL_SEMIRINGS))
def test_all_modes_match_scratch(store, alg):
    sr = ALL_SEMIRINGS[alg]
    n_snap = store.seq.num_snapshots
    scratch = [run_to_fixpoint(store.snapshot_view(i), sr, 0).values
               for i in range(n_snap)]
    ks, _ = run_kickstarter_stream(store, sr, 0)
    dh = run_direct_hop(store, sr, 0)
    dhb = run_direct_hop_batched(store, sr, 0)
    ws = run_plan(store, optimal_plan(store), sr, 0)
    for i in range(n_snap):
        for label, got in (("ks", ks[i]), ("dh", dh.results[i]),
                           ("dhb", dhb.results[i]), ("ws", ws.results[i])):
            np.testing.assert_allclose(np.asarray(got), np.asarray(scratch[i]),
                                       rtol=1e-6, err_msg=f"{label}/{alg}/{i}")


def test_window_nesting(store):
    """Wider windows give subgraphs: |T(i,j)| decreasing in window width."""
    n = store.seq.num_snapshots
    for i in range(n):
        for j in range(i, n - 1):
            assert store.window_size(i, j + 1) <= store.window_size(i, j)
            inner = store.window_keys(i, j)
            outer = store.window_keys(i, j + 1)
            assert np.intersect1d(outer, inner).size == outer.size  # ⊆


def test_plan_leaves_cover_all_snapshots(store):
    n = store.seq.num_snapshots
    for plan in (optimal_plan(store), bisection_plan(n=n), direct_hop_plan(n=n)):
        leaves = sorted(w[0] for w in plan.leaves())
        assert leaves == list(range(n))


def test_optimal_plan_dominates(store):
    n = store.seq.num_snapshots
    opt = plan_added_edges(store, optimal_plan(store))
    bis = plan_added_edges(store, bisection_plan(n=n))
    dh = plan_added_edges(store, direct_hop_plan(n=n))
    assert opt <= bis <= dh or opt <= dh  # optimal never loses


def test_delta_volume_identity(store):
    """|Δ(parent→child)| == |T(child)| − |T(parent)| (nested windows)."""
    for parent, child in (((0, 5), (0, 2)), ((0, 5), (3, 5)), ((0, 2), (1, 1))):
        dk = store.delta_keys(parent, child)
        assert dk.shape[0] == (store.window_size(*child)
                               - store.window_size(*parent))


def test_kickstarter_taints_on_parent_deletion(store):
    """Trim must fire when a dependence parent edge is deleted."""
    _, stats = run_kickstarter_stream(store, ALL_SEMIRINGS["sssp"], 0)
    assert any(s.tainted > 0 for s in stats[1:])  # deletions hit used edges


@given(seed=st.integers(0, 1000))
@settings(max_examples=5, deadline=None)
def test_ws_exact_on_random_sequences(seed):
    seq = make_evolving_sequence(150, 900, 4, 80, seed=seed)
    store = SnapshotStore(seq, granule=128)
    sr = ALL_SEMIRINGS["sswp"]
    ws = run_plan(store, optimal_plan(store), sr, 0)
    for i in range(4):
        ref = run_to_fixpoint(store.snapshot_view(i), sr, 0).values
        np.testing.assert_allclose(np.asarray(ws.results[i]), np.asarray(ref),
                                   rtol=1e-6)


def test_sliding_window_hop(store):
    """Sliding [0..3] -> [1..4]: hop from the global apex state, exactness.

    The old window apex is NOT a valid warm start (T(0,3) ⊄ T(1,4)); the
    global CG is (it is a subgraph of every window's CG).
    """
    from repro.graph import EdgeView, incremental_additions
    sr = ALL_SEMIRINGS["sssp"]
    old_keys = store.window_keys(0, 3)
    new_keys = store.window_keys(1, 4)
    # demonstrate the subtlety the implementation guards against:
    assert np.setdiff1d(old_keys, new_keys).size > 0  # old apex ⊄ new apex
    apex = store.common_graph_view()
    base = run_to_fixpoint(apex, sr, 0)
    delta = store.slide_block((1, 4))
    view = apex.extended(delta)
    hop = incremental_additions(view, delta, sr, base.values, base.parent)
    # reference: from-scratch on the new window's CG
    ref = run_to_fixpoint(
        EdgeView((store.window_block(1, 4),), store.num_nodes), sr, 0)
    np.testing.assert_allclose(np.asarray(hop.values), np.asarray(ref.values),
                               rtol=1e-6)


def test_slide_block_rejects_non_nested():
    seq = make_evolving_sequence(100, 600, 5, 40, seed=21)
    s = SnapshotStore(seq, granule=64)
    with pytest.raises(ValueError):
        s.slide_block((1, 4), anchor=(2, 3))  # anchor not a super-window


def test_window_keys_long_sequence_iterative():
    """A cold T(0, n−1) on a 3000-snapshot keys-only sequence must not hit
    the recursion limit (the old window_keys recursed once per snapshot)."""
    import sys

    from repro.graph import EvolvingSequence
    n_snap = 3000
    common = np.arange(64, dtype=np.int64)
    snaps = tuple(np.sort(np.concatenate([common, [np.int64(64 + k % 7)]]))
                  for k in range(n_snap))
    store = SnapshotStore(EvolvingSequence(num_nodes=100, snapshot_keys=snaps,
                                           additions=(), deletions=()))
    assert n_snap > sys.getrecursionlimit() // 2
    np.testing.assert_array_equal(store.window_keys(0, n_snap - 1), common)
    # intermediate prefixes are cached by the left-to-right build
    np.testing.assert_array_equal(store.window_keys(0, n_snap // 2), common)


def test_optimal_plan_is_nonrecursive():
    """Bottom-up interval DP: the plan (cost, split, AND tree build) must
    not consume stack proportional to the snapshot count."""
    import inspect
    import sys
    seq = make_evolving_sequence(80, 400, 40, 20, seed=9)
    store = SnapshotStore(seq, granule=64)
    store.window_keys(0, 39)  # pre-warm the prefix cache outside the limit
    limit = sys.getrecursionlimit()
    try:
        sys.setrecursionlimit(len(inspect.stack()) + 30)
        plan = optimal_plan(store)
    finally:
        sys.setrecursionlimit(limit)
    assert sorted(w[0] for w in plan.leaves()) == list(range(40))


def test_plan_constructors_require_j_or_n():
    """j=None + n=None used to crash with an opaque TypeError on n - 1."""
    with pytest.raises(ValueError, match="either j= or n="):
        bisection_plan()
    with pytest.raises(ValueError, match="either j= or n="):
        direct_hop_plan()
    # explicit j (or n) still works, including the i == j degenerate plan
    assert bisection_plan(j=3).window == (0, 3)
    assert direct_hop_plan(n=1).window == (0, 0)

"""Semiring algebra + fixpoint vs a pure-python oracle (unit + property)."""

import heapq

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.graph import EdgeView, run_to_fixpoint
from repro.graph.edgeset import make_block
from repro.graph.semiring import ALL_SEMIRINGS, SSSP


def dijkstra_like(n, edges, sr, source):
    """Generic best-path oracle over a monotone semiring (heap order by reduce)."""
    sign = 1.0 if sr.is_min else -1.0
    dist = {v: sr.identity for v in range(n)}
    dist[source] = sr.source_value
    heap = [(sign * sr.source_value, source)]
    adj = {}
    for (u, v, w) in edges:
        adj.setdefault(u, []).append((v, w))
    seen = set()
    while heap:
        d, u = heapq.heappop(heap)
        if u in seen:
            continue
        seen.add(u)
        for v, w in adj.get(u, []):
            cand = float(sr.combine(jnp.float32(dist[u]), jnp.float32(w)))
            if (cand < dist[v]) if sr.is_min else (cand > dist[v]):
                dist[v] = cand
                heapq.heappush(heap, (sign * cand, v))
    return np.array([dist[v] for v in range(n)], np.float32)


@st.composite
def small_graph(draw):
    n = draw(st.integers(4, 24))
    m = draw(st.integers(1, 60))
    edges = []
    for _ in range(m):
        u = draw(st.integers(0, n - 1))
        v = draw(st.integers(0, n - 1))
        if u != v:
            w = draw(st.floats(0.0625, 1.0, allow_nan=False, width=32))
            edges.append((u, v, round(w, 3)))
    return n, list(dict.fromkeys(edges))


@pytest.mark.parametrize("alg", list(ALL_SEMIRINGS))
@given(g=small_graph())
@settings(max_examples=15, deadline=None)
def test_fixpoint_matches_oracle(alg, g):
    n, edges = g
    sr = ALL_SEMIRINGS[alg]
    if not edges:
        return
    src = np.array([e[0] for e in edges], np.int32)
    dst = np.array([e[1] for e in edges], np.int32)
    w = np.array([e[2] for e in edges], np.float32)
    blk = make_block(src, dst, w, n, granule=16)
    res = run_to_fixpoint(EdgeView((blk,), n), sr, 0)
    ref = dijkstra_like(n, edges, sr, 0)
    got = np.asarray(res.values)
    fin = np.isfinite(ref)
    np.testing.assert_array_equal(np.isfinite(got), fin)
    np.testing.assert_allclose(got[fin], ref[fin], rtol=1e-5)


def test_semiring_identities():
    # combine(identity, w) must be absorbing (never better than identity)
    for sr in ALL_SEMIRINGS.values():
        out = sr.combine(jnp.float32(sr.identity), jnp.float32(0.5))
        assert not bool(sr.strictly_better(out, jnp.float32(sr.identity))), sr.name


def test_source_anchoring_is_extremal():
    # source_value must already be the best possible value
    for sr in ALL_SEMIRINGS.values():
        w = jnp.float32(0.5)
        via = sr.combine(jnp.float32(sr.source_value), w)
        assert not bool(sr.strictly_better(via, jnp.float32(sr.source_value))), sr.name


def test_parent_forest_is_consistent():
    n, e = 200, 1200
    rng = np.random.default_rng(1)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    w = (rng.random(src.shape[0]).astype(np.float32) + 0.05)
    blk = make_block(src, dst, w, n, granule=256)
    res = run_to_fixpoint(EdgeView((blk,), n), SSSP, 0)
    vals = np.asarray(res.values)
    par = np.asarray(res.parent)
    emap = {}
    for s, d, ww in zip(src, dst, w):
        emap[(int(s), int(d))] = min(emap.get((int(s), int(d)), np.inf), float(ww))
    for v in range(n):
        if par[v] >= 0:
            assert np.isfinite(vals[v])
            assert (par[v], v) in emap
            np.testing.assert_allclose(vals[v], vals[par[v]] + emap[(par[v], v)],
                                       rtol=1e-5)

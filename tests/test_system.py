"""End-to-end behaviour tests for the paper's system (drivers + integration)."""

import pathlib

import numpy as np

from repro.core import (
    SnapshotStore,
    optimal_plan,
    run_direct_hop,
    run_kickstarter_stream,
    run_plan,
)
from repro.graph import make_evolving_sequence, run_to_fixpoint
from repro.graph.semiring import ALL_SEMIRINGS


def test_evolving_window_end_to_end():
    """The paper's pipeline: generate -> store -> KS/DH/WS -> identical answers."""
    seq = make_evolving_sequence(600, 5000, 5, 300, seed=13)
    store = SnapshotStore(seq, granule=512)
    for alg in ("bfs", "viterbi"):
        sr = ALL_SEMIRINGS[alg]
        ks, stats = run_kickstarter_stream(store, sr, 0)
        dh = run_direct_hop(store, sr, 0)
        ws = run_plan(store, optimal_plan(store), sr, 0)
        for i in range(5):
            ref = run_to_fixpoint(store.snapshot_view(i), sr, 0).values
            np.testing.assert_allclose(np.asarray(ks[i]), np.asarray(ref), rtol=1e-6)
            np.testing.assert_allclose(np.asarray(dh.results[i]), np.asarray(ref), rtol=1e-6)
            np.testing.assert_allclose(np.asarray(ws.results[i]), np.asarray(ref), rtol=1e-6)
        # the deletion-free schedules must stream strictly less relaxation
        # work than the baseline spends on trim + re-converge transitions
        ks_work = sum(s.edge_work for s in stats[1:])
        dh_work = sum(h.edge_work for h in dh.hop_stats)
        assert dh_work < ks_work


def test_train_driver_loss_decreases():
    from repro.launch import train as train_mod
    losses = train_mod.main(["--arch", "stablelm-1.6b", "--reduced",
                             "--steps", "6", "--batch", "4", "--seq", "32"])
    assert losses[-1] < losses[0]


def test_train_driver_checkpoint_resume(tmp_path):
    from repro.launch import train as train_mod
    d = str(tmp_path / "ck")
    train_mod.main(["--arch", "gcn-cora", "--reduced", "--steps", "4",
                    "--ckpt-dir", d, "--ckpt-every", "2"])
    # resume continues from the step-4 checkpoint without error
    losses = train_mod.main(["--arch", "gcn-cora", "--reduced", "--steps", "6",
                             "--ckpt-dir", d, "--ckpt-every", "2", "--resume"])
    assert len(losses) >= 1


def test_serve_driver_generates():
    from repro.launch import serve as serve_mod
    out = serve_mod.main(["--arch", "stablelm-1.6b", "--reduced", "--batch", "2",
                          "--prompt-len", "8", "--decode-steps", "4"])
    assert out.shape == (2, 4)


def test_evolve_driver_cli():
    from repro.launch import evolve as evolve_mod
    evolve_mod.main(["--nodes", "400", "--edges", "2500", "--snapshots", "4",
                     "--changes", "200", "--alg", "sswp", "--verify"])


def test_dryrun_module_has_flag_first():
    """The XLA device-count override must precede every import (spec)."""
    src = pathlib.Path("src/repro/launch/dryrun.py").read_text()
    first_code = [ln for ln in src.splitlines() if ln and not ln.startswith("#")]
    assert first_code[0] == "import os"
    assert "xla_force_host_platform_device_count=512" in first_code[1]
    idx_flag = src.index("XLA_FLAGS")
    assert idx_flag < src.index("import jax")
    assert idx_flag < src.index("from repro")

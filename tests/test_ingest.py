"""Live-ingestion contract (core/ingest.py).

Covers the acceptance criteria of the ingestion PR: a replayed edge
firehose is bit-identical to its precomputed sequence (structure AND
query values across all five semirings), watermark cuts obey
last-op-wins / sealing / monotonicity, the three backpressure policies
meter what they promise, the running common graph is maintained online,
and compaction strictly shrinks storage while respecting window-feed
floors. Feed wiring into WindowStream and QueryService is covered here;
the pinned-"AS" compaction audit lives in tests/test_window_stream.py.
"""

import numpy as np
import pytest

from repro.core import (
    BackpressureStall,
    EdgeEvent,
    EdgeLog,
    IngestMetrics,
    LiveSequence,
    LiveWindowFeed,
    QueryService,
    SnapshotStore,
    Watermark,
    WindowStream,
    events_from_sequence,
    replay_events,
    run_window_slide_batched,
    run_window_stream_batched,
)
from repro.graph import make_evolving_sequence
from repro.graph.semiring import ALL_SEMIRINGS


def _seq(n=200, e=1400, snaps=5, changes=100, seed=11):
    return make_evolving_sequence(n, e, snaps, changes, seed=seed)


def _live(num_nodes, weight_seed=0, **log_kw):
    """Fresh (store, log, watermark) triple over an empty live sequence."""
    store = SnapshotStore(LiveSequence(num_nodes, weight_seed=weight_seed))
    log = EdgeLog(num_nodes, metrics=IngestMetrics(), **log_kw)
    return store, log, Watermark(log, store)


def _replayed(seq, **log_kw):
    store, log, wm = _live(seq.num_nodes, seq.weight_seed, **log_kw)
    cuts = replay_events(log, wm, events_from_sequence(seq))
    return store, wm, cuts


# -- replay bit-identity (the PR's acceptance bar) ----------------------------

def test_replay_bit_identical_structure():
    """Snapshots + canonical Δ pairs cut from the firehose equal the
    precomputed sequence exactly, with zero redundancy or loss."""
    seq = _seq()
    store, wm, cuts = _replayed(seq)
    assert cuts == list(range(seq.num_snapshots))
    for i in range(seq.num_snapshots):
        np.testing.assert_array_equal(store.seq.snapshot_keys[i],
                                      seq.snapshot_keys[i])
    for t in range(seq.num_snapshots - 1):
        np.testing.assert_array_equal(store.seq.additions[t],
                                      seq.additions[t])
        np.testing.assert_array_equal(store.seq.deletions[t],
                                      seq.deletions[t])
    m = wm.metrics
    assert m.cuts == seq.num_snapshots
    assert m.late_events == m.dropped == m.stalls == m.redundant_events == 0
    assert m.applied_additions == sum(len(a) for a in seq.additions) \
        + len(seq.snapshot_keys[0])
    assert m.applied_deletions == sum(len(d) for d in seq.deletions)


@pytest.mark.parametrize("alg", sorted(ALL_SEMIRINGS))
def test_replay_values_bit_identical_all_semirings(alg):
    """Query values over the replayed store equal the precomputed-input
    path bit-for-bit — same keys, same hash weights, same fixpoints."""
    seq = _seq(n=150, e=1000, snaps=4)
    live, _, _ = _replayed(seq)
    ref = SnapshotStore(seq)
    sr = ALL_SEMIRINGS[alg]
    a = run_window_slide_batched(live, sr, 0, 2)
    b = run_window_slide_batched(ref, sr, 0, 2)
    assert list(a.results) == list(b.results)
    for wnd in b.results:
        np.testing.assert_array_equal(np.asarray(a.results[wnd]),
                                      np.asarray(b.results[wnd]))


def test_online_common_graph_matches_batch_intersection():
    """The incrementally shrunk common graph equals the batch T(0, n-1)
    and is installed in the window cache; total shrinkage telescopes to
    |S_0| - |T(0, n-1)|."""
    seq = _seq()
    live, wm, _ = _replayed(seq)
    ref = SnapshotStore(seq)
    last = seq.num_snapshots - 1
    expected = ref.window_keys(0, last)
    np.testing.assert_array_equal(live._t[(0, last)], expected)
    assert wm.metrics.common_shrinkage == \
        len(seq.snapshot_keys[0]) - len(expected)


# -- EdgeLog: validation, ticks, lateness, backpressure -----------------------

def test_edge_log_validation():
    with pytest.raises(ValueError):
        EdgeLog(10, policy="shed")
    with pytest.raises(ValueError):
        EdgeLog(10, max_pending_events=0)
    log = EdgeLog(10)
    with pytest.raises(ValueError):
        log.append(0, 1, op="toggle")
    with pytest.raises(ValueError):
        log.append(0, 10)


def test_default_ts_follows_latest_stamp():
    """ts=None events belong to the current tick — the latest stamped ts."""
    log = EdgeLog(10)
    assert log.append(0, 1).ts == 0
    log.append(1, 2, ts=5)
    assert log.append(2, 3).ts == 5
    assert log.pending_events() == 3


def test_late_events_rejected_after_seal():
    store, log, wm = _live(10)
    log.append(0, 1, ts=3)
    assert wm.advance(3).cut() == 0
    assert log.append(1, 2, ts=3) is None          # at the seal: late
    assert log.append(1, 2, ts=2) is None          # below it: late
    assert log.metrics.late_events == 2
    ev = log.append(1, 2, ts=4)                    # above it: accepted
    assert ev is not None
    assert log.extend([EdgeEvent(2, 3, 4), EdgeEvent(4, 3, 4)]) == 1


def test_block_policy_stalls_until_cut():
    store, log, wm = _live(10, max_pending_events=2, policy="block")
    log.append(0, 1)
    log.append(1, 2)
    with pytest.raises(BackpressureStall):
        log.append(2, 3)
    assert log.metrics.stalls == 1
    assert log.metrics.events == 2                 # the stalled event is not in
    wm.advance(0).cut()                            # cut empties the buffer
    assert log.append(2, 3, ts=1) is not None


def test_drop_policy_is_lossy_and_metered():
    store, log, wm = _live(10, max_pending_events=2, policy="drop")
    log.append(0, 1)
    log.append(1, 2)
    assert log.append(2, 3) is None
    assert log.metrics.dropped == 1 and log.metrics.events == 2
    assert log.pending_events() == 2


def test_spill_policy_is_lossless_and_deterministic():
    """A tiny spill buffer replays any trace to the same snapshots as an
    unbounded log — spilled events rejoin in (ts, arrival) order."""
    seq = _seq(n=80, e=300, snaps=4, changes=40)
    free, _, _ = _replayed(seq)
    tight_store, tight_log, tight_wm = _live(seq.num_nodes, seq.weight_seed,
                                             max_pending_events=16,
                                             policy="spill")
    replay_events(tight_log, tight_wm, events_from_sequence(seq))
    assert tight_log.metrics.spilled > 0
    for i in range(seq.num_snapshots):
        np.testing.assert_array_equal(tight_store.seq.snapshot_keys[i],
                                      free.seq.snapshot_keys[i])


# -- Watermark: guards, last-op-wins, sealing ---------------------------------

def test_watermark_guards():
    store, log, wm = _live(10)
    with pytest.raises(ValueError):
        wm.cut()                                   # advance first
    wm.advance(4)
    with pytest.raises(ValueError):
        wm.advance(3)                              # no regressions
    assert wm.ts == 4
    assert wm.advance(4).cut() == 0                # first cut may be empty
    assert store.seq.snapshot_keys[0].shape == (0,)
    assert wm.advance(9).cut() is None             # empty cut: no duplicate


def test_cut_last_op_wins_and_meters_redundancy():
    store, log, wm = _live(10)
    log.append(0, 1, ts=0)
    log.append(0, 2, ts=0)
    assert wm.advance(0).cut() == 0
    log.append(0, 3, ts=1)                          # add then del: net del
    log.append(0, 3, op="del", ts=1)                # ... of an absent edge
    log.append(0, 1, op="del", ts=1)                # real deletion
    assert wm.advance(1).cut() == 1
    m = wm.metrics
    # one superseded add + one no-op del of the absent (0, 3)
    assert m.redundant_events == 2
    assert m.applied_deletions == 1
    assert store.seq.snapshot_keys[1].shape == (1,)  # only (0, 2) remains
    np.testing.assert_array_equal(store.seq.deletions[0],
                                  store.seq.snapshot_keys[0][:1])


def test_out_of_order_within_tick_is_timestamp_ordered():
    """Events may arrive out of ts order above the seal; the cut consumes
    them in (ts, arrival) order, so interleaved ticks still converge."""
    store, log, wm = _live(10)
    log.append(0, 1, ts=2)
    log.append(0, 1, op="del", ts=5)               # later tick wins
    log.append(0, 2, ts=4)
    assert wm.advance(5).cut() == 0
    keys = store.seq.snapshot_keys[0]
    assert keys.shape == (1,)                       # (0,1) added then deleted
    assert replay_events(EdgeLog(10), Watermark(EdgeLog(10), store),
                         []) == []
    with pytest.raises(ValueError):                 # replay needs sorted ts
        replay_events(*_live(10)[1:], [EdgeEvent(3, 0, 1), EdgeEvent(1, 0, 2)])


# -- compaction + floors ------------------------------------------------------

def test_compact_respects_feed_floor_then_retires():
    seq = _seq()
    store, wm, _ = _replayed(seq)
    feed = LiveWindowFeed(store, width=2, name="lagging")
    assert feed.poll() == [(i, i + 1) for i in range(seq.num_snapshots - 1)]
    stats = wm.compact()                            # floor 0: nothing retires
    assert stats.retired == 0 and store.first_live == 0
    feed.advance_floor(3)                           # consumer is at (3, 4)
    before = store.stored_edges
    stats = wm.compact()
    assert stats.retired == 3 and store.first_live == 3
    assert store.stored_edges < before              # strictly fewer edges
    assert wm.metrics.freed_edges == stats.freed_edges > 0
    store.window_keys(3, 4)                         # live range still serves
    with pytest.raises(ValueError):
        store.window_keys(2, 4)                     # retired range does not
    feed.close()
    assert wm.compact().horizon == seq.num_snapshots - 1


def test_cut_rebases_common_graph_after_compaction():
    """Compaction moves the live base; the next cut lazily rebases its
    running intersection to T(first_live, ·) and stays bit-identical."""
    seq = _seq(snaps=6)
    events = events_from_sequence(seq)
    split = next(i for i, ev in enumerate(events) if ev.ts == 4)
    store, log, wm = _live(seq.num_nodes, seq.weight_seed)
    replay_events(log, wm, events[:split])          # snapshots 0..3
    store.set_floor("consumer", 2)
    wm.compact()
    assert store.first_live == 2
    replay_events(log, wm, events[split:])          # snapshots 4, 5
    ref = SnapshotStore(seq)
    for i in range(2, seq.num_snapshots):
        np.testing.assert_array_equal(store.seq.snapshot_keys[i],
                                      seq.snapshot_keys[i])
    np.testing.assert_array_equal(store._t[(2, 5)], ref.window_keys(2, 5))


def test_frozen_store_rejects_live_operations():
    store = SnapshotStore(_seq(n=60, e=200, snaps=3, changes=30))
    empty = np.empty(0, np.int64)
    with pytest.raises(TypeError):
        store.ingest_cut(empty, empty, empty)
    with pytest.raises(TypeError):
        store.compact()


# -- feed wiring: WindowStream + QueryService ---------------------------------

def test_live_window_feed_validation_and_cursor():
    store, _, _ = _live(10)
    with pytest.raises(ValueError):
        LiveWindowFeed(store, width=0)
    with pytest.raises(ValueError):
        LiveWindowFeed(store, width=2, step=0)
    feed = LiveWindowFeed(store, width=2, name="f")
    assert feed.poll() == []                        # nothing born yet
    assert store._floors["f"] == 0
    feed.close()
    assert "f" not in store._floors


def test_window_stream_feed_serves_windows_as_cut():
    """A feed-driven WindowStream blocks on the watermark: windows appear
    in pending() as their last snapshot is cut, values stay bit-identical
    to the precomputed slide, and draining advances the feed's floor."""
    seq = _seq()
    sr = ALL_SEMIRINGS["sssp"]
    store, log, wm = _live(seq.num_nodes, seq.weight_seed)
    stream = WindowStream(campaign_width=2, name="live",
                          feed=LiveWindowFeed(store, width=3, name="live"))
    results = {}

    def on_cut(_idx):
        run = run_window_stream_batched(store, sr, 0, stream=stream)
        results.update(run.results)

    replay_events(log, wm, events_from_sequence(seq), on_cut=on_cut)
    ref = run_window_slide_batched(SnapshotStore(seq), sr, 0, 3)
    assert set(results) == set(ref.results)
    for wnd, vals in ref.results.items():
        np.testing.assert_array_equal(np.asarray(results[wnd]),
                                      np.asarray(vals))
    # fully drained: the floor parks at the next unborn window's low,
    # so compaction retires everything older than the live tail
    stats = wm.compact()
    assert stats.retired > 0
    assert store.first_live == store._floors["live"]


def test_query_service_feed_client_live():
    """register(feed=...) grows the client's horizon as snapshots are cut
    and serves born windows through the normal admission path."""
    seq = _seq()
    sr = ALL_SEMIRINGS["sssp"]
    store, log, wm = _live(seq.num_nodes, seq.weight_seed)
    service = QueryService(store)
    client = service.register(
        sr, 0, campaign_width=2, name="live",
        feed=LiveWindowFeed(store, width=3, name="live"))
    replay_events(log, wm, events_from_sequence(seq),
                  on_cut=lambda _idx: service.turn())
    service.drain()
    assert client.horizon == seq.num_snapshots - 1
    ref = run_window_slide_batched(SnapshotStore(seq), sr, 0, 3)
    assert set(client.results) == set(ref.results)
    for wnd, vals in ref.results.items():
        np.testing.assert_array_equal(np.asarray(client.results[wnd]),
                                      np.asarray(vals))
    service.unregister(client)
    assert "live" not in store._floors              # unregister closes the feed

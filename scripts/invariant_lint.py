#!/usr/bin/env python3
"""graphlint CLI: AST-enforced launch/cache/sharding invariants.

Runs the ``repro.analysis`` rules (see docs/ANALYSIS.md for the catalog)
over source trees and exits non-zero on any finding — the CI
``invariant-lint`` job runs ``--format json`` over ``src/``. Stdlib-only:
rules read source with ``ast``, they never import or execute the code
under analysis, so this needs no installed dependencies.

    python scripts/invariant_lint.py                     # lint src/
    python scripts/invariant_lint.py --format json src
    python scripts/invariant_lint.py --select G002,G004 src/repro/core
    python scripts/invariant_lint.py --list-rules
"""

from __future__ import annotations

import argparse
import pathlib
import sys
import textwrap

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

from repro.analysis import (  # noqa: E402  (path bootstrap above)
    Linter,
    all_rules,
    get_rule,
    render_human,
    render_json,
)


def list_rules() -> str:
    blocks = []
    for rule in all_rules():
        contract = textwrap.fill(rule.contract, width=76,
                                 initial_indent="    ",
                                 subsequent_indent="    ")
        blocks.append(f"{rule.id}  {rule.title}\n{contract}")
    return "\n\n".join(blocks)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="invariant_lint.py",
        description="graphlint: static AST checks for the repo's "
                    "launch/cache/sharding contracts")
    p.add_argument("paths", nargs="*", type=pathlib.Path,
                   default=[REPO / "src"],
                   help="files or directories to lint (default: src/)")
    p.add_argument("--format", choices=("human", "json"), default="human",
                   help="output format (json is what CI consumes)")
    p.add_argument("--select", metavar="IDS",
                   help="comma-separated rule ids to run (default: all)")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule catalog and exit")
    args = p.parse_args(argv)

    if args.list_rules:
        print(list_rules())
        return 0

    rules = None
    if args.select:
        try:
            rules = [get_rule(rid.strip())
                     for rid in args.select.split(",") if rid.strip()]
        except KeyError as e:
            p.error(str(e.args[0]))
    linter = Linter(rules=rules)
    findings = linter.lint(args.paths)
    render = render_json if args.format == "json" else render_human
    print(render(findings, linter.files_checked))
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())

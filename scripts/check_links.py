#!/usr/bin/env python3
"""Docs drift gate: markdown links, orphan pages + embedded --help.

Stdlib-only (runs in CI's docs job before anything is installed). Three
checks, all on by default:

* **Links.** For each markdown file checked, every relative link target
  must exist on disk, and every ``#fragment`` — on another checked
  markdown file or within the same file — must match a heading's
  GitHub-style anchor. External links (http/https/mailto) are ignored.
* **Orphans** (default file set only). Every ``docs/*.md`` must be
  reachable from README.md by following relative markdown links — a
  guide nobody links from the docs index is invisible to readers, so
  shipping one fails CI until the index row exists.
* **Embedded --help** (when docs/BENCHMARKS.md is among the files). The
  fenced block under the ``<!-- bench-gate-help -->`` marker must equal
  ``scripts/bench_gate.py --help`` verbatim (COLUMNS=80), so the
  documented CLI can't drift from the real one.

The API-reference drift check (docs/API.md entries vs the public ast
surface of the documented modules) that used to live here is now
graphlint rule G006 — ``scripts/invariant_lint.py`` / docs/ANALYSIS.md —
so the docs and invariant gates share one source of truth.

    python scripts/check_links.py [files...]   # default: README.md docs/*.md
"""

from __future__ import annotations

import os
import pathlib
import re
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
# [text](target) — skips images' leading ! via the (?<!\!) guard
LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def github_anchor(heading: str) -> str:
    """GitHub's heading → anchor slug (lowercase, spaces→dashes, strip
    punctuation except dashes/underscores)."""
    heading = re.sub(r"`([^`]*)`", r"\1", heading).strip().lower()
    heading = re.sub(r"[^\w\- ]", "", heading, flags=re.UNICODE)
    return heading.replace(" ", "-")


def anchors_of(md: pathlib.Path) -> set[str]:
    """All anchors the file's headings define, with GitHub's -1/-2 suffixes
    for repeated headings."""
    text = CODE_FENCE_RE.sub("", md.read_text(encoding="utf-8"))
    seen: dict[str, int] = {}
    anchors = set()
    for h in HEADING_RE.findall(text):
        slug = github_anchor(h)
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        anchors.add(slug if n == 0 else f"{slug}-{n}")
    return anchors


def rel(p: pathlib.Path) -> str:
    try:
        return str(p.relative_to(REPO))
    except ValueError:
        return str(p)


def check(files: list[pathlib.Path]) -> list[str]:
    errors = []
    for md in files:
        text = CODE_FENCE_RE.sub("", md.read_text(encoding="utf-8"))
        for target in LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, fragment = target.partition("#")
            dest = (md.parent / path_part).resolve() if path_part else md
            if not dest.exists():
                errors.append(f"{rel(md)}: broken link "
                              f"'{target}' ({dest} does not exist)")
                continue
            if fragment and dest.suffix == ".md" \
                    and github_anchor(fragment) not in anchors_of(dest):
                errors.append(f"{rel(md)}: anchor "
                              f"'#{fragment}' not found in {rel(dest)}")
    return errors


# -- orphan pages (docs/*.md unreachable from README.md) ----------------------


def md_targets(md: pathlib.Path) -> set[pathlib.Path]:
    """Resolved .md files ``md`` links to (relative links only)."""
    text = CODE_FENCE_RE.sub("", md.read_text(encoding="utf-8"))
    out = set()
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, _ = target.partition("#")
        if not path_part:
            continue
        dest = (md.parent / path_part).resolve()
        if dest.suffix == ".md" and dest.exists():
            out.add(dest)
    return out


def check_orphans(root: pathlib.Path, pages: list[pathlib.Path]) -> list[str]:
    """Pages not reachable from ``root`` via relative markdown links."""
    reached, frontier = {root}, [root]
    while frontier:
        for dest in md_targets(frontier.pop()):
            if dest not in reached:
                reached.add(dest)
                frontier.append(dest)
    return [f"{rel(p)}: orphan page — not reachable from {rel(root)} "
            "(add it to the README docs index)"
            for p in pages if p not in reached]


# -- embedded --help drift (docs/BENCHMARKS.md vs scripts/bench_gate.py) ------

HELP_MARKER = "<!-- bench-gate-help -->"
HELP_CMD = ("scripts/bench_gate.py", "--help")


def embedded_help_block(text: str) -> "str | None":
    """The first fenced block after HELP_MARKER (None when absent)."""
    _, found, after = text.partition(HELP_MARKER)
    if not found:
        return None
    m = re.search(r"```[^\n]*\n(.*?)```", after, re.DOTALL)
    return m.group(1) if m else None


def check_embedded_help(md: pathlib.Path) -> list[str]:
    embedded = embedded_help_block(md.read_text(encoding="utf-8"))
    if embedded is None:
        return [f"{rel(md)}: marker {HELP_MARKER!r} with a fenced help "
                "block not found"]
    proc = subprocess.run(
        [sys.executable, str(REPO / HELP_CMD[0]), *HELP_CMD[1:]],
        capture_output=True, text=True, cwd=REPO,
        env={**os.environ, "COLUMNS": "80"},   # argparse wraps to COLUMNS
    )
    if proc.returncode != 0:
        reason = (proc.stderr.strip().splitlines()[-1]
                  if proc.stderr.strip() else "no stderr")
        return [f"{rel(md)}: `{' '.join(HELP_CMD)}` exited "
                f"{proc.returncode} — cannot compare the embedded help "
                f"block ({reason})"]
    actual = proc.stdout
    if embedded.strip() != actual.strip():
        return [f"{rel(md)}: embedded `{' '.join(HELP_CMD)}` output is stale "
                "— re-paste the current --help into the fenced block under "
                f"{HELP_MARKER!r}"]
    return []


def main(argv: list[str]) -> int:
    files = ([pathlib.Path(a).resolve() for a in argv]
             if argv else
             [REPO / "README.md", *sorted((REPO / "docs").glob("*.md"))])
    missing = [f for f in files if not f.exists()]
    for f in missing:
        print(f"MISSING FILE: {f}", file=sys.stderr)
    present = [f for f in files if f.exists()]
    errors = check(present)
    if not argv:   # default set: README must index every docs page
        errors += check_orphans(REPO / "README.md",
                                [f for f in present
                                 if f.parent == REPO / "docs"])
    if REPO / "docs" / "BENCHMARKS.md" in present:
        errors += check_embedded_help(REPO / "docs" / "BENCHMARKS.md")
    for e in errors:
        print(f"BROKEN: {e}", file=sys.stderr)
    if missing or errors:
        return 1
    print(f"checked {len(files)} files: links, page reachability and "
          "embedded --help all in sync")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))

#!/usr/bin/env python3
"""Check that intra-repo links in docs/*.md and README.md resolve.

Stdlib-only (runs in CI's docs job before anything is installed). For each
markdown file checked, every relative link target must exist on disk, and
every ``#fragment`` — on another checked markdown file or within the same
file — must match a heading's GitHub-style anchor. External links
(http/https/mailto) are ignored.

    python scripts/check_links.py [files...]   # default: README.md docs/*.md
"""

from __future__ import annotations

import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
# [text](target) — skips images' leading ! via the (?<!\!) guard
LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def github_anchor(heading: str) -> str:
    """GitHub's heading → anchor slug (lowercase, spaces→dashes, strip
    punctuation except dashes/underscores)."""
    heading = re.sub(r"`([^`]*)`", r"\1", heading).strip().lower()
    heading = re.sub(r"[^\w\- ]", "", heading, flags=re.UNICODE)
    return heading.replace(" ", "-")


def anchors_of(md: pathlib.Path) -> set[str]:
    """All anchors the file's headings define, with GitHub's -1/-2 suffixes
    for repeated headings."""
    text = CODE_FENCE_RE.sub("", md.read_text(encoding="utf-8"))
    seen: dict[str, int] = {}
    anchors = set()
    for h in HEADING_RE.findall(text):
        slug = github_anchor(h)
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        anchors.add(slug if n == 0 else f"{slug}-{n}")
    return anchors


def rel(p: pathlib.Path) -> str:
    try:
        return str(p.relative_to(REPO))
    except ValueError:
        return str(p)


def check(files: list[pathlib.Path]) -> list[str]:
    errors = []
    for md in files:
        text = CODE_FENCE_RE.sub("", md.read_text(encoding="utf-8"))
        for target in LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, fragment = target.partition("#")
            dest = (md.parent / path_part).resolve() if path_part else md
            if not dest.exists():
                errors.append(f"{rel(md)}: broken link "
                              f"'{target}' ({dest} does not exist)")
                continue
            if fragment and dest.suffix == ".md":
                if github_anchor(fragment) not in anchors_of(dest):
                    errors.append(f"{rel(md)}: anchor "
                                  f"'#{fragment}' not found in {rel(dest)}")
    return errors


def main(argv: list[str]) -> int:
    files = ([pathlib.Path(a).resolve() for a in argv]
             if argv else
             [REPO / "README.md", *sorted((REPO / "docs").glob("*.md"))])
    missing = [f for f in files if not f.exists()]
    for f in missing:
        print(f"MISSING FILE: {f}", file=sys.stderr)
    errors = check([f for f in files if f.exists()])
    for e in errors:
        print(f"BROKEN: {e}", file=sys.stderr)
    if missing or errors:
        return 1
    print(f"checked {len(files)} files: all intra-repo links resolve")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))

"""CI perf-regression gate: diff a bench run against committed baselines.

Compares every ``BENCH_<name>.json`` the smoke harness emitted
(``benchmarks.run --smoke --out-dir <run-dir>``) against the committed
smoke baselines in ``benchmarks/baselines/smoke/`` and fails (exit 1) on:

* a failed or missing bench (baseline exists, run JSON absent or
  ``status != ok``);
* a row present in the baseline but absent from the run, or vice versa
  (adding/removing a bench case requires refreshing the baselines in the
  same PR — see docs/BENCHMARKS.md);
* any ``exact`` field differing — these are machine-independent
  (edge/work counts, rebuild counts, verification booleans), so ANY drift
  is a real behaviour change, never noise;
* ``us_per_call`` exceeding baseline × ``--time-tol`` — wall time on
  shared CI runners is noisy and baselines may come from a different
  hardware class, so the tolerance is deliberately coarse (default 10x)
  and catches only order-of-magnitude slowdowns; the exact fields are the
  precise teeth;
* any ``ratio`` field (machine-dependent rates/latencies: queries/sec,
  p50/p99 µs) outside ``--time-tol`` in EITHER direction — the class
  covers higher-is-better and lower-is-better fields uniformly, and a
  >tol× improvement demands a baseline refresh just like a regression
  (the baseline should describe current reality); ratio key-set drift
  between baseline and run fails like row drift;
* a missing run/baseline directory, or a ``BENCH_*.json`` on either side
  that cannot be read or parsed — each such file fails with its own
  named problem (file, parse position, which side) instead of an
  unhandled traceback, so a truncated artifact upload or a
  half-committed baseline is diagnosable from the gate output alone.

Stdlib-only (like scripts/check_links.py) so the CI step needs no extras:

    python scripts/bench_gate.py --run-dir bench-artifacts
    python scripts/bench_gate.py --run-dir bench-artifacts --time-tol 4
"""

import argparse
import json
import pathlib
import sys

DEFAULT_BASELINE_DIR = pathlib.Path("benchmarks/baselines/smoke")
DEFAULT_TIME_TOL = 10.0

REFRESH_HINT = ("refresh the committed baselines: PYTHONPATH=src python -m "
                "benchmarks.run --smoke --out-dir benchmarks/baselines/smoke")
RERUN_HINT = ("re-emit the run artifacts: PYTHONPATH=src python -m "
              "benchmarks.run --smoke --out-dir <run-dir>")


def load_bench_json(path: pathlib.Path, side: str,
                    hint: str) -> "tuple[dict | None, str | None]":
    """Parse one BENCH_*.json: ``(doc, None)``, or ``(None, problem)``.

    Every failure mode — unreadable file, malformed JSON, non-object
    top level — comes back as ONE named problem string (file, side,
    parse position, remedy) so ``gate`` reports it alongside the diff
    problems instead of dying with a traceback on the first bad file.
    """
    try:
        text = path.read_text()
    except OSError as exc:
        return None, (f"{path.name}: unreadable {side} file "
                      f"({exc}) — {hint}")
    try:
        doc = json.loads(text)
    except json.JSONDecodeError as exc:
        return None, (f"{path.name}: {side} is not valid JSON (line "
                      f"{exc.lineno} col {exc.colno}: {exc.msg}) — {hint}")
    if not isinstance(doc, dict):
        return None, (f"{path.name}: {side} top level must be a JSON "
                      f"object, got {type(doc).__name__} — {hint}")
    return doc, None


def diff_bench(baseline: dict, run: dict, time_tol: float) -> "list[str]":
    """All regressions of one bench's run JSON vs its baseline JSON."""
    problems: "list[str]" = []
    name = baseline.get("bench", "?")
    if run.get("status") != "ok":
        problems.append(f"{name}: status={run.get('status')!r} "
                        f"error={run.get('error')!r}")
        return problems  # rows are empty/meaningless on failure
    if run.get("schema_version") != baseline.get("schema_version"):
        problems.append(
            f"{name}: schema_version {run.get('schema_version')} != baseline "
            f"{baseline.get('schema_version')} — {REFRESH_HINT}")
    base_rows = {r["name"]: r for r in baseline.get("rows", [])}
    run_rows = {r["name"]: r for r in run.get("rows", [])}
    for missing in sorted(base_rows.keys() - run_rows.keys()):
        problems.append(f"{name}: row {missing} missing from run")
    for extra in sorted(run_rows.keys() - base_rows.keys()):
        problems.append(f"{name}: row {extra} has no baseline — "
                        f"{REFRESH_HINT}")
    for row_name in sorted(base_rows.keys() & run_rows.keys()):
        b, r = base_rows[row_name], run_rows[row_name]
        b_exact, r_exact = b.get("exact", {}), r.get("exact", {})
        for key in sorted(b_exact.keys() | r_exact.keys()):
            if b_exact.get(key) != r_exact.get(key):
                problems.append(
                    f"{name}: row {row_name} exact field {key!r}: "
                    f"run {r_exact.get(key)!r} != baseline "
                    f"{b_exact.get(key)!r}")
        b_ratio, r_ratio = b.get("ratio", {}), r.get("ratio", {})
        for key in sorted(b_ratio.keys() | r_ratio.keys()):
            if key not in b_ratio or key not in r_ratio:
                side = "run" if key not in r_ratio else "baseline"
                problems.append(
                    f"{name}: row {row_name} ratio field {key!r} missing "
                    f"from {side} — {REFRESH_HINT}")
                continue
            bval, rval = float(b_ratio[key]), float(r_ratio[key])
            lo, hi = sorted((bval, rval))
            if hi > lo * time_tol:
                problems.append(
                    f"{name}: row {row_name} ratio field {key!r}: run "
                    f"{rval:g} vs baseline {bval:g} is outside the "
                    f"{time_tol}x two-sided tolerance")
        limit = b["us_per_call"] * time_tol
        if r["us_per_call"] > limit:
            problems.append(
                f"{name}: row {row_name} wall time {r['us_per_call']:.0f}us "
                f"exceeds baseline {b['us_per_call']:.0f}us x tol {time_tol} "
                f"= {limit:.0f}us")
    return problems


def gate(run_dir: pathlib.Path, baseline_dir: pathlib.Path,
         time_tol: float) -> "list[str]":
    """Regressions across all benches; empty list = gate passes."""
    problems: "list[str]" = []
    if not baseline_dir.is_dir():
        return [f"baseline directory {baseline_dir} does not exist — "
                f"{REFRESH_HINT}"]
    baselines = sorted(baseline_dir.glob("BENCH_*.json"))
    if not baselines:
        return [f"no BENCH_*.json baselines under {baseline_dir} — "
                f"{REFRESH_HINT}"]
    if not run_dir.is_dir():
        return [f"run directory {run_dir} does not exist — {RERUN_HINT}"]
    for base_path in baselines:
        run_path = run_dir / base_path.name
        if not run_path.exists():
            problems.append(f"{base_path.name}: baseline exists but the run "
                            f"emitted no {run_path}")
            continue
        baseline, problem = load_bench_json(base_path, "baseline",
                                            REFRESH_HINT)
        if problem:
            problems.append(problem)
            continue
        run, problem = load_bench_json(run_path, "run", RERUN_HINT)
        if problem:
            problems.append(problem)
            continue
        problems.extend(diff_bench(baseline, run, time_tol))
    known = {p.name for p in baselines}
    for run_path in sorted(run_dir.glob("BENCH_*.json")):
        if run_path.name not in known:
            problems.append(f"{run_path.name}: no committed baseline — "
                            f"{REFRESH_HINT}")
    return problems


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--run-dir", type=pathlib.Path, required=True,
                   help="directory with the run's BENCH_*.json files")
    p.add_argument("--baseline-dir", type=pathlib.Path,
                   default=DEFAULT_BASELINE_DIR,
                   help=f"committed baselines (default {DEFAULT_BASELINE_DIR})")
    p.add_argument("--time-tol", type=float, default=DEFAULT_TIME_TOL,
                   help="allowed us_per_call slowdown factor vs baseline, "
                        "and the two-sided factor for ratio fields "
                        f"(qps, p50/p99) (default {DEFAULT_TIME_TOL}x; "
                        "exact fields always compare strictly)")
    args = p.parse_args(argv)
    problems = gate(args.run_dir, args.baseline_dir, args.time_tol)
    if problems:
        print(f"bench gate: FAIL ({len(problems)} problem(s))")
        for prob in problems:
            print(f"  - {prob}")
        return 1
    n = len(list(args.baseline_dir.glob("BENCH_*.json")))
    print(f"bench gate: OK ({n} benches within tolerance, exact fields "
          "identical)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Nightly perf trend: diff the two most recent bench artifact sets.

Compares the BENCH_*.json files of tonight's nightly harness run against
the previous nightly's downloaded artifacts and prints a per-row drift
report — wall-time movement, ratio-field movement (queries/sec, p50/p99)
and any exact-field change. Unlike scripts/bench_gate.py this is a TREND
tool, not a gate: the two runs come from different commits, so exact
drift usually means "a PR changed behaviour between the nightlies" and
is reported with the field-by-field diff rather than a refresh hint.

Directories are searched recursively (``rglob``) because
``gh run download`` unpacks each artifact into its own subdirectory.
Exit code is 1 when any exact field drifted (the CI step runs with
``continue-on-error: true``, so this only colors the step, never the
job), 0 otherwise — including when either side is missing files, which
happens legitimately on the first nightly or after artifact expiry.

    python scripts/bench_trend.py --prev bench-prev --curr bench-nightly
    python scripts/bench_trend.py --prev a --curr b --move-tol 1.25
"""

import argparse
import json
import pathlib
import sys

DEFAULT_MOVE_TOL = 1.5  # report wall/ratio moves beyond this factor


def find_bench_files(root: pathlib.Path) -> "dict[str, pathlib.Path]":
    """Map ``BENCH_<name>.json`` filename -> path, searching recursively.

    ``gh run download`` nests artifacts one directory per artifact name,
    so a flat glob would find nothing. Duplicate filenames (two artifacts
    carrying the same bench) keep the lexically first path, noted on
    stdout so a surprising diff is traceable to the file actually read.
    """
    found: "dict[str, pathlib.Path]" = {}
    for path in sorted(root.rglob("BENCH_*.json")):
        if path.name in found:
            print(f"note: duplicate {path.name} under {root} — "
                  f"using {found[path.name]}, ignoring {path}")
            continue
        found[path.name] = path
    return found


def load(path: pathlib.Path) -> "dict | None":
    """Parse one artifact; unreadable/invalid files are noted and skipped
    (a truncated upload must not kill the whole trend report)."""
    try:
        doc = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as exc:
        print(f"note: skipping unreadable {path}: {exc}")
        return None
    return doc if isinstance(doc, dict) else None


def _fmt_move(prev: float, curr: float) -> str:
    if prev == curr:
        return "unchanged"
    if prev == 0:
        return f"{prev:g} -> {curr:g}"
    return f"{prev:g} -> {curr:g} ({curr / prev:.2f}x)"


def trend_rows(name: str, prev: dict, curr: dict,
               move_tol: float) -> "tuple[list[str], int]":
    """(report lines, exact-drift count) for one bench's two documents."""
    lines: "list[str]" = []
    exact_drifts = 0
    for side, doc in (("prev", prev), ("curr", curr)):
        if doc.get("status") != "ok":
            lines.append(f"{name}: {side} status={doc.get('status')!r} "
                         f"error={doc.get('error')!r} — rows not comparable")
            return lines, 0
    prev_rows = {r["name"]: r for r in prev.get("rows", [])}
    curr_rows = {r["name"]: r for r in curr.get("rows", [])}
    for gone in sorted(prev_rows.keys() - curr_rows.keys()):
        lines.append(f"{name}: row {gone} disappeared since last nightly")
    for new in sorted(curr_rows.keys() - prev_rows.keys()):
        lines.append(f"{name}: row {new} is new since last nightly")
    for row_name in sorted(prev_rows.keys() & curr_rows.keys()):
        p, c = prev_rows[row_name], curr_rows[row_name]
        p_exact, c_exact = p.get("exact", {}), c.get("exact", {})
        for key in sorted(p_exact.keys() | c_exact.keys()):
            if p_exact.get(key) != c_exact.get(key):
                exact_drifts += 1
                lines.append(
                    f"{name}: row {row_name} exact {key!r}: "
                    f"{p_exact.get(key)!r} -> {c_exact.get(key)!r}")
        moved: "list[str]" = []
        pw, cw = float(p["us_per_call"]), float(c["us_per_call"])
        if pw > 0 and max(pw, cw) > min(pw, cw) * move_tol:
            moved.append(f"wall {_fmt_move(pw, cw)}")
        p_ratio, c_ratio = p.get("ratio", {}), c.get("ratio", {})
        for key in sorted(p_ratio.keys() & c_ratio.keys()):
            pv, cv = float(p_ratio[key]), float(c_ratio[key])
            if pv > 0 and max(pv, cv) > min(pv, cv) * move_tol:
                moved.append(f"{key} {_fmt_move(pv, cv)}")
        if moved:
            lines.append(f"{name}: row {row_name} moved >"
                         f"{move_tol:g}x: " + "; ".join(moved))
    return lines, exact_drifts


def trend(prev_dir: pathlib.Path, curr_dir: pathlib.Path,
          move_tol: float) -> int:
    """Print the trend report; return the number of exact-field drifts."""
    prev_files = find_bench_files(prev_dir)
    curr_files = find_bench_files(curr_dir)
    print(f"bench trend: {len(prev_files)} prev file(s) under {prev_dir}, "
          f"{len(curr_files)} curr file(s) under {curr_dir}")
    if not prev_files or not curr_files:
        print("bench trend: nothing to compare (first nightly, or "
              "artifacts expired) — skipping")
        return 0
    for gone in sorted(prev_files.keys() - curr_files.keys()):
        print(f"  {gone}: present last nightly, absent tonight")
    for new in sorted(curr_files.keys() - prev_files.keys()):
        print(f"  {new}: new tonight (no previous artifact)")
    exact_drifts = 0
    reported = 0
    for fname in sorted(prev_files.keys() & curr_files.keys()):
        prev, curr = load(prev_files[fname]), load(curr_files[fname])
        if prev is None or curr is None:
            continue
        lines, drifts = trend_rows(prev.get("bench", fname), prev, curr,
                                   move_tol)
        exact_drifts += drifts
        reported += len(lines)
        for line in lines:
            print(f"  {line}")
    if not reported:
        print(f"bench trend: steady — no exact drift, no wall/ratio move "
              f"beyond {move_tol:g}x")
    elif exact_drifts:
        print(f"bench trend: {exact_drifts} exact field(s) drifted since "
              "the last nightly (behaviour changed between the runs)")
    return exact_drifts


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--prev", type=pathlib.Path, required=True,
                   help="previous nightly's artifact directory "
                        "(searched recursively)")
    p.add_argument("--curr", type=pathlib.Path, required=True,
                   help="tonight's artifact directory "
                        "(searched recursively)")
    p.add_argument("--move-tol", type=float, default=DEFAULT_MOVE_TOL,
                   help="report wall-time/ratio moves beyond this factor "
                        f"in either direction (default {DEFAULT_MOVE_TOL}x)")
    args = p.parse_args(argv)
    for side, d in (("--prev", args.prev), ("--curr", args.curr)):
        if not d.is_dir():
            print(f"bench trend: {side} directory {d} does not exist — "
                  "skipping (nothing to compare)")
            return 0
    return 1 if trend(args.prev, args.curr, args.move_tol) else 0


if __name__ == "__main__":
    sys.exit(main())

"""Example 3: end-to-end driver — train a ~100M-param LM for a few hundred steps.

Uses the qwen3 MoE *family* at ~100M scale (8 experts, top-2, 8 layers) with
the full production substrate: deterministic data pipeline, AdamW,
checkpoint-every-N with restart, and the same train-step code path the
256-chip dry-run lowers. Takes ~15-30 min on this CPU container at the
default 300 steps; pass --steps 30 for a quick look.

    PYTHONPATH=src python examples/train_lm_e2e.py --steps 30
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.data import DataCursor, lm_batch
from repro.models.transformer import LMConfig, init_lm_params, lm_loss
from repro.optim import adamw_init, adamw_update
from repro.runtime import CheckpointManager


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--ckpt-dir", default="/tmp/repro_lm_e2e")
    args = p.parse_args()

    cfg = LMConfig(
        name="qwen3-family-100m",
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, d_head=64,
        d_ff=1408, moe_d_ff=704, vocab=32_000,
        moe_every=1, n_experts=8, top_k=2,
        param_dtype=jnp.float32,
    )
    params = init_lm_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"[e2e] {cfg.name}: {n_params/1e6:.1f}M params")

    opt = adamw_init(params)
    ckpt = CheckpointManager(args.ckpt_dir)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(cfg, p, batch["tokens"], batch["labels"]))(params)
        params, opt, gnorm = adamw_update(grads, opt, params, lr=3e-4,
                                          weight_decay=0.01)
        return params, opt, loss, gnorm

    cursor = DataCursor(seed=0, step=0)
    t0 = time.perf_counter()
    first = None
    for i in range(args.steps):
        batch = lm_batch(cursor, args.batch, args.seq, cfg.vocab)
        cursor.step += 1
        params, opt, loss, gnorm = step(params, opt, batch)
        if first is None:
            first = float(loss)
        if (i + 1) % 50 == 0 or i == 0:
            print(f"[e2e] step {i+1:4d} loss {float(loss):.4f} "
                  f"gnorm {float(gnorm):.2f} "
                  f"({(time.perf_counter()-t0)/(i+1)*1e3:.0f} ms/step)")
        if (i + 1) % 100 == 0:
            ckpt.save(i + 1, {"params": params, "opt": opt, "cursor": cursor})
    print(f"[e2e] loss {first:.4f} -> {float(loss):.4f} over {args.steps} steps")


if __name__ == "__main__":
    main()

"""Example 2: many sliding-window queries, one batched launch each.

A width-4 window slides over a 10-snapshot evolving sequence. CommonGraph
turns every window into an addition-only hop from the windows' common
super-window apex, so the hops are independent — the batched window
executor (core/window.py) stacks them as lanes of a SINGLE
`incremental_additions_batched` launch instead of re-running each window
sequentially (on a mesh the lane axis shards over `data`:
`python -m repro.launch.evolve --window 4 --window-batch --shard`). We run
all five paper algorithms over the same windows and reuse the shared store.

    PYTHONPATH=src python examples/multi_query_window.py
"""

import time

import numpy as np

from repro.core import (
    SnapshotStore,
    run_window_slide,
    run_window_slide_batched,
    slide_windows,
)
from repro.graph import EdgeView, make_evolving_sequence, run_to_fixpoint
from repro.graph.semiring import ALL_SEMIRINGS

WIDTH = 4

seq = make_evolving_sequence(num_nodes=10_000, num_edges=100_000,
                             num_snapshots=10, batch_changes=4_000, seed=1)
store = SnapshotStore(seq)   # window intersections are computed once,
                             # shared by every query below
windows = slide_windows(seq.num_snapshots, WIDTH)

for alg, sr in ALL_SEMIRINGS.items():
    t0 = time.perf_counter()
    bat = run_window_slide_batched(store, sr, source=0, width=WIDTH)
    dt = time.perf_counter() - t0
    # the sequential slide gives the same bits, one hop at a time
    seq_run = run_window_slide(store, sr, source=0, width=WIDTH)
    for wnd in windows:
        np.testing.assert_array_equal(np.asarray(bat.results[wnd]),
                                      np.asarray(seq_run.results[wnd]))
    # spot-check the first and last window against from-scratch
    for wnd in (windows[0], windows[-1]):
        ref = run_to_fixpoint(
            EdgeView((store.window_block(*wnd),), store.num_nodes),
            sr, 0).values
        np.testing.assert_allclose(np.asarray(bat.results[wnd]),
                                   np.asarray(ref), rtol=1e-6)
    reached = int(np.isfinite(np.asarray(bat.results[windows[-1]])).sum())
    print(f"{alg:8s}: {len(windows)} width-{WIDTH} windows in one batched "
          f"launch, {dt:5.2f}s (anchor T{bat.anchor}), "
          f"{reached:,} vertices reached ✓")

"""Example 2: many queries over one evolving window, batched executor.

CommonGraph removes the sequential dependence between snapshots, so the
per-snapshot hops stack on a tensor axis (vmapped here; on a mesh this is
the `data` axis — launch/evolve.py / configs/commongraph.py). We run all
five paper algorithms over the same window and reuse the shared store.

    PYTHONPATH=src python examples/multi_query_window.py
"""

import time

import numpy as np

from repro.core import SnapshotStore, run_direct_hop_batched
from repro.graph import make_evolving_sequence, run_to_fixpoint
from repro.graph.semiring import ALL_SEMIRINGS

seq = make_evolving_sequence(num_nodes=10_000, num_edges=100_000,
                             num_snapshots=10, batch_changes=4_000, seed=1)
store = SnapshotStore(seq)   # window intersections are computed once,
                             # shared by every query below

for alg, sr in ALL_SEMIRINGS.items():
    t0 = time.perf_counter()
    run_ = run_direct_hop_batched(store, sr, source=0)
    dt = time.perf_counter() - t0
    # spot-check two snapshots against from-scratch
    for i in (0, 9):
        ref = run_to_fixpoint(store.snapshot_view(i), sr, 0).values
        np.testing.assert_allclose(np.asarray(run_.results[i]),
                                   np.asarray(ref), rtol=1e-6)
    reached = int(np.isfinite(np.asarray(run_.results[-1])).sum())
    print(f"{alg:8s}: 10 snapshots in one batched call, {dt:5.2f}s, "
          f"{reached:,} vertices reached ✓")

"""Quickstart: the paper in 50 lines.

Build an evolving graph, answer an SSSP query on every snapshot three ways
(KickStarter streaming, CommonGraph Direct-Hop, TG work-sharing), verify
they agree, show the deletion-free schedules' work saving, and slide a
query window with the batched window executor. The CLI exposes the same
modes at scale — see ``python -m repro.launch.evolve --help`` for
``--shard`` (mesh-shard the batched lane axis), ``--window W`` (sliding
windows) and ``--window-batch`` (the one-launch batched slide).

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (
    SnapshotStore,
    optimal_plan,
    plan_added_edges,
    run_direct_hop,
    run_kickstarter_stream,
    run_plan,
    run_plan_batched,
    run_window_slide_batched,
)
from repro.graph import make_evolving_sequence, run_to_fixpoint
from repro.graph.semiring import SSSP

# 1. an evolving graph: 8 snapshots, each 2000 edge changes (50% deletions)
seq = make_evolving_sequence(num_nodes=5_000, num_edges=50_000,
                             num_snapshots=8, batch_changes=2_000, seed=0)
store = SnapshotStore(seq)
print(f"snapshots: {seq.num_snapshots}, CommonGraph edges: "
      f"{store.window_size(0, 7):,} of {seq.snapshot_keys[0].shape[0]:,}")

# 2. baseline: KickStarter streams additions AND deletions in sequence
ks_results, ks_stats = run_kickstarter_stream(store, SSSP, source=0)
print(f"KickStarter: {sum(s.wall_s for s in ks_stats):.2f}s, "
      f"edge work {sum(s.edge_work for s in ks_stats):,.0f}")

# 3. CommonGraph Direct-Hop: deletions become additions from the apex
dh = run_direct_hop(store, SSSP, source=0)
print(f"Direct-Hop:  {dh.wall_s:.2f}s, "
      f"edge work {dh.base_stats.edge_work + sum(h.edge_work for h in dh.hop_stats):,.0f}")

# 4. Triangular-Grid work sharing (DP-optimal plan)
plan = optimal_plan(store)
ws = run_plan(store, plan, SSSP, source=0)
print(f"Work-Share:  {ws.wall_s:.2f}s, Δ-edges {ws.added_edges:,} "
      f"(Direct-Hop would stream "
      f"{plan_added_edges(store, __import__('repro.core', fromlist=['direct_hop_plan']).direct_hop_plan(n=8)):,})")

# 5. the same plan, level-synchronous and batched: sibling hops at each plan
#    depth run as ONE stacked snapshot-axis launch (the paper's parallelism
#    claim — on a mesh this axis shards over `data`)
wsb = run_plan_batched(store, plan, SSSP, source=0)
print(f"Work-Share (batched): {wsb.wall_s:.2f}s, "
      f"{len(wsb.hop_stats)} level launches vs {len(ws.hop_stats)} hops")

# 6. sliding windows: every width-3 window is an addition-only hop from the
#    windows' shared super-window apex; all hops run as ONE stacked launch
#    (CLI: python -m repro.launch.evolve --window 3 --window-batch)
sl = run_window_slide_batched(store, SSSP, source=0, width=3)
print(f"Window slide (batched): {sl.wall_s:.2f}s, "
      f"{len(sl.results)} width-3 windows in 1 launch, anchor T{sl.anchor}")

# 7. all modes agree with from-scratch on every snapshot
for i in range(8):
    ref = run_to_fixpoint(store.snapshot_view(i), SSSP, 0).values
    np.testing.assert_allclose(np.asarray(ks_results[i]), np.asarray(ref), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(dh.results[i]), np.asarray(ref), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(ws.results[i]), np.asarray(ref), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(wsb.results[i]), np.asarray(ws.results[i]))
print("all modes exact on all snapshots ✓")

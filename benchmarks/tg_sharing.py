"""Paper §2 work sharing: TG plans vs Direct-Hop — Δ-edge volume AND wall-clock.

Two accounts per window size, for all three plans (star/Direct-Hop, balanced
bisection, DP-optimal):

* Δ-edge volume streamed by the plan (plan_added_edges) — the scaling the
  paper's TG section argues.
* Executed wall-clock + engine edge work, sequential DFS (`run_plan`) vs the
  level-synchronous batched executor (`run_plan_batched`) — the paper's
  parallelism claim as a measurable hot path. Both executors are warmed up
  once so compile time is excluded; the batched column should win for
  windows ≥ 8 (fewer, fatter launches; no per-hop host sync).

    PYTHONPATH=src python -m benchmarks.tg_sharing
"""

from __future__ import annotations

from repro.core import (
    SnapshotStore,
    bisection_plan,
    direct_hop_plan,
    optimal_plan,
    plan_added_edges,
    run_plan,
    run_plan_batched,
)
from repro.graph import make_evolving_sequence
from repro.graph.semiring import ALL_SEMIRINGS


def _executed(store, plan, sr, source):
    """(sequential, batched) timed runs, each after a warm-up for compiles."""
    run_plan(store, plan, sr, source)
    seq_run = run_plan(store, plan, sr, source)
    run_plan_batched(store, plan, sr, source)
    bat_run = run_plan_batched(store, plan, sr, source)
    return seq_run, bat_run


def run_tg_sharing(n=10_000, e=100_000, batch_changes=5_000,
                   windows=(4, 8, 16), seed=0, execute=True, alg="sssp",
                   source=0):
    sr = ALL_SEMIRINGS[alg]
    rows = []
    for w in windows:
        seq = make_evolving_sequence(n, e, w, batch_changes, seed=seed)
        store = SnapshotStore(seq)
        plans = {"dh": direct_hop_plan(n=w), "bisect": bisection_plan(n=w),
                 "optimal": optimal_plan(store)}
        dh, bis, opt = (plan_added_edges(store, plans[k])
                        for k in ("dh", "bisect", "optimal"))
        row = {"window": w, "dh_edges": dh, "bisect_edges": bis,
               "optimal_edges": opt,
               "bisect_saving": 1 - bis / dh, "optimal_saving": 1 - opt / dh}
        if execute:
            for name, plan in plans.items():
                seq_run, bat_run = _executed(store, plan, sr, source)
                row[f"{name}_seq_s"] = seq_run.wall_s
                row[f"{name}_bat_s"] = bat_run.wall_s
                row[f"{name}_bat_speedup"] = seq_run.wall_s / bat_run.wall_s
                row[f"{name}_work"] = (seq_run.base_stats.edge_work
                                       + sum(h.edge_work
                                             for h in seq_run.hop_stats))
                row[f"{name}_bat_work"] = (bat_run.base_stats.edge_work
                                           + sum(h.edge_work
                                                 for h in bat_run.hop_stats))
        rows.append(row)
    return rows


if __name__ == "__main__":
    for r in run_tg_sharing():
        print(f"n={r['window']:3d}  DH {r['dh_edges']:>10,}  "
              f"bisect {r['bisect_edges']:>10,} (-{r['bisect_saving']:.1%})  "
              f"optimal {r['optimal_edges']:>10,} (-{r['optimal_saving']:.1%})")
        if "dh_seq_s" in r:
            for name in ("dh", "bisect", "optimal"):
                print(f"      {name:8s} seq {r[f'{name}_seq_s']:.3f}s  "
                      f"batched {r[f'{name}_bat_s']:.3f}s  "
                      f"({r[f'{name}_bat_speedup']:.2f}x, "
                      f"work {r[f'{name}_work']:,.0f} vs "
                      f"{r[f'{name}_bat_work']:,.0f})")

"""Paper §2 work sharing: Δ-edge volume of TG plans vs Direct-Hop.

The Triangular Grid's value is the drop in total streamed addition volume;
this benchmark accounts it exactly (plan_added_edges) for the star plan
(Direct-Hop), balanced bisection, and the DP-optimal plan, across window
sizes — the scaling the paper's Figure/TG section argues.
"""

from __future__ import annotations

from repro.core import (
    SnapshotStore,
    bisection_plan,
    direct_hop_plan,
    optimal_plan,
    plan_added_edges,
)
from repro.graph import make_evolving_sequence


def run_tg_sharing(n=20_000, e=200_000, batch_changes=10_000,
                   windows=(4, 8, 16), seed=0):
    rows = []
    for w in windows:
        seq = make_evolving_sequence(n, e, w, batch_changes, seed=seed)
        store = SnapshotStore(seq)
        dh = plan_added_edges(store, direct_hop_plan(n=w))
        bis = plan_added_edges(store, bisection_plan(n=w))
        opt = plan_added_edges(store, optimal_plan(store))
        rows.append({"window": w, "dh_edges": dh, "bisect_edges": bis,
                     "optimal_edges": opt,
                     "bisect_saving": 1 - bis / dh, "optimal_saving": 1 - opt / dh})
    return rows


if __name__ == "__main__":
    for r in run_tg_sharing():
        print(f"n={r['window']:3d}  DH {r['dh_edges']:>10,}  "
              f"bisect {r['bisect_edges']:>10,} (-{r['bisect_saving']:.1%})  "
              f"optimal {r['optimal_edges']:>10,} (-{r['optimal_saving']:.1%})")

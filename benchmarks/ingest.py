"""Live-ingestion bench: firehose replay vs the precomputed-input path.

Replays a seeded edge-event trace (``events_from_sequence`` of a
``make_evolving_sequence`` graph) through the full ingestion pipeline —
``EdgeLog`` (bounded buffer, spill backpressure) → ``Watermark.cut`` per
tick → ``LiveWindowFeed`` → a live ``WindowStream`` served by
``run_window_stream_batched`` after every cut — and accounts one row:

* **Exact (gate-strict) fields**: every ``IngestMetrics`` counter
  (events, late/spilled/dropped/stalls, cuts, applied additions/
  deletions, redundant events, common-graph shrinkage, compaction trio),
  the stored-edge count before/after compaction, windows served live,
  and the bit-identity boolean. All are pure functions of the seed:
  event consumption is (ts, arrival)-ordered and scheduling count-based.
* The wall time covers the timed replay *including* live query serving
  (one warm replay first compiles traces and prices blocks).

The row doubles as the acceptance check (assertions, not just numbers):
snapshots and Δ-batches cut from the firehose must be **bit-identical**
to the precomputed sequence; queries answered live during ingestion and
post-replay window slides across **all five semirings** must be
bit-identical to the precomputed-input path; and compaction must leave
**strictly fewer** stored edges.

    PYTHONPATH=src python -m benchmarks.ingest [--smoke]

CI runs this via the bench job's ``benchmarks.run --smoke`` harness pass
and diffs the emitted BENCH_ingest.json against the committed smoke
baseline (docs/BENCHMARKS.md).
"""

import argparse
import dataclasses
import time

import numpy as np

from repro.core import (
    EdgeLog,
    IngestMetrics,
    LiveSequence,
    LiveWindowFeed,
    SnapshotStore,
    Watermark,
    WindowStream,
    events_from_sequence,
    replay_events,
    run_window_slide_batched,
    run_window_stream_batched,
)
from repro.graph import make_evolving_sequence
from repro.graph.semiring import ALL_SEMIRINGS


def _replay_and_serve(seq, events, semiring, *, width, campaign_width,
                      max_pending):
    """One full live run: replay the trace, serving born windows per cut.

    Returns ``(store, results, windows_served, metrics, watermark)``.
    The ``"spill"`` policy makes the bounded buffer lossless AND
    deterministic — spilled events rejoin in (ts, arrival) order at the
    next cut — so every counter below is a pure function of the trace.
    """
    metrics = IngestMetrics()
    store = SnapshotStore(LiveSequence(seq.num_nodes,
                                       weight_seed=seq.weight_seed))
    log = EdgeLog(seq.num_nodes, max_pending_events=max_pending,
                  policy="spill", metrics=metrics)
    watermark = Watermark(log, store)
    stream = WindowStream(campaign_width, name="live-ingest",
                          feed=LiveWindowFeed(store, width=width))
    results = {}

    def on_cut(_idx):
        run = run_window_stream_batched(store, semiring, 0, stream=stream)
        results.update(run.results)

    replay_events(log, watermark, events, on_cut=on_cut)
    return store, results, len(results), metrics, watermark


def run_ingest_bench(n=2_000, e=20_000, snaps=8, changes=600, width=3,
                     campaign_width=2, max_pending=1_024, seed=7):
    """One row of firehose-vs-precomputed accounting + replay wall time."""
    seq = make_evolving_sequence(n, e, snaps, changes, seed=seed)
    events = events_from_sequence(seq)
    semiring = ALL_SEMIRINGS["sssp"]
    ref = SnapshotStore(seq)

    # Warm-up replay: compiles every slide trace and builds the reference
    # blocks, so the timed run measures ingestion + serving, not jit.
    _replay_and_serve(seq, events, semiring, width=width,
                      campaign_width=campaign_width, max_pending=max_pending)
    t0 = time.perf_counter()
    live, live_results, served, metrics, watermark = _replay_and_serve(
        seq, events, semiring, width=width, campaign_width=campaign_width,
        max_pending=max_pending)
    wall_s = time.perf_counter() - t0

    # Bit-identity, structure: every snapshot + canonical Δ pair cut from
    # the firehose equals the precomputed sequence exactly.
    bit_identical = all(
        np.array_equal(live.seq.snapshot_keys[i], seq.snapshot_keys[i])
        for i in range(snaps))
    bit_identical = bit_identical and all(
        np.array_equal(live.seq.additions[t], seq.additions[t])
        and np.array_equal(live.seq.deletions[t], seq.deletions[t])
        for t in range(snaps - 1))
    assert bit_identical, "replayed snapshots/Δ diverged from the sequence"

    # Bit-identity, values: windows answered LIVE (mid-ingestion, anchors
    # widening cut by cut) vs the precomputed-input slide — the monotone
    # rounded fixpoint of (window, qkey) is unique, so exact equality.
    ref_slide = run_window_slide_batched(ref, semiring, 0, width)
    assert set(live_results) == set(ref_slide.results), "window set diverged"
    for wnd, vals in ref_slide.results.items():
        if not np.array_equal(np.asarray(live_results[wnd]),
                              np.asarray(vals)):
            bit_identical = False
    assert bit_identical, "live-served values diverged from precomputed path"

    # All five semirings over the fully ingested store vs the precomputed
    # one — same blocks, same weights (pure key hash), same fixpoints.
    for name, sr in sorted(ALL_SEMIRINGS.items()):
        a = run_window_slide_batched(live, sr, 0, width)
        b = run_window_slide_batched(ref, sr, 0, width)
        for wnd, vals in b.results.items():
            assert np.array_equal(np.asarray(a.results[wnd]),
                                  np.asarray(vals)), (name, wnd)

    # Compaction: the drained feed's floor frees every out-of-window
    # snapshot — strictly fewer stored edges (the PR's acceptance bar).
    stored_before = live.stored_edges
    stats = watermark.compact()
    stored_after = live.stored_edges
    assert stats.retired > 0, "drained feed should allow retirement"
    assert stored_after < stored_before, (
        f"compaction must strictly shrink storage "
        f"({stored_before} -> {stored_after})")
    live.window_keys(live.first_live, snaps - 1)  # live range still serves

    assert metrics.spilled > 0, "smoke trace should exercise backpressure"
    assert metrics.late_events == 0 and metrics.dropped == 0

    return {
        **dataclasses.asdict(metrics),
        "stored_edges_before": stored_before,
        "stored_edges_after": stored_after,
        "windows_served": served,
        "bit_identical": bit_identical,
        "wall_s": wall_s,
    }


SMOKE = dict(n=400, e=3_000, snaps=6, changes=200, width=3,
             campaign_width=2, max_pending=1_024, seed=7)


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="tiny graph (CI smoke run)")
    args = p.parse_args(argv)
    r = run_ingest_bench(**(SMOKE if args.smoke else {}))
    print(f"events={r['events']}  cuts={r['cuts']}  "
          f"spilled={r['spilled']}  "
          f"applied +{r['applied_additions']}/-{r['applied_deletions']}  "
          f"redundant={r['redundant_events']}  "
          f"common-shrinkage={r['common_shrinkage']}  "
          f"served={r['windows_served']} windows live  "
          f"compaction retired {r['retired_snapshots']} snaps "
          f"({r['stored_edges_before']}→{r['stored_edges_after']} edges)  "
          f"replay {r['wall_s'] * 1e3:.1f}ms  bit-identical ✓")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

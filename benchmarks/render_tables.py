"""Render §Dry-run / §Roofline markdown tables into EXPERIMENTS.md from the
JSON artifacts (placeholders: <!-- DRYRUN_TABLE --> and <!-- ROOFLINE_TABLE -->).

    PYTHONPATH=src python benchmarks/render_tables.py
"""

import json
import re


def gb(x):
    return f"{(x or 0)/2**30:.2f}"


def dryrun_table(path="dryrun_results.json"):
    with open(path) as f:
        recs = json.load(f)["records"]
    lines = ["| cell | mesh | FLOPs/dev | bytes/dev | coll GiB/dev (top op) "
             "| arg GiB/dev | temp GiB/dev |",
             "|---|---|---|---|---|---|---|"]
    for r in recs:
        mesh = "×".join(str(v) for v in r["mesh"].values())
        coll = r["collective_bytes"]
        top = max(coll, key=coll.get) if coll else "-"
        tot = sum(coll.values())
        mem = r["mem_per_device"]
        lines.append(
            f"| {r['cell']} | {mesh} | {r['flops']:.2e} | {r['bytes_accessed']:.2e} "
            f"| {tot/2**30:.2f} ({top}) | {gb(mem['argument_bytes'])} "
            f"| {gb(mem['temp_bytes'])} |")
    return "\n".join(lines)


def roofline_table(path="roofline.json"):
    with open(path) as f:
        rows = json.load(f)
    lines = ["| cell | compute (s) | memory (s) | collective (s) | dominant "
             "| MODEL_FLOPS | useful/HLO |",
             "|---|---|---|---|---|---|---|"]
    for r in rows:
        ur = r["useful_ratio"]
        lines.append(
            f"| {r['cell']} | {r['compute_s']:.4f} | {r['memory_s']:.4f} "
            f"| {r['collective_s']:.4f} | {r['dominant'].replace('_s','')} "
            f"| {r['model_flops']:.2e} | {ur and round(ur, 3)} |")
    return "\n".join(lines)


def main():
    with open("EXPERIMENTS.md") as f:
        doc = f.read()
    try:
        doc = doc.replace("<!-- DRYRUN_TABLE -->",
                          "<details><summary>All 84 cell records "
                          "(both meshes)</summary>\n\n"
                          + dryrun_table() + "\n\n</details>")
    except FileNotFoundError:
        print("dryrun_results.json missing; skipped")
    try:
        doc = doc.replace("<!-- ROOFLINE_TABLE -->", roofline_table())
    except FileNotFoundError:
        print("roofline.json missing; skipped")
    with open("EXPERIMENTS.md", "w") as f:
        f.write(doc)
    print("EXPERIMENTS.md tables rendered")


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Roofline-term extraction for every (arch × shape) cell — §Roofline.

Hardware model (TPU v5e): 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI. All compiled-artifact quantities below are PER-DEVICE
(the post-SPMD module), so the assignment's three terms reduce to:

    compute    = flops_dev / 197e12          (= HLO_FLOPs / (chips · peak))
    memory     = bytes_dev / 819e9
    collective = coll_bytes_dev / 50e9

**Scan correction.** XLA's cost_analysis counts a while-loop body ONCE
(verified empirically in this repo), so a 96-layer scanned transformer would
be undercounted 96×. We therefore compile each LM/recsys cell at TWO small
depths with the layer scan UNROLLED (`scan_unroll=True` — exact counting),
and extrapolate linearly: f(K) = a + b·K with b = f(2u) − f(u),
a = 2f(u) − f(2u). Linearity is exact because scanned layers are
homogeneous. GNN cells have no scans — their full-config dry-run numbers are
already exact. The commongraph engine's fixpoint loop is data-dependent:
terms are reported PER RELAXATION SWEEP (the natural unit; measured sweep
counts come from the evolving-graph benchmarks).

MODEL_FLOPS (useful work): 6·N_active·tokens for LM training (2· for
forward-only), analytic matmul counts for GNN/DIEN — formulas inline. The
ratio MODEL_FLOPS / HLO_FLOPs exposes remat/dispatch/padding overhead.

Run standalone (own process — the XLA flag must precede jax init):
    PYTHONPATH=src python benchmarks/roofline.py --json roofline.json \
        --dryrun dryrun_results.json --markdown roofline.md

``--kernels`` switches to the graph-kernel roofline instead of the model
cells: the fused k-sweep relax kernel (kernels/edge_relax_multi) is lowered
in both edge streams — ``edge`` (caller order) and ``csr`` (dst-sorted, the
segment-reduce layout) — plus the unfused 1-sweep kernel dispatched k
times, and their compiled cost_analysis terms are compared. Results are
bit-identical across layouts (tests/test_kernels_diff.py); this mode shows
what the layout/fusion choice costs in bytes and FLOPs:
    PYTHONPATH=src python benchmarks/roofline.py --kernels \
        [--nodes N --edges E --fused-k K] [--json kernels_roofline.json]
"""

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import sys               # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax               # noqa: E402

from repro.configs import ARCH_IDS, get_arch, shapes_for              # noqa: E402
from repro.configs.base import named, with_sharding                   # noqa: E402
from repro.launch.dryrun import collective_bytes                      # noqa: E402
from repro.launch.mesh import make_production_mesh                    # noqa: E402

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = {"single": 256, "multi": 512}


# -- per-cell compiled measurement (small-depth, unrolled) ---------------------

def _measure(cell, mesh):
    args = with_sharding(mesh, cell.in_specs, cell.args)
    out_shardings = named(mesh, cell.out_specs) if cell.out_specs is not None else None
    jitted = jax.jit(cell.fn, out_shardings=out_shardings,
                     donate_argnums=cell.donate)
    with jax.sharding.set_mesh(mesh):
        compiled = jitted.lower(*args).compile()
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": cost.get("flops", 0.0),
        "bytes": cost.get("bytes accessed", 0.0),
        "coll": sum(coll.values()),
        "coll_by_op": coll,
    }


def _extrapolate(m1, m2, k_total):
    """f(k) = a + b·k from f(1), f(2) in layer-units; evaluate at k_total."""
    out = {}
    for key in ("flops", "bytes", "coll"):
        b = m2[key] - m1[key]
        a = 2 * m1[key] - m2[key]
        out[key] = max(a + b * k_total, 0.0)
    return out


def lm_cell_terms(arch, shape, mesh):
    from repro.configs.lm_family import make_lm_cell
    cfg, _ = get_arch(arch)
    u = 2 if cfg.moe_every == 2 else 1          # depth unit (super-layer)
    k_total = cfg.n_layers // u
    ms = []
    for k in (1, 2):
        cfg_k = dataclasses.replace(cfg, n_layers=k * u, scan_unroll=True)
        cell = make_lm_cell(cfg_k, shape, mesh)
        ms.append(_measure(cell, mesh))
    return _extrapolate(ms[0], ms[1], k_total)


def recsys_cell_terms(arch, shape, mesh):
    from repro.configs.recsys_family import make_recsys_cell
    cfg, _ = get_arch(arch)
    s_total = cfg.seq_len
    ms = []
    for s in (2, 4):
        cfg_s = dataclasses.replace(cfg, seq_len=s, scan_unroll=True)
        cell = make_recsys_cell(cfg_s, shape, mesh)
        ms.append(_measure(cell, mesh))
    # seq-units of 2: f(1u)=seq2, f(2u)=seq4 -> evaluate at seq_len/2 units
    return _extrapolate(ms[0], ms[1], s_total / 2)


# -- MODEL_FLOPS (useful work) -------------------------------------------------

def lm_model_flops(arch, shape):
    from repro.configs.lm_family import LM_SHAPES
    cfg, _ = get_arch(arch)
    n_total = cfg.param_count()
    if cfg.is_moe:
        active_cfg = dataclasses.replace(cfg, n_experts=cfg.top_k)
        n_active = active_cfg.param_count()
    else:
        n_active = n_total
    sh = LM_SHAPES[shape]
    if sh["kind"] == "train":
        tokens = sh["batch"] * sh["seq"]
        return 6.0 * n_active * tokens, n_total, n_active
    if sh["kind"] == "prefill":
        tokens = sh["batch"] * sh["seq"]
        return 2.0 * n_active * tokens, n_total, n_active
    # decode: one token per sequence per step
    return 2.0 * n_active * sh["batch"], n_total, n_active


def gnn_model_flops(arch, shape):
    """Analytic matmul counts (×3 for train fwd+bwd): formulas per arch."""
    from repro.configs.gnn_family import GNN_SHAPES, _arch_shape_cfg
    from repro.graph.sampler import subgraph_shapes
    cfg0, _ = get_arch(arch)
    cfg = _arch_shape_cfg(cfg0, shape)
    sh = GNN_SHAPES[shape]
    if sh["kind"] == "minibatch":
        n, e = subgraph_shapes(sh["batch_nodes"], sh["fanout"])
    elif sh["kind"] == "molecule":
        n, e = sh["batch"] * sh["n_nodes"], sh["batch"] * sh["n_edges"]
    else:
        n, e = sh["n_nodes"], sh["n_edges"]
    d = cfg.d_hidden
    if cfg.arch == "gcn":
        f = 2 * n * cfg.d_in * d + 2 * n * d * cfg.d_out
    elif cfg.arch == "pna":
        per_layer = 2 * n * (13 * d) * d + 2 * n * d * d
        f = 2 * n * cfg.d_in * d + cfg.n_layers * per_layer + 4 * n * d * d
    elif cfg.arch == "meshgraphnet":
        mlp2 = lambda a, b: 2 * (a * d + d * d + d * b)  # 2-hidden MLP matmuls
        per_block = e * mlp2(3 * d, d) + n * mlp2(2 * d, d)
        f = (n * mlp2(cfg.d_in, d) + e * mlp2(cfg.d_edge, d)
             + cfg.n_layers * per_block + n * 2 * (d * d + d * cfg.d_out))
    else:  # graphcast
        m = max(n // 4, 42)
        em = 4 * m
        mlp1 = lambda a, b: 2 * (a * d + d * b)
        per_block = em * mlp1(3 * d, d) + m * mlp1(2 * d, d)
        f = (n * mlp1(cfg.n_vars, d) + e * mlp1(cfg.d_edge, d)
             + cfg.n_layers * per_block + e * mlp1(cfg.d_edge, d)
             + n * mlp1(2 * d, d) + n * 2 * (d * d + d * cfg.n_vars))
    return 3.0 * f  # train: fwd + bwd(2x)


def recsys_model_flops(arch, shape):
    from repro.configs.recsys_family import RECSYS_SHAPES
    cfg, _ = get_arch(arch)
    sh = RECSYS_SHAPES[shape]
    d, dh, s = cfg.d_behavior, cfg.gru_dim, cfg.seq_len
    gru = 2 * (d * 3 * dh + dh * 3 * dh)                # per step
    att = 2 * ((dh + d) * 80 + 80)
    mlp = 2 * ((dh + 2 * d) * 200 + 200 * 80 + 80 * 2)
    aux = 2 * 2 * ((dh + d) * 100 + 100)
    per_user = s * (2 * gru + att) + mlp
    if sh["kind"] == "train":
        return 3.0 * sh["batch"] * (per_user + (s - 1) * aux)
    if sh["kind"] == "serve":
        return 1.0 * sh["batch"] * per_user
    c = sh["n_candidates"]
    return 1.0 * (s * gru + c * (s * (gru + att) + mlp))


# -- assembly -------------------------------------------------------------------

def terms_from(meas):
    return {
        "compute_s": meas["flops"] / PEAK_FLOPS,
        "memory_s": meas["bytes"] / HBM_BW,
        "collective_s": meas["coll"] / ICI_BW,
    }


def dominant(terms):
    return max(terms, key=lambda k: terms[k])


def kernels_main(args):
    """Roofline terms for the fused relax kernel's layout variants.

    Lowers the fused k-sweep kernel per layout (edge-parallel vs csr) and
    the unfused 1-sweep kernel (charged ×k — what k separate dispatches
    would move), and reports compiled cost_analysis terms. The csr stream
    adds an argsort but turns the per-block scatter into segment runs; the
    fused grid skips k−1 HBM round trips of values/parent/frontier.
    """
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels import relax_multi

    n, e, k = args.nodes, args.edges, args.fused_k
    rng = np.random.default_rng(0)
    src = jnp.asarray(rng.integers(0, n, e).astype(np.int32))
    dst = jnp.asarray(rng.integers(0, n, e).astype(np.int32))
    w = jnp.asarray((rng.random(e) + 0.01).astype(np.float32))
    values = jnp.asarray((rng.random(n) * 10).astype(np.float32))
    parent = jnp.full((n,), -1, jnp.int32)
    frontier = jnp.ones((n,), bool)

    def measure(k_eff, layout, charge=1):
        compiled = relax_multi.lower(
            values, parent, frontier, src, dst, w, op="min_plus",
            num_nodes=n, k=k_eff, layout=layout).compile()
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):  # older jax: list of one dict
            cost = cost[0] if cost else {}
        return {"flops": charge * cost.get("flops", 0.0),
                "bytes": charge * cost.get("bytes accessed", 0.0),
                "coll": 0.0}

    rows = []
    cells = [(f"edge_relax_multi/fused{k}/edge", measure(k, "edge")),
             (f"edge_relax_multi/fused{k}/csr", measure(k, "csr")),
             (f"edge_relax_multi/unfused x{k}/edge", measure(1, "edge", k))]
    for cell, meas in cells:
        t = terms_from(meas)
        rows.append({"cell": cell, "family": "kernel",
                     **{key: round(v, 6) for key, v in t.items()},
                     "dominant": dominant(t),
                     "hlo_flops_dev": meas["flops"],
                     "hlo_bytes_dev": meas["bytes"]})
        print(f"[roofline] {cell:42s} comp {t['compute_s']:.6f}s "
              f"mem {t['memory_s']:.6f}s dom={rows[-1]['dominant']}")
    fused, unfused = rows[0]["hlo_bytes_dev"], rows[2]["hlo_bytes_dev"]
    if unfused:
        print(f"[roofline] fused/{k} moves {fused / unfused:.2f}x the bytes "
              f"of {k} unfused dispatches")
    with open(args.json, "w") as f:
        json.dump(rows, f, indent=1)
    return 0


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--dryrun", default="dryrun_results.json")
    p.add_argument("--json", default="roofline.json")
    p.add_argument("--markdown", default=None)
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--kernels", action="store_true",
                   help="graph-kernel layout roofline instead of model cells")
    p.add_argument("--nodes", type=int, default=2_000)
    p.add_argument("--edges", type=int, default=24_000)
    p.add_argument("--fused-k", type=int, default=4)
    args = p.parse_args(argv)

    if args.kernels:
        return kernels_main(args)

    with open(args.dryrun) as f:
        dry = {(r["cell"], len(r["mesh"])): r for r in json.load(f)["records"]}

    mesh = make_production_mesh(multi_pod=False)
    rows = []
    for arch in ([args.arch] if args.arch else ARCH_IDS):
        cfg, family = get_arch(arch)
        for shape in ([args.shape] if args.shape else shapes_for(arch)):
            cell_name = f"{cfg.name}/{shape}"
            try:
                if family == "lm":
                    meas = lm_cell_terms(arch, shape, mesh)
                    mf, n_tot, n_act = lm_model_flops(arch, shape)
                elif family == "recsys":
                    meas = recsys_cell_terms(arch, shape, mesh)
                    mf, n_tot, n_act = recsys_model_flops(arch, shape), None, None
                else:
                    rec = dry[(cell_name, 2)]
                    meas = {"flops": rec["flops"], "bytes": rec["bytes_accessed"],
                            "coll": sum(rec["collective_bytes"].values())}
                    mf, n_tot, n_act = gnn_model_flops(arch, shape), None, None
            except Exception as e:  # noqa: BLE001
                print(f"[roofline] FAIL {cell_name}: {e}")
                import traceback; traceback.print_exc()
                continue
            t = terms_from(meas)
            hlo_global = meas["flops"] * CHIPS["single"]
            row = {
                "cell": cell_name,
                "family": family,
                **{k: round(v, 6) for k, v in t.items()},
                "dominant": dominant(t),
                "hlo_flops_dev": meas["flops"],
                "hlo_bytes_dev": meas["bytes"],
                "coll_bytes_dev": meas["coll"],
                "model_flops": mf,
                "useful_ratio": (mf / hlo_global) if hlo_global else None,
                "peak_bytes_dev": dry.get((cell_name, 2), {}).get(
                    "mem_per_device", {}).get("peak_bytes"),
            }
            rows.append(row)
            print(f"[roofline] {cell_name:45s} comp {t['compute_s']:.4f}s "
                  f"mem {t['memory_s']:.4f}s coll {t['collective_s']:.4f}s "
                  f"dom={row['dominant']:<12s} useful={row['useful_ratio'] and round(row['useful_ratio'],3)}")

    with open(args.json, "w") as f:
        json.dump(rows, f, indent=1)

    if args.markdown:
        with open(args.markdown, "w") as f:
            f.write("| cell | compute (s) | memory (s) | collective (s) | dominant "
                    "| MODEL_FLOPS | useful/HLO |\n|---|---|---|---|---|---|---|\n")
            for r in rows:
                f.write(f"| {r['cell']} | {r['compute_s']:.4f} | {r['memory_s']:.4f} "
                        f"| {r['collective_s']:.4f} | {r['dominant'].replace('_s','')} "
                        f"| {r['model_flops']:.3e} "
                        f"| {r['useful_ratio'] and round(r['useful_ratio'], 3)} |\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())

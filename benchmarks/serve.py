"""Query-service load bench: sustained throughput, latency, anchor sharing.

Drives ``core/service.py`` under the deterministic seeded open-loop load
plan from ``repro.launch.serve.generate_load`` — many clients, mixed
semirings/sources/window extents, bursty arrivals — and accounts one row:

* **Exact (gate-strict) fields**: queries admitted/completed, turn/launch/
  lane counts, batch occupancy (milli-lanes per launch — an integer so the
  gate compares it strictly), anchor rebuild/hop/hit counts for the
  service AND for the solo stream-at-a-time baseline, and the bit-identity
  boolean. All are pure functions of the seed: scheduling and packing are
  count-based, never wall-clock-based.
* **Ratio fields** (``scripts/bench_gate.py`` compares them within
  ``--time-tol`` both ways): sustained queries/sec, p50/p99
  admission→completion latency.

The row doubles as the acceptance check (assertions, not just numbers):
every client's every window must be bit-identical to running that
client's stream solo (``run_window_stream_batched``, fresh anchor cache),
the service must perform STRICTLY FEWER total anchor rebuilds than the
solo runs combined (clients sharing a query key share anchor states), and
at least one launch must pack lanes from more than one client
(batch occupancy > 1).

    PYTHONPATH=src python -m benchmarks.serve [--smoke]

CI runs this via the bench job's ``benchmarks.run --smoke`` harness pass
and diffs the emitted BENCH_serve.json against the committed smoke
baseline (docs/BENCHMARKS.md).
"""

import argparse
import time

import numpy as np

from repro.core import SnapshotStore, run_window_stream_batched
from repro.graph import make_evolving_sequence
from repro.graph.semiring import ALL_SEMIRINGS
from repro.launch.serve import generate_load, run_service_load


def run_serve_bench(n=2_000, e=20_000, snaps=8, batch_changes=600,
                    num_clients=6, seed=7, lane_budget=8, turn_budget=None):
    """One row of service-vs-solo accounting + throughput/latency."""
    seq = make_evolving_sequence(n, e, snaps, batch_changes, seed=seed)
    store = SnapshotStore(seq)
    specs, schedule = generate_load(snaps, num_clients=num_clients, seed=seed)

    # Warm-up: compiles every packed trace and builds every block the load
    # touches; the timed run then starts with warm blocks and cold anchors
    # (anchor state is the query-side cache under test).
    warm, warm_clients = run_service_load(store, specs, schedule,
                                          lane_budget=lane_budget,
                                          turn_budget=turn_budget)
    for client in list(warm.clients):
        warm.unregister(client)
    store.release(("AS",))

    t0 = time.perf_counter()
    service, clients = run_service_load(store, specs, schedule,
                                        lane_budget=lane_budget,
                                        turn_budget=turn_budget)
    wall_s = time.perf_counter() - t0
    m = service.metrics()
    for client in list(service.clients):
        service.unregister(client)

    # Solo baseline: each client's stream runs alone with a fresh anchor
    # cache (stream-at-a-time — what the repo did before the service).
    solo_rebuilds = solo_hops = 0
    bit_identical = True
    for spec, client in zip(specs, clients):
        store.release(("AS",))
        solo = run_window_stream_batched(
            store, ALL_SEMIRINGS[spec["alg"]], spec["source"],
            windows=spec["windows"],
            campaign_width=spec["campaign_width"])
        solo_rebuilds += solo.anchor_rebuilds
        solo_hops += solo.anchor_hops
        for wnd, vals in solo.results.items():
            if not np.array_equal(np.asarray(vals),
                                  np.asarray(client.results[wnd])):
                bit_identical = False

    assert bit_identical, "service results diverged from solo streams"
    assert m.anchor_rebuilds < solo_rebuilds, (
        f"service must rebuild strictly fewer anchors than solo "
        f"({m.anchor_rebuilds} vs {solo_rebuilds})")
    assert m.batch_occupancy > 1, (
        f"admission layer never coalesced: occupancy {m.batch_occupancy}")
    assert any(len(set(rec.clients)) > 1 for rec in service.launch_log), (
        "no launch packed lanes from more than one client")
    assert m.stable_fraction_milli > 0, (
        f"service must observe a positive stable fraction "
        f"(got {m.stable_fraction_milli}‰)")

    return {
        "clients": num_clients,
        "admitted": m.admitted,
        "completed": m.completed,
        "turns": m.turns,
        "launches": m.launches,
        "lanes": m.lanes,
        "padded_lanes": m.padded_lanes,
        "occupancy_milli": int(round(1000 * m.lanes / m.launches)),
        "rebuilds_service": m.anchor_rebuilds,
        "hops_service": m.anchor_hops,
        "hits_service": m.anchor_hits,
        "rebuilds_solo": solo_rebuilds,
        "hops_solo": solo_hops,
        # stable-vertex analysis: fraction of seeded vertex-lanes already at
        # their fixpoint (exact ‰ integer — count-based, seed-deterministic)
        "stable_fraction_milli": m.stable_fraction_milli,
        "bit_identical": bit_identical,
        "wall_s": wall_s,
        "queries_per_sec": m.queries_per_sec,
        "p50_us": m.latency_us(50),
        "p99_us": m.latency_us(99),
    }


SMOKE = dict(n=400, e=3_000, snaps=6, batch_changes=200, num_clients=4,
             seed=7)


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="tiny graph (CI smoke run)")
    args = p.parse_args(argv)
    r = run_serve_bench(**(SMOKE if args.smoke else {}))
    print(f"clients={r['clients']}  {r['completed']}/{r['admitted']} queries  "
          f"turns={r['turns']}  launches={r['launches']}  "
          f"occupancy={r['occupancy_milli'] / 1000:.2f} "
          f"({r['padded_lanes']} padded lanes)  "
          f"anchors {r['rebuilds_service']} (+{r['hops_service']} hops "
          f"+{r['hits_service']} hits) vs solo {r['rebuilds_solo']} "
          f"(+{r['hops_solo']} hops)  stable {r['stable_fraction_milli']}‰  "
          f"{r['queries_per_sec']:.1f} q/s  "
          f"p50 {r['p50_us'] / 1e3:.1f}ms  p99 {r['p99_us'] / 1e3:.1f}ms  "
          f"bit-identical ✓")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Sliding-window executors: batched one-launch slide vs sequential re-hops.

For each window width the full slide (every width-W window over the
sequence) runs twice — sequential ``run_window_slide`` (one incremental hop
per window) and batched ``run_window_slide_batched`` (every hop a lane of
ONE stacked launch, core/window.py) — after a warm-up so compile time is
excluded. Results are bit-compared each round, so a timing row is also an
equivalence check. This is the window analogue of benchmarks/tg_sharing.py:
same level-batching machinery, windows instead of plan levels.

    PYTHONPATH=src python -m benchmarks.window_slide [--smoke]

``--smoke`` runs a tiny graph for a seconds-long local check; CI covers
the same path via the bench job's ``benchmarks.run --smoke`` harness pass
(see docs/BENCHMARKS.md for the emitted BENCH_*.json schema).
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.core import (
    SnapshotStore,
    run_window_slide,
    run_window_slide_batched,
    slide_windows,
)
from repro.graph import make_evolving_sequence
from repro.graph.semiring import ALL_SEMIRINGS


def run_window_slide_bench(n=10_000, e=100_000, snaps=12, batch_changes=4_000,
                           widths=(2, 4, 8), step=1, seed=0, alg="sssp",
                           source=0):
    """Rows of {width, lanes, added_edges, seq_s, bat_s, bat_speedup, ...}."""
    sr = ALL_SEMIRINGS[alg]
    seq = make_evolving_sequence(n, e, snaps, batch_changes, seed=seed)
    store = SnapshotStore(seq)
    rows = []
    for width in widths:
        windows = slide_windows(snaps, width, step=step)
        # warm-up (compiles), then the timed runs
        run_window_slide(store, sr, source, width, step=step)
        seq_run = run_window_slide(store, sr, source, width, step=step)
        run_window_slide_batched(store, sr, source, width, step=step)
        bat_run = run_window_slide_batched(store, sr, source, width, step=step)
        for wnd in windows:
            np.testing.assert_array_equal(
                np.asarray(bat_run.results[wnd]),
                np.asarray(seq_run.results[wnd]),
                err_msg=f"width {width} window {wnd}: batched != sequential")
        rows.append({
            "width": width,
            "lanes": len(windows),
            "added_edges": seq_run.added_edges,
            "seq_s": seq_run.wall_s,
            "bat_s": bat_run.wall_s,
            "bat_speedup": seq_run.wall_s / bat_run.wall_s,
            "seq_work": sum(h.edge_work for h in seq_run.hop_stats),
            "bat_work": sum(h.edge_work for h in bat_run.hop_stats),
        })
    return rows


SMOKE = dict(n=400, e=3_000, snaps=6, batch_changes=200, widths=(2, 3))


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="tiny graph (CI smoke run)")
    args = p.parse_args(argv)
    rows = run_window_slide_bench(**(SMOKE if args.smoke else {}))
    for r in rows:
        print(f"width={r['width']:3d}  lanes={r['lanes']:3d}  "
              f"Δ-edges {r['added_edges']:>10,}  "
              f"seq {r['seq_s']:.3f}s  batched {r['bat_s']:.3f}s  "
              f"({r['bat_speedup']:.2f}x, work {r['seq_work']:,.0f} vs "
              f"{r['bat_work']:,.0f})  bit-identical ✓")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Benchmark harness entry: one function per paper table/claim.

Prints ``name,us_per_call,derived`` CSV per the harness contract, and
writes one machine-readable ``BENCH_<bench>.json`` per bench into
``--out-dir`` (default: current directory) — the schema is documented in
docs/BENCHMARKS.md. Scales are container-sized (DESIGN.md §7.4); pass
--full for larger graphs.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only BENCH] \
        [--out-dir DIR]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

BENCH_SCHEMA_VERSION = 1


def bench_table1(full: bool):
    from benchmarks.table1 import run_table1
    graphs = {"RM-20k": (20_000, 200_000)} if not full else \
        {"RM-100k": (100_000, 1_000_000), "RM-20k": (20_000, 200_000)}
    t0 = time.perf_counter()
    rows = run_table1(graphs, num_snapshots=6 if not full else 12,
                      batch_changes=6_000 if not full else 20_000)
    dt = time.perf_counter() - t0
    out = []
    for r in rows:
        assert r.verified, f"table1 row {r.graph}/{r.alg} failed verification"
        out.append((f"table1/{r.graph}/{r.alg}/ks", r.ks_time_s * 1e6,
                    f"dh={r.dh_speedup:.2f}x ws={r.ws_speedup:.2f}x "
                    f"dhb={r.dhb_speedup:.2f}x"))
    spe = [r.ws_speedup for r in rows]
    out.append(("table1/summary", dt * 1e6,
                f"ws-speedup-range={min(spe):.2f}x..{max(spe):.2f}x"))
    return out


def bench_del_vs_add(full: bool):
    from benchmarks.del_vs_add import run_del_vs_add
    out = []
    for alg in ("bfs", "sssp", "sswp", "ssnp", "viterbi"):
        r = run_del_vs_add(alg=alg, n=10_000, e=100_000, k=3_000,
                           repeats=2 if not full else 5)
        assert r["verified"], f"del_vs_add {alg} verification failed"
        out.append((f"del_vs_add/{alg}", r["t_del_s"] * 1e6,
                    f"del/add-time={r['ratio_time']:.2f}x work={r['ratio_work']:.2f}x"))
    return out


def bench_tg_sharing(full: bool):
    from benchmarks.tg_sharing import run_tg_sharing
    rows = run_tg_sharing(n=10_000, e=100_000, batch_changes=4_000,
                          windows=(4, 8, 16) if not full else (4, 8, 16, 32))
    out = []
    for r in rows:
        out.append((f"tg_sharing/window{r['window']}",
                    r["optimal_bat_s"] * 1e6,
                    f"dh={r['dh_edges']} opt={r['optimal_edges']} "
                    f"saving={r['optimal_saving']:.1%} "
                    f"batched-speedup dh={r['dh_bat_speedup']:.2f}x "
                    f"bisect={r['bisect_bat_speedup']:.2f}x "
                    f"opt={r['optimal_bat_speedup']:.2f}x"))
    return out


def bench_kernels(full: bool):
    """Interpret-mode kernels vs jnp oracle: correctness + oracle timing."""
    import jax
    import numpy as np
    from repro.kernels import edge_relax
    from repro.kernels.edge_relax.ref import edge_relax_ref

    n, e = 5_000, 60_000
    key = jax.random.PRNGKey(0)
    vals = jax.random.uniform(key, (n,)) * 10
    src = jax.random.randint(jax.random.PRNGKey(1), (e,), 0, n)
    dst = jax.random.randint(jax.random.PRNGKey(2), (e,), 0, n)
    w = jax.random.uniform(jax.random.PRNGKey(3), (e,)) + 0.01
    out = []
    for op in ("min_plus", "max_min"):
        a = edge_relax(vals, src, dst, w, op=op, num_nodes=n)
        b = edge_relax_ref(vals, src, dst, w, op=op, num_nodes=n)
        fin = np.isfinite(np.asarray(b))
        assert np.allclose(np.asarray(a)[fin], np.asarray(b)[fin], rtol=1e-6)
        t0 = time.perf_counter()
        edge_relax_ref(vals, src, dst, w, op=op, num_nodes=n).block_until_ready()
        dt = time.perf_counter() - t0
        out.append((f"kernels/edge_relax/{op}", dt * 1e6, "allclose=1"))
    return out


def bench_window_slide(full: bool):
    from benchmarks.window_slide import run_window_slide_bench
    rows = run_window_slide_bench(widths=(2, 4, 8) if not full
                                  else (2, 4, 8, 16),
                                  snaps=12 if not full else 24)
    # equivalence is asserted inside run_window_slide_bench (bit-compare per
    # window); a mismatch raises there and the harness reports FAILED
    out = []
    for r in rows:
        out.append((f"window_slide/width{r['width']}", r["bat_s"] * 1e6,
                    f"lanes={r['lanes']} edges={r['added_edges']} "
                    f"batched-speedup={r['bat_speedup']:.2f}x"))
    return out


BENCHES = {
    "table1": bench_table1,
    "del_vs_add": bench_del_vs_add,
    "tg_sharing": bench_tg_sharing,
    "window_slide": bench_window_slide,
    "kernels": bench_kernels,
}


def write_bench_json(out_dir: pathlib.Path, bench: str, status: str,
                     rows, error: str | None) -> pathlib.Path:
    """Emit BENCH_<bench>.json (schema: docs/BENCHMARKS.md)."""
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"BENCH_{bench}.json"
    path.write_text(json.dumps({
        "bench": bench,
        "schema_version": BENCH_SCHEMA_VERSION,
        "generated_unix": time.time(),
        "status": status,
        "error": error,
        "rows": [{"name": n, "us_per_call": us, "derived": d}
                 for n, us, d in rows],
    }, indent=2) + "\n")
    return path


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true")
    p.add_argument("--only", default=None, choices=list(BENCHES))
    p.add_argument("--out-dir", default=".", type=pathlib.Path,
                   help="directory for the BENCH_<bench>.json files")
    args = p.parse_args(argv)

    print("name,us_per_call,derived")
    ok = True
    for name, fn in BENCHES.items():
        if args.only and name != args.only:
            continue
        try:
            rows = list(fn(args.full))
            for row in rows:
                print(f"{row[0]},{row[1]:.1f},{row[2]}")
            write_bench_json(args.out_dir, name, "ok", rows, None)
        except Exception as exc:  # noqa: BLE001
            ok = False
            print(f"{name},NaN,FAILED:{exc}")
            write_bench_json(args.out_dir, name, "failed", [], str(exc))
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())

"""Benchmark harness entry: one function per paper table/claim.

Prints ``name,us_per_call,derived`` CSV per the harness contract, and
writes one machine-readable ``BENCH_<bench>.json`` per bench into
``--out-dir`` (default: current directory; created — parents included — if
missing, so fresh CI runners and first local runs never trip on it) — the
schema is documented in docs/BENCHMARKS.md. Scales are container-sized
(DESIGN.md §7.4); pass --full for larger graphs, or --smoke for the
tiny-graph tier CI runs on every push (each bench still asserts its own
correctness at smoke scale).

Schema v2: a row may carry an ``exact`` dict of machine-independent fields
(edge/work counts, verification booleans, lane counts). The CI
perf-regression gate (scripts/bench_gate.py) diffs each run's JSON against
the committed smoke baselines in benchmarks/baselines/smoke/ — wall-time
within a tolerance factor, ``exact`` fields strictly equal.

    PYTHONPATH=src python -m benchmarks.run [--full | --smoke] \
        [--only BENCH] [--out-dir DIR]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

BENCH_SCHEMA_VERSION = 2


def bench_table1(scale: str):
    from benchmarks.table1 import run_table1
    graphs, snaps, changes = {
        "smoke": ({"RM-2k": (2_000, 20_000)}, 4, 600),
        "default": ({"RM-20k": (20_000, 200_000)}, 6, 6_000),
        "full": ({"RM-100k": (100_000, 1_000_000),
                  "RM-20k": (20_000, 200_000)}, 12, 20_000),
    }[scale]
    t0 = time.perf_counter()
    rows = run_table1(graphs, num_snapshots=snaps, batch_changes=changes)
    dt = time.perf_counter() - t0
    out = []
    for r in rows:
        assert r.verified, f"table1 row {r.graph}/{r.alg} failed verification"
        out.append((f"table1/{r.graph}/{r.alg}/ks", r.ks_time_s * 1e6,
                    f"dh={r.dh_speedup:.2f}x ws={r.ws_speedup:.2f}x "
                    f"dhb={r.dhb_speedup:.2f}x",
                    {"verified": True}))
    spe = [r.ws_speedup for r in rows]
    out.append(("table1/summary", dt * 1e6,
                f"ws-speedup-range={min(spe):.2f}x..{max(spe):.2f}x",
                {"rows": len(rows)}))
    return out


def bench_del_vs_add(scale: str):
    from benchmarks.del_vs_add import run_del_vs_add
    n, e, k, repeats = {"smoke": (2_000, 20_000, 600, 1),
                        "default": (10_000, 100_000, 3_000, 2),
                        "full": (10_000, 100_000, 3_000, 5)}[scale]
    out = []
    for alg in ("bfs", "sssp", "sswp", "ssnp", "viterbi"):
        r = run_del_vs_add(alg=alg, n=n, e=e, k=k, repeats=repeats)
        assert r["verified"], f"del_vs_add {alg} verification failed"
        out.append((f"del_vs_add/{alg}", r["t_del_s"] * 1e6,
                    f"del/add-time={r['ratio_time']:.2f}x work={r['ratio_work']:.2f}x",
                    {"verified": True,
                     "ratio_work": round(float(r["ratio_work"]), 4)}))
    return out


def bench_tg_sharing(scale: str):
    from benchmarks.tg_sharing import run_tg_sharing
    n, e, changes, windows = {
        "smoke": (2_000, 20_000, 800, (4,)),
        "default": (10_000, 100_000, 4_000, (4, 8, 16)),
        "full": (10_000, 100_000, 4_000, (4, 8, 16, 32)),
    }[scale]
    rows = run_tg_sharing(n=n, e=e, batch_changes=changes, windows=windows)
    out = []
    for r in rows:
        out.append((f"tg_sharing/window{r['window']}",
                    r["optimal_bat_s"] * 1e6,
                    f"dh={r['dh_edges']} opt={r['optimal_edges']} "
                    f"saving={r['optimal_saving']:.1%} "
                    f"batched-speedup dh={r['dh_bat_speedup']:.2f}x "
                    f"bisect={r['bisect_bat_speedup']:.2f}x "
                    f"opt={r['optimal_bat_speedup']:.2f}x",
                    {"dh_edges": int(r["dh_edges"]),
                     "optimal_edges": int(r["optimal_edges"])}))
    return out


def bench_kernels(scale: str):
    """Interpret-mode kernels vs jnp oracle: correctness + oracle timing."""
    import jax
    import numpy as np
    from repro.kernels import edge_relax
    from repro.kernels.edge_relax.ref import edge_relax_ref

    n, e = (1_000, 12_000) if scale == "smoke" else (5_000, 60_000)
    key = jax.random.PRNGKey(0)
    vals = jax.random.uniform(key, (n,)) * 10
    src = jax.random.randint(jax.random.PRNGKey(1), (e,), 0, n)
    dst = jax.random.randint(jax.random.PRNGKey(2), (e,), 0, n)
    w = jax.random.uniform(jax.random.PRNGKey(3), (e,)) + 0.01
    out = []
    for op in ("min_plus", "max_min"):
        a = edge_relax(vals, src, dst, w, op=op, num_nodes=n)
        b = edge_relax_ref(vals, src, dst, w, op=op, num_nodes=n)
        fin = np.isfinite(np.asarray(b))
        assert np.allclose(np.asarray(a)[fin], np.asarray(b)[fin], rtol=1e-6)
        t0 = time.perf_counter()
        edge_relax_ref(vals, src, dst, w, op=op, num_nodes=n).block_until_ready()
        dt = time.perf_counter() - t0
        out.append((f"kernels/edge_relax/{op}", dt * 1e6, "allclose=1",
                    {"allclose": True}))
    return out


def bench_window_slide(scale: str):
    from benchmarks.window_slide import run_window_slide_bench
    widths, snaps = {"smoke": ((2,), 6),
                     "default": ((2, 4, 8), 12),
                     "full": ((2, 4, 8, 16), 24)}[scale]
    rows = run_window_slide_bench(widths=widths, snaps=snaps)
    # equivalence is asserted inside run_window_slide_bench (bit-compare per
    # window); a mismatch raises there and the harness reports FAILED
    out = []
    for r in rows:
        out.append((f"window_slide/width{r['width']}", r["bat_s"] * 1e6,
                    f"lanes={r['lanes']} edges={r['added_edges']} "
                    f"batched-speedup={r['bat_speedup']:.2f}x",
                    {"lanes": int(r["lanes"]),
                     "added_edges": int(r["added_edges"]),
                     "edge_work": int(round(r["bat_work"]))}))
    return out


def bench_window_stream(scale: str):
    from benchmarks.window_stream import run_window_stream_bench
    widths, snaps, cw = {"smoke": ((2, 3), 6, 2),
                         "default": ((3, 4), 12, 3),
                         "full": ((4, 8), 24, 4)}[scale]
    rows = run_window_stream_bench(widths=widths, snaps=snaps,
                                   campaign_width=cw)
    # bit-identity vs cold campaigns AND strictly-fewer-rebuilds are
    # asserted inside run_window_stream_bench; a failure raises there
    out = []
    for r in rows:
        out.append((f"window_stream/width{r['width']}", r["stream_s"] * 1e6,
                    f"campaigns={r['campaigns']} "
                    f"rebuilds={r['rebuilds_stream']}+{r['anchor_hops']}hops "
                    f"vs cold {r['rebuilds_cold']} "
                    f"speedup={r['stream_speedup']:.2f}x",
                    {"campaigns": int(r["campaigns"]),
                     "rebuilds_stream": int(r["rebuilds_stream"]),
                     "anchor_hops": int(r["anchor_hops"]),
                     "rebuilds_cold": int(r["rebuilds_cold"]),
                     "added_edges": int(r["added_edges"]),
                     "anchor_delta_edges": int(r["anchor_delta_edges"]),
                     "edge_work": int(round(r["stream_work"]))}))
    return out


def bench_window_overlap(scale: str):
    from benchmarks.window_stream import run_window_overlap_bench
    params = {
        "smoke": dict(n=400, e=3_000, snaps=6, batch_changes=200,
                      num_streams=2, width=3),
        "default": dict(snaps=12, num_streams=3, width=4),
        "full": dict(n=20_000, e=200_000, snaps=16, batch_changes=8_000,
                     num_streams=4, width=6),
    }[scale]
    rows = run_window_overlap_bench(**params)
    # bit-identity shared-vs-solo AND strictly-fewer-total-rebuilds are
    # asserted inside run_window_overlap_bench; a failure raises there
    out = []
    for r in rows:
        out.append((f"window_overlap/streams{r['streams']}",
                    r["shared_s"] * 1e6,
                    f"links={r['chain_links']} "
                    f"rebuilds={r['rebuilds_shared']}+{r['hops_shared']}hops"
                    f"+{r['hits_shared']}hits vs solo {r['rebuilds_solo']} "
                    f"speedup={r['shared_speedup']:.2f}x "
                    f"auto-widths={r['auto_widths']}",
                    {"streams": int(r["streams"]),
                     "chain_links": int(r["chain_links"]),
                     "rebuilds_shared": int(r["rebuilds_shared"]),
                     "hops_shared": int(r["hops_shared"]),
                     "hits_shared": int(r["hits_shared"]),
                     "rebuilds_solo": int(r["rebuilds_solo"]),
                     "hops_solo": int(r["hops_solo"]),
                     "added_edges": int(r["added_edges"]),
                     "anchor_delta_edges": int(r["anchor_delta_edges"]),
                     "shared_work": int(round(r["shared_work"])),
                     "solo_work": int(round(r["solo_work"])),
                     "auto_widths": [int(w) for w in r["auto_widths"]]}))
    return out


def bench_evolve(scale: str):
    """End-to-end wall time of every executor mode the evolve driver runs,
    verified against from-scratch fixpoints — the committed seed baseline
    (benchmarks/baselines/BENCH_evolve.json) that future PRs diff against.
    """
    import numpy as np

    from repro.core import (
        SnapshotStore,
        optimal_plan,
        run_direct_hop,
        run_direct_hop_batched,
        run_kickstarter_stream,
        run_plan,
        run_plan_batched,
        run_window_slide,
        run_window_slide_batched,
        run_window_stream_batched,
    )
    from repro.graph import make_evolving_sequence, run_to_fixpoint
    from repro.graph.semiring import ALL_SEMIRINGS

    n, e, snaps, changes, width = {
        "smoke": (2_000, 20_000, 5, 600, 3),
        "default": (10_000, 100_000, 8, 3_000, 4),
        "full": (20_000, 200_000, 10, 10_000, 4),
    }[scale]
    sr = ALL_SEMIRINGS["sssp"]
    store = SnapshotStore(make_evolving_sequence(n, e, snaps, changes, seed=0))
    plan = optimal_plan(store)

    def timed(fn):
        fn()  # warm up (compile + block caches)
        t0 = time.perf_counter()
        res = fn()
        return time.perf_counter() - t0, res

    t_ks, (ks_res, _) = timed(lambda: run_kickstarter_stream(store, sr, 0))
    modes = [
        ("dh", lambda: run_direct_hop(store, sr, 0)),
        ("dhb", lambda: run_direct_hop_batched(store, sr, 0)),
        ("ws", lambda: run_plan(store, plan, sr, 0)),
        ("wsb", lambda: run_plan_batched(store, plan, sr, 0)),
        ("window_seq", lambda: run_window_slide(store, sr, 0, width)),
        ("window_bat", lambda: run_window_slide_batched(store, sr, 0, width)),
        # anchor cache released per run: times the streamed path (1 rebuild
        # + incremental hops), not the all-hits replay
        ("window_stream", lambda: (
            store.release(("AS",)),
            run_window_stream_batched(store, sr, 0, width,
                                      campaign_width=2))[1]),
    ]
    out = [("evolve/ks", t_ks * 1e6, f"snapshots={snaps} edges~{e}",
            {"snapshots": snaps})]
    runs = {}
    for name, fn in modes:
        dt, res = timed(fn)
        runs[name] = res
        out.append((f"evolve/{name}", dt * 1e6,
                    f"speedup-vs-ks={t_ks / dt:.2f}x",
                    {"verified": True}))
    for i in range(snaps):
        ref = run_to_fixpoint(store.snapshot_view(i), sr, 0).values
        for name in ("dh", "dhb"):
            np.testing.assert_allclose(np.asarray(runs[name].results[i]),
                                       np.asarray(ref), rtol=1e-6)
        for name in ("ws", "wsb"):
            np.testing.assert_allclose(np.asarray(runs[name].results[i]),
                                       np.asarray(ref), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(ks_res[i]), np.asarray(ref),
                                   rtol=1e-6)
    for wnd, vals in runs["window_bat"].results.items():
        np.testing.assert_array_equal(np.asarray(vals),
                                      np.asarray(runs["window_seq"].results[wnd]))
    # the streamed campaigns anchor differently per campaign, yet the
    # monotone fixpoint is unique — still bit-identical to the slide
    for wnd, vals in runs["window_stream"].results.items():
        np.testing.assert_array_equal(np.asarray(vals),
                                      np.asarray(runs["window_seq"].results[wnd]))
    return out


def bench_serve(scale: str):
    from benchmarks.serve import run_serve_bench
    params = {
        "smoke": dict(n=400, e=3_000, snaps=6, batch_changes=200,
                      num_clients=4, seed=7),
        "default": dict(),
        "full": dict(n=10_000, e=100_000, snaps=12, batch_changes=4_000,
                     num_clients=8, seed=7),
    }[scale]
    r = run_serve_bench(**params)
    # bit-identity vs solo streams, strictly-fewer-rebuilds and
    # occupancy > 1 are asserted inside run_serve_bench
    return [("serve/load", r["wall_s"] * 1e6,
             f"clients={r['clients']} {r['completed']}/{r['admitted']} "
             f"queries occupancy={r['occupancy_milli'] / 1000:.2f} "
             f"rebuilds={r['rebuilds_service']}+{r['hops_service']}hops "
             f"vs solo {r['rebuilds_solo']} "
             f"qps={r['queries_per_sec']:.1f} "
             f"p99={r['p99_us'] / 1e3:.1f}ms",
             {"clients": int(r["clients"]),
              "admitted": int(r["admitted"]),
              "completed": int(r["completed"]),
              "turns": int(r["turns"]),
              "launches": int(r["launches"]),
              "lanes": int(r["lanes"]),
              "padded_lanes": int(r["padded_lanes"]),
              "occupancy_milli": int(r["occupancy_milli"]),
              "rebuilds_service": int(r["rebuilds_service"]),
              "hops_service": int(r["hops_service"]),
              "hits_service": int(r["hits_service"]),
              "rebuilds_solo": int(r["rebuilds_solo"]),
              "hops_solo": int(r["hops_solo"]),
              "bit_identical": bool(r["bit_identical"])},
             {"queries_per_sec": round(float(r["queries_per_sec"]), 2),
              "p50_us": round(float(r["p50_us"]), 1),
              "p99_us": round(float(r["p99_us"]), 1)})]


BENCHES = {
    "table1": bench_table1,
    "del_vs_add": bench_del_vs_add,
    "tg_sharing": bench_tg_sharing,
    "window_slide": bench_window_slide,
    "window_stream": bench_window_stream,
    "window_overlap": bench_window_overlap,
    "serve": bench_serve,
    "kernels": bench_kernels,
    "evolve": bench_evolve,
}


def ensure_out_dir(out_dir: pathlib.Path) -> pathlib.Path:
    """Create ``out_dir`` (parents included) up front with a clear error.

    Centralized so a fresh CI runner or first local run never trips on a
    missing directory mid-run, and a path that collides with an existing
    FILE fails immediately with an actionable message instead of at the
    first JSON write.
    """
    try:
        out_dir.mkdir(parents=True, exist_ok=True)
    except (FileExistsError, NotADirectoryError) as exc:
        raise SystemExit(
            f"--out-dir {out_dir} collides with an existing file: {exc}") from exc
    return out_dir


def write_bench_json(out_dir: pathlib.Path, bench: str, status: str,
                     rows, error: str | None) -> pathlib.Path:
    """Emit BENCH_<bench>.json (schema v2: docs/BENCHMARKS.md).

    Rows are ``(name, us_per_call, derived)``, ``(name, us_per_call,
    derived, exact)`` or ``(name, us_per_call, derived, exact, ratio)`` —
    ``exact`` holds the machine-independent fields (edge/work counts,
    verification booleans) the regression gate (scripts/bench_gate.py)
    compares strictly; ``ratio`` holds machine-dependent rate/latency
    fields (queries/sec, p50/p99 µs) the gate compares within the same
    tolerance factor as wall times, in BOTH directions; rows without
    ratio fields omit the key entirely.
    """
    ensure_out_dir(out_dir)
    path = out_dir / f"BENCH_{bench}.json"
    path.write_text(json.dumps({
        "bench": bench,
        "schema_version": BENCH_SCHEMA_VERSION,
        "generated_unix": time.time(),
        "status": status,
        "error": error,
        "rows": [dict({"name": r[0], "us_per_call": r[1], "derived": r[2],
                       "exact": r[3] if len(r) > 3 else {}},
                      **({"ratio": r[4]} if len(r) > 4 and r[4] else {}))
                 for r in rows],
    }, indent=2) + "\n")
    return path


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    scale_group = p.add_mutually_exclusive_group()
    scale_group.add_argument("--full", action="store_true",
                             help="larger graphs (paper-representative)")
    scale_group.add_argument("--smoke", action="store_true",
                             help="tiny graphs — the CI tier: correctness "
                                  "asserts + artifact emission in minutes")
    p.add_argument("--only", default=None, choices=list(BENCHES))
    p.add_argument("--out-dir", default=".", type=pathlib.Path,
                   help="directory for the BENCH_<bench>.json files")
    args = p.parse_args(argv)
    scale = "full" if args.full else "smoke" if args.smoke else "default"
    ensure_out_dir(args.out_dir)

    print("name,us_per_call,derived")
    ok = True
    for name, fn in BENCHES.items():
        if args.only and name != args.only:
            continue
        try:
            rows = list(fn(scale))
            for row in rows:
                print(f"{row[0]},{row[1]:.1f},{row[2]}")
            write_bench_json(args.out_dir, name, "ok", rows, None)
        except Exception as exc:  # noqa: BLE001
            ok = False
            print(f"{name},NaN,FAILED:{exc}")
            write_bench_json(args.out_dir, name, "failed", [], str(exc))
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())

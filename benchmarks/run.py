"""Benchmark harness entry: one function per paper table/claim.

Prints ``name,us_per_call,derived`` CSV per the harness contract, and
writes one machine-readable ``BENCH_<bench>.json`` per bench into
``--out-dir`` (default: current directory; created — parents included — if
missing, so fresh CI runners and first local runs never trip on it) — the
schema is documented in docs/BENCHMARKS.md. Scales are container-sized
(DESIGN.md §7.4); pass --full for larger graphs, or --smoke for the
tiny-graph tier CI runs on every push (each bench still asserts its own
correctness at smoke scale).

Schema v2: a row may carry an ``exact`` dict of machine-independent fields
(edge/work counts, verification booleans, lane counts). The CI
perf-regression gate (scripts/bench_gate.py) diffs each run's JSON against
the committed smoke baselines in benchmarks/baselines/smoke/ — wall-time
within a tolerance factor, ``exact`` fields strictly equal.

    PYTHONPATH=src python -m benchmarks.run [--full | --smoke] \
        [--only BENCH] [--out-dir DIR]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

BENCH_SCHEMA_VERSION = 2

# Per-bench scale tiers. Single source of truth: the bench functions below
# read their parameters from here, and ``--list`` prints the same dicts —
# the listing can never drift from what actually runs.
SCALES = {
    "table1": {
        "smoke": dict(graphs={"RM-2k": (2_000, 20_000)}, snaps=4,
                      changes=600),
        "default": dict(graphs={"RM-20k": (20_000, 200_000)}, snaps=6,
                        changes=6_000),
        "full": dict(graphs={"RM-100k": (100_000, 1_000_000),
                             "RM-20k": (20_000, 200_000)}, snaps=12,
                     changes=20_000),
    },
    "del_vs_add": {
        "smoke": dict(n=2_000, e=20_000, k=600, repeats=1),
        "default": dict(n=10_000, e=100_000, k=3_000, repeats=2),
        "full": dict(n=10_000, e=100_000, k=3_000, repeats=5),
    },
    "tg_sharing": {
        "smoke": dict(n=2_000, e=20_000, batch_changes=800, windows=(4,)),
        "default": dict(n=10_000, e=100_000, batch_changes=4_000,
                        windows=(4, 8, 16)),
        "full": dict(n=10_000, e=100_000, batch_changes=4_000,
                     windows=(4, 8, 16, 32)),
    },
    "window_slide": {
        "smoke": dict(widths=(2,), snaps=6),
        "default": dict(widths=(2, 4, 8), snaps=12),
        "full": dict(widths=(2, 4, 8, 16), snaps=24),
    },
    "window_stream": {
        "smoke": dict(widths=(2, 3), snaps=6, campaign_width=2),
        "default": dict(widths=(3, 4), snaps=12, campaign_width=3),
        "full": dict(widths=(4, 8), snaps=24, campaign_width=4),
    },
    "window_overlap": {
        "smoke": dict(n=400, e=3_000, snaps=6, batch_changes=200,
                      num_streams=2, width=3),
        "default": dict(snaps=12, num_streams=3, width=4),
        "full": dict(n=20_000, e=200_000, snaps=16, batch_changes=8_000,
                     num_streams=4, width=6),
    },
    "serve": {
        "smoke": dict(n=400, e=3_000, snaps=6, batch_changes=200,
                      num_clients=4, seed=7),
        "default": dict(),
        "full": dict(n=10_000, e=100_000, snaps=12, batch_changes=4_000,
                     num_clients=8, seed=7),
    },
    "kernels": {
        "smoke": dict(n=1_000, e=12_000, fused_k=4, plan_n=400,
                      plan_e=3_000, plan_snaps=6, plan_changes=200,
                      plan_width=3),
        "default": dict(n=5_000, e=60_000, fused_k=8, plan_n=2_000,
                        plan_e=20_000, plan_snaps=8, plan_changes=600,
                        plan_width=3),
        "full": dict(n=5_000, e=60_000, fused_k=8, plan_n=2_000,
                     plan_e=20_000, plan_snaps=8, plan_changes=600,
                     plan_width=3),
    },
    "evolve": {
        "smoke": dict(n=2_000, e=20_000, snaps=5, changes=600, width=3),
        "default": dict(n=10_000, e=100_000, snaps=8, changes=3_000,
                        width=4),
        "full": dict(n=20_000, e=200_000, snaps=10, changes=10_000,
                     width=4),
    },
    "ingest": {
        "smoke": dict(n=400, e=3_000, snaps=6, changes=200, width=3,
                      campaign_width=2, max_pending=1_024, seed=7),
        "default": dict(n=2_000, e=20_000, snaps=8, changes=600, width=3,
                        campaign_width=2, max_pending=4_096, seed=7),
        "full": dict(n=10_000, e=100_000, snaps=12, changes=3_000, width=4,
                     campaign_width=3, max_pending=16_384, seed=7),
    },
}


def bench_table1(scale: str):
    """Paper Table 1: DH/WS/DHB executor speedups vs the KS baseline."""
    from benchmarks.table1 import run_table1
    p = SCALES["table1"][scale]
    graphs, snaps, changes = p["graphs"], p["snaps"], p["changes"]
    t0 = time.perf_counter()
    rows = run_table1(graphs, num_snapshots=snaps, batch_changes=changes)
    dt = time.perf_counter() - t0
    out = []
    for r in rows:
        assert r.verified, f"table1 row {r.graph}/{r.alg} failed verification"
        out.append((f"table1/{r.graph}/{r.alg}/ks", r.ks_time_s * 1e6,
                    f"dh={r.dh_speedup:.2f}x ws={r.ws_speedup:.2f}x "
                    f"dhb={r.dhb_speedup:.2f}x",
                    {"verified": True}))
    spe = [r.ws_speedup for r in rows]
    out.append(("table1/summary", dt * 1e6,
                f"ws-speedup-range={min(spe):.2f}x..{max(spe):.2f}x",
                {"rows": len(rows)}))
    return out


def bench_del_vs_add(scale: str):
    """Deletion-vs-addition cost asymmetry across all five semirings."""
    from benchmarks.del_vs_add import run_del_vs_add
    p = SCALES["del_vs_add"][scale]
    out = []
    for alg in ("bfs", "sssp", "sswp", "ssnp", "viterbi"):
        r = run_del_vs_add(alg=alg, **p)
        assert r["verified"], f"del_vs_add {alg} verification failed"
        out.append((f"del_vs_add/{alg}", r["t_del_s"] * 1e6,
                    f"del/add-time={r['ratio_time']:.2f}x work={r['ratio_work']:.2f}x",
                    {"verified": True,
                     "ratio_work": round(float(r["ratio_work"]), 4)}))
    return out


def bench_tg_sharing(scale: str):
    """Trigrid plan sharing: DH vs bisect vs optimal Δ-volume plans."""
    from benchmarks.tg_sharing import run_tg_sharing
    rows = run_tg_sharing(**SCALES["tg_sharing"][scale])
    out = []
    for r in rows:
        out.append((f"tg_sharing/window{r['window']}",
                    r["optimal_bat_s"] * 1e6,
                    f"dh={r['dh_edges']} opt={r['optimal_edges']} "
                    f"saving={r['optimal_saving']:.1%} "
                    f"batched-speedup dh={r['dh_bat_speedup']:.2f}x "
                    f"bisect={r['bisect_bat_speedup']:.2f}x "
                    f"opt={r['optimal_bat_speedup']:.2f}x",
                    {"dh_edges": int(r["dh_edges"]),
                     "optimal_edges": int(r["optimal_edges"])}))
    return out


def bench_kernels(scale: str):
    """Kernels vs jnp oracle, fused k-sweep chunk, planner calibration."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import (SnapshotStore, campaign_volume, optimal_campaigns,
                            slide_windows)
    from repro.core.costmodel import calibrate
    from repro.graph import make_evolving_sequence
    from repro.graph.edgeset import make_block
    from repro.graph.engine import relax_sweep, relax_sweep_fused
    from repro.graph.semiring import ALL_SEMIRINGS
    from repro.kernels import edge_relax
    from repro.kernels.edge_relax.ref import edge_relax_ref

    p = SCALES["kernels"][scale]
    n, e = p["n"], p["e"]
    key = jax.random.PRNGKey(0)
    vals = jax.random.uniform(key, (n,)) * 10
    src = jax.random.randint(jax.random.PRNGKey(1), (e,), 0, n)
    dst = jax.random.randint(jax.random.PRNGKey(2), (e,), 0, n)
    w = jax.random.uniform(jax.random.PRNGKey(3), (e,)) + 0.01
    out = []
    for op in ("min_plus", "max_min"):
        a = edge_relax(vals, src, dst, w, op=op, num_nodes=n)
        b = edge_relax_ref(vals, src, dst, w, op=op, num_nodes=n)
        fin = np.isfinite(np.asarray(b))
        assert np.allclose(np.asarray(a)[fin], np.asarray(b)[fin], rtol=1e-6)
        t0 = time.perf_counter()
        edge_relax_ref(vals, src, dst, w, op=op, num_nodes=n).block_until_ready()
        dt = time.perf_counter() - t0
        out.append((f"kernels/edge_relax/{op}", dt * 1e6, "allclose=1",
                    {"allclose": True}))

    # -- fused k-sweep chunk vs k host-synced sequential dispatches -------
    # Same math (bit-compared below); the fused chunk replaces k dispatch/
    # host-sync round trips — where values/parent/frontier would bounce
    # through HBM between sweeps — with one call that keeps them resident.
    sr = ALL_SEMIRINGS["sssp"]
    fused_k = p["fused_k"]
    rng = np.random.default_rng(0)
    bsrc = np.concatenate([np.arange(n - 1), rng.integers(0, n, e)])
    bdst = np.concatenate([np.arange(1, n), rng.integers(0, n, e)])
    bw = (rng.random(bsrc.size) + 0.01).astype(np.float32)
    blocks = (make_block(bsrc.astype(np.int32), bdst.astype(np.int32),
                         bw, n),)
    values0 = jnp.full((n,), jnp.float32(sr.identity)).at[0].set(
        jnp.float32(sr.source_value))
    parent0 = jnp.full((n,), -1, jnp.int32)
    frontier0 = jnp.zeros((n,), bool).at[0].set(True)

    def run_seq():
        v, par, fro = values0, parent0, frontier0
        sweeps = 0
        for _ in range(fused_k):
            if not bool(np.any(np.asarray(fro))):  # per-sweep host sync
                break
            v, par, fro, _ = relax_sweep(sr, n, v, par, fro, blocks)
            jax.block_until_ready(v)
            sweeps += 1
        return v, par, fro, sweeps

    @jax.jit
    def _fused_chunk(v, par, fro, blk):
        # jitted like the engine's _fixpoint chunk — one dispatch for the
        # whole while_loop, no host round trips between sweeps
        return relax_sweep_fused(sr, n, v, par, fro, blk, k=fused_k)

    def run_fused():
        v, par, fro, sweeps, _ = _fused_chunk(values0, parent0, frontier0,
                                              blocks)
        jax.block_until_ready(v)
        return v, par, fro, int(sweeps)

    seq_out = run_seq()      # warm-up both paths (compile) + bit-compare
    fused_out = run_fused()
    bit_identical = (
        seq_out[3] == fused_out[3]
        and all(np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(seq_out[:3], fused_out[:3])))
    assert bit_identical, "fused chunk diverged from sequential sweeps"
    sweeps_fused = fused_out[3]
    assert sweeps_fused == fused_k, \
        f"frontier drained early at smoke scale: {sweeps_fused} < {fused_k}"
    t_seq = min(_timed(run_seq) for _ in range(5))
    t_fused = min(_timed(run_fused) for _ in range(5))
    speedup = t_seq / t_fused
    assert speedup >= 1.0, \
        f"fused chunk slower than {fused_k} sequential dispatches: " \
        f"{speedup:.2f}x"
    out.append((f"kernels/relax_fused/{sr.name}", t_fused * 1e6,
                f"k={fused_k} sweeps={sweeps_fused} "
                f"speedup={speedup:.2f}x bit_identical=1",
                {"fused_k": fused_k,
                 "sweeps_fused": sweeps_fused,
                 "hbm_roundtrips_skipped": sweeps_fused - 1,
                 "bit_identical": True},
                {"fused_speedup": round(speedup, 3)}))

    # -- measured-cost planner calibration --------------------------------
    # Fit a SweepCostModel on this machine, then price BOTH partitions
    # under it: the raw-edge-count DP's plan vs the calibrated DP's plan.
    # The calibrated DP optimizes exactly the price campaign_volume
    # charges, so it can never be worse — asserted, and exported as the
    # gate's exact field.
    seq2 = make_evolving_sequence(p["plan_n"], p["plan_e"], p["plan_snaps"],
                                  p["plan_changes"], seed=0)
    store = SnapshotStore(seq2)
    windows = slide_windows(p["plan_snaps"], p["plan_width"])
    t0 = time.perf_counter()
    model = calibrate(store, sr, 0, stable_milli=500, fused_k=fused_k)
    raw_plan = optimal_campaigns(store, windows)
    raw_priced = campaign_volume(store, raw_plan.campaigns,
                                 cost_model=model).total_edges
    cal_plan = optimal_campaigns(store, windows, cost_model=model)
    dt = time.perf_counter() - t0
    assert cal_plan.total_edges <= raw_priced, \
        f"calibrated plan worse than raw-edge-count plan: " \
        f"{cal_plan.total_edges} > {raw_priced} modeled ns"
    saving = 1.0 - cal_plan.total_edges / max(raw_priced, 1)
    out.append(("kernels/planner_calibration", dt * 1e6,
                f"{model.per_edge_nanos}ns/edge+{model.per_sweep_nanos}"
                f"ns/sweep raw={raw_priced}ns cal={cal_plan.total_edges}ns "
                f"saving={saving:.1%}",
                {"calibrated_not_worse": True}))
    return out


def _timed(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def bench_window_slide(scale: str):
    """Sliding-window batched launches vs sequential slides."""
    from benchmarks.window_slide import run_window_slide_bench
    rows = run_window_slide_bench(**SCALES["window_slide"][scale])
    # equivalence is asserted inside run_window_slide_bench (bit-compare per
    # window); a mismatch raises there and the harness reports FAILED
    out = []
    for r in rows:
        out.append((f"window_slide/width{r['width']}", r["bat_s"] * 1e6,
                    f"lanes={r['lanes']} edges={r['added_edges']} "
                    f"batched-speedup={r['bat_speedup']:.2f}x",
                    {"lanes": int(r["lanes"]),
                     "added_edges": int(r["added_edges"]),
                     "edge_work": int(round(r["bat_work"]))}))
    return out


def bench_window_stream(scale: str):
    """Streaming slide campaigns vs cold per-campaign rebuilds."""
    from benchmarks.window_stream import run_window_stream_bench
    rows = run_window_stream_bench(**SCALES["window_stream"][scale])
    # bit-identity vs cold campaigns AND strictly-fewer-rebuilds are
    # asserted inside run_window_stream_bench; a failure raises there
    out = []
    for r in rows:
        out.append((f"window_stream/width{r['width']}", r["stream_s"] * 1e6,
                    f"campaigns={r['campaigns']} "
                    f"rebuilds={r['rebuilds_stream']}+{r['anchor_hops']}hops "
                    f"vs cold {r['rebuilds_cold']} "
                    f"speedup={r['stream_speedup']:.2f}x",
                    {"campaigns": int(r["campaigns"]),
                     "rebuilds_stream": int(r["rebuilds_stream"]),
                     "anchor_hops": int(r["anchor_hops"]),
                     "rebuilds_cold": int(r["rebuilds_cold"]),
                     "added_edges": int(r["added_edges"]),
                     "anchor_delta_edges": int(r["anchor_delta_edges"]),
                     "edge_work": int(round(r["stream_work"])),
                     "edge_work_delta_seed":
                         int(round(r["edge_work_delta_seed"])),
                     "stable_fraction_milli":
                         int(r["stable_fraction_milli"])}))
    return out


def bench_window_overlap(scale: str):
    """Overlapping streams sharing one AnchorChain vs running solo."""
    from benchmarks.window_stream import run_window_overlap_bench
    rows = run_window_overlap_bench(**SCALES["window_overlap"][scale])
    # bit-identity shared-vs-solo AND strictly-fewer-total-rebuilds are
    # asserted inside run_window_overlap_bench; a failure raises there
    out = []
    for r in rows:
        out.append((f"window_overlap/streams{r['streams']}",
                    r["shared_s"] * 1e6,
                    f"links={r['chain_links']} "
                    f"rebuilds={r['rebuilds_shared']}+{r['hops_shared']}hops"
                    f"+{r['hits_shared']}hits vs solo {r['rebuilds_solo']} "
                    f"speedup={r['shared_speedup']:.2f}x "
                    f"auto-widths={r['auto_widths']}",
                    {"streams": int(r["streams"]),
                     "chain_links": int(r["chain_links"]),
                     "rebuilds_shared": int(r["rebuilds_shared"]),
                     "hops_shared": int(r["hops_shared"]),
                     "hits_shared": int(r["hits_shared"]),
                     "rebuilds_solo": int(r["rebuilds_solo"]),
                     "hops_solo": int(r["hops_solo"]),
                     "added_edges": int(r["added_edges"]),
                     "anchor_delta_edges": int(r["anchor_delta_edges"]),
                     "shared_work": int(round(r["shared_work"])),
                     "solo_work": int(round(r["solo_work"])),
                     "auto_widths": [int(w) for w in r["auto_widths"]]}))
    return out


def bench_evolve(scale: str):
    """End-to-end wall time of every evolve-driver executor mode.

    Each mode is verified against from-scratch fixpoints — the committed
    seed baseline (benchmarks/baselines/BENCH_evolve.json) that future
    PRs diff against.
    """
    import numpy as np

    from repro.core import (
        SnapshotStore,
        optimal_plan,
        run_direct_hop,
        run_direct_hop_batched,
        run_kickstarter_stream,
        run_plan,
        run_plan_batched,
        run_window_slide,
        run_window_slide_batched,
        run_window_stream_batched,
    )
    from repro.graph import make_evolving_sequence, run_to_fixpoint
    from repro.graph.semiring import ALL_SEMIRINGS

    p = SCALES["evolve"][scale]
    n, e, snaps, changes, width = (p["n"], p["e"], p["snaps"], p["changes"],
                                   p["width"])
    sr = ALL_SEMIRINGS["sssp"]
    store = SnapshotStore(make_evolving_sequence(n, e, snaps, changes, seed=0))
    plan = optimal_plan(store)

    def timed(fn):
        fn()  # warm up (compile + block caches)
        t0 = time.perf_counter()
        res = fn()
        return time.perf_counter() - t0, res

    t_ks, (ks_res, _) = timed(lambda: run_kickstarter_stream(store, sr, 0))
    modes = [
        ("dh", lambda: run_direct_hop(store, sr, 0)),
        ("dhb", lambda: run_direct_hop_batched(store, sr, 0)),
        ("ws", lambda: run_plan(store, plan, sr, 0)),
        ("wsb", lambda: run_plan_batched(store, plan, sr, 0)),
        ("window_seq", lambda: run_window_slide(store, sr, 0, width)),
        ("window_bat", lambda: run_window_slide_batched(store, sr, 0, width)),
        # anchor cache released per run: times the streamed path (1 rebuild
        # + incremental hops), not the all-hits replay
        ("window_stream", lambda: (
            store.release(("AS",)),
            run_window_stream_batched(store, sr, 0, width,
                                      campaign_width=2))[1]),
    ]
    out = [("evolve/ks", t_ks * 1e6, f"snapshots={snaps} edges~{e}",
            {"snapshots": snaps})]
    runs = {}
    for name, fn in modes:
        dt, res = timed(fn)
        runs[name] = res
        out.append((f"evolve/{name}", dt * 1e6,
                    f"speedup-vs-ks={t_ks / dt:.2f}x",
                    {"verified": True}))
    for i in range(snaps):
        ref = run_to_fixpoint(store.snapshot_view(i), sr, 0).values
        for name in ("dh", "dhb"):
            np.testing.assert_allclose(np.asarray(runs[name].results[i]),
                                       np.asarray(ref), rtol=1e-6)
        for name in ("ws", "wsb"):
            np.testing.assert_allclose(np.asarray(runs[name].results[i]),
                                       np.asarray(ref), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(ks_res[i]), np.asarray(ref),
                                   rtol=1e-6)
    for wnd, vals in runs["window_bat"].results.items():
        np.testing.assert_array_equal(np.asarray(vals),
                                      np.asarray(runs["window_seq"].results[wnd]))
    # the streamed campaigns anchor differently per campaign, yet the
    # monotone fixpoint is unique — still bit-identical to the slide
    for wnd, vals in runs["window_stream"].results.items():
        np.testing.assert_array_equal(np.asarray(vals),
                                      np.asarray(runs["window_seq"].results[wnd]))
    return out


def bench_serve(scale: str):
    """Query-service load: throughput, latency, anchor sharing vs solo."""
    from benchmarks.serve import run_serve_bench
    r = run_serve_bench(**SCALES["serve"][scale])
    # bit-identity vs solo streams, strictly-fewer-rebuilds and
    # occupancy > 1 are asserted inside run_serve_bench
    return [("serve/load", r["wall_s"] * 1e6,
             f"clients={r['clients']} {r['completed']}/{r['admitted']} "
             f"queries occupancy={r['occupancy_milli'] / 1000:.2f} "
             f"rebuilds={r['rebuilds_service']}+{r['hops_service']}hops "
             f"vs solo {r['rebuilds_solo']} "
             f"qps={r['queries_per_sec']:.1f} "
             f"p99={r['p99_us'] / 1e3:.1f}ms",
             {"clients": int(r["clients"]),
              "admitted": int(r["admitted"]),
              "completed": int(r["completed"]),
              "turns": int(r["turns"]),
              "launches": int(r["launches"]),
              "lanes": int(r["lanes"]),
              "padded_lanes": int(r["padded_lanes"]),
              "occupancy_milli": int(r["occupancy_milli"]),
              "rebuilds_service": int(r["rebuilds_service"]),
              "hops_service": int(r["hops_service"]),
              "hits_service": int(r["hits_service"]),
              "rebuilds_solo": int(r["rebuilds_solo"]),
              "hops_solo": int(r["hops_solo"]),
              "stable_fraction_milli": int(r["stable_fraction_milli"]),
              "bit_identical": bool(r["bit_identical"])},
             {"queries_per_sec": round(float(r["queries_per_sec"]), 2),
              "p50_us": round(float(r["p50_us"]), 1),
              "p99_us": round(float(r["p99_us"]), 1)})]


def bench_ingest(scale: str):
    """Live ingestion: firehose replay + live serving vs precomputed path."""
    from benchmarks.ingest import run_ingest_bench
    r = run_ingest_bench(**SCALES["ingest"][scale])
    # snapshot/Δ/value bit-identity across all five semirings AND
    # strictly-fewer-stored-edges after compaction are asserted inside
    # run_ingest_bench; a failure raises there
    exact = {k: (bool(v) if k == "bit_identical" else int(v))
             for k, v in r.items() if k != "wall_s"}
    return [("ingest/replay", r["wall_s"] * 1e6,
             f"events={r['events']} cuts={r['cuts']} "
             f"spilled={r['spilled']} "
             f"served={r['windows_served']} "
             f"shrinkage={r['common_shrinkage']} "
             f"compacted {r['stored_edges_before']}->"
             f"{r['stored_edges_after']}",
             exact)]


BENCHES = {
    "table1": bench_table1,
    "del_vs_add": bench_del_vs_add,
    "tg_sharing": bench_tg_sharing,
    "window_slide": bench_window_slide,
    "window_stream": bench_window_stream,
    "window_overlap": bench_window_overlap,
    "serve": bench_serve,
    "kernels": bench_kernels,
    "evolve": bench_evolve,
    "ingest": bench_ingest,
}


def ensure_out_dir(out_dir: pathlib.Path) -> pathlib.Path:
    """Create ``out_dir`` (parents included) up front with a clear error.

    Centralized so a fresh CI runner or first local run never trips on a
    missing directory mid-run, and a path that collides with an existing
    FILE fails immediately with an actionable message instead of at the
    first JSON write.
    """
    try:
        out_dir.mkdir(parents=True, exist_ok=True)
    except (FileExistsError, NotADirectoryError) as exc:
        raise SystemExit(
            f"--out-dir {out_dir} collides with an existing file: {exc}") from exc
    return out_dir


def write_bench_json(out_dir: pathlib.Path, bench: str, status: str,
                     rows, error: str | None) -> pathlib.Path:
    """Emit BENCH_<bench>.json (schema v2: docs/BENCHMARKS.md).

    Rows are ``(name, us_per_call, derived)``, ``(name, us_per_call,
    derived, exact)`` or ``(name, us_per_call, derived, exact, ratio)`` —
    ``exact`` holds the machine-independent fields (edge/work counts,
    verification booleans) the regression gate (scripts/bench_gate.py)
    compares strictly; ``ratio`` holds machine-dependent rate/latency
    fields (queries/sec, p50/p99 µs) the gate compares within the same
    tolerance factor as wall times, in BOTH directions; rows without
    ratio fields omit the key entirely.
    """
    ensure_out_dir(out_dir)
    path = out_dir / f"BENCH_{bench}.json"
    path.write_text(json.dumps({
        "bench": bench,
        "schema_version": BENCH_SCHEMA_VERSION,
        "generated_unix": time.time(),
        "status": status,
        "error": error,
        "rows": [dict({"name": r[0], "us_per_call": r[1], "derived": r[2],
                       "exact": r[3] if len(r) > 3 else {}},
                      **({"ratio": r[4]} if len(r) > 4 and r[4] else {}))
                 for r in rows],
    }, indent=2) + "\n")
    return path


BASELINES_DIR = pathlib.Path(__file__).resolve().parent / "baselines"

#: Tier -> committed-baseline path for one bench (``None`` = the gate has
#: no baseline concept for that tier; only listed tiers are reported).
BASELINE_TIERS = {
    "smoke": lambda name: BASELINES_DIR / "smoke" / f"BENCH_{name}.json",
    "default": lambda name: BASELINES_DIR / f"BENCH_{name}.json",
}


def baseline_status(name: str) -> str:
    """``"smoke=present default=missing"``-style committed-baseline status.

    One token per gateable tier, read from the same paths
    ``scripts/bench_gate.py`` diffs against — so a bench added without
    committing its smoke baseline shows up in ``--list`` before the CI
    gate fails on it.
    """
    return " ".join(
        f"{tier}={'present' if path_fn(name).is_file() else 'missing'}"
        for tier, path_fn in BASELINE_TIERS.items())


def list_benches(out=print) -> None:
    """Print every bench: purpose, scale tiers, committed-baseline status.

    Reads ``SCALES`` — the same registry the bench functions run from —
    so the listing is exact by construction (docs/BENCHMARKS.md embeds
    the workflow, not this output). The ``baselines:`` line flags any
    bench whose committed gate baseline is missing for a tier.
    """
    for name, fn in BENCHES.items():
        doc = (fn.__doc__ or "").strip().splitlines()[0]
        out(f"{name}: {doc}")
        for tier in ("smoke", "default", "full"):
            params = SCALES[name][tier]
            rendered = ", ".join(f"{k}={v}" for k, v in params.items()) \
                or "(module defaults)"
            out(f"  {tier:8s} {rendered}")
        out(f"  baselines: {baseline_status(name)}")


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    scale_group = p.add_mutually_exclusive_group()
    scale_group.add_argument("--full", action="store_true",
                             help="larger graphs (paper-representative)")
    scale_group.add_argument("--smoke", action="store_true",
                             help="tiny graphs — the CI tier: correctness "
                                  "asserts + artifact emission in minutes")
    p.add_argument("--only", default=None, choices=list(BENCHES))
    p.add_argument("--out-dir", default=".", type=pathlib.Path,
                   help="directory for the BENCH_<bench>.json files")
    p.add_argument("--list", action="store_true",
                   help="list bench names with their smoke/default/full "
                        "tier parameters and exit (runs nothing)")
    args = p.parse_args(argv)
    if args.list:
        list_benches()
        return 0
    scale = "full" if args.full else "smoke" if args.smoke else "default"
    ensure_out_dir(args.out_dir)

    print("name,us_per_call,derived")
    ok = True
    for name, fn in BENCHES.items():
        if args.only and name != args.only:
            continue
        try:
            rows = list(fn(scale))
            for row in rows:
                print(f"{row[0]},{row[1]:.1f},{row[2]}")
            write_bench_json(args.out_dir, name, "ok", rows, None)
        except Exception as exc:  # noqa: BLE001
            ok = False
            print(f"{name},NaN,FAILED:{exc}")
            write_bench_json(args.out_dir, name, "failed", [], str(exc))
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())

"""Benchmark harness entry: one function per paper table/claim.

Prints ``name,us_per_call,derived`` CSV per the harness contract, and
writes one machine-readable ``BENCH_<bench>.json`` per bench into
``--out-dir`` (default: current directory) — the schema is documented in
docs/BENCHMARKS.md. Scales are container-sized (DESIGN.md §7.4); pass
--full for larger graphs, or --smoke for the tiny-graph tier CI runs on
every push (each bench still asserts its own correctness at smoke scale,
and the JSON artifacts give PRs a perf trajectory to diff against — the
committed seed baseline lives in benchmarks/baselines/).

    PYTHONPATH=src python -m benchmarks.run [--full | --smoke] \
        [--only BENCH] [--out-dir DIR]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

BENCH_SCHEMA_VERSION = 1


def bench_table1(scale: str):
    from benchmarks.table1 import run_table1
    graphs, snaps, changes = {
        "smoke": ({"RM-2k": (2_000, 20_000)}, 4, 600),
        "default": ({"RM-20k": (20_000, 200_000)}, 6, 6_000),
        "full": ({"RM-100k": (100_000, 1_000_000),
                  "RM-20k": (20_000, 200_000)}, 12, 20_000),
    }[scale]
    t0 = time.perf_counter()
    rows = run_table1(graphs, num_snapshots=snaps, batch_changes=changes)
    dt = time.perf_counter() - t0
    out = []
    for r in rows:
        assert r.verified, f"table1 row {r.graph}/{r.alg} failed verification"
        out.append((f"table1/{r.graph}/{r.alg}/ks", r.ks_time_s * 1e6,
                    f"dh={r.dh_speedup:.2f}x ws={r.ws_speedup:.2f}x "
                    f"dhb={r.dhb_speedup:.2f}x"))
    spe = [r.ws_speedup for r in rows]
    out.append(("table1/summary", dt * 1e6,
                f"ws-speedup-range={min(spe):.2f}x..{max(spe):.2f}x"))
    return out


def bench_del_vs_add(scale: str):
    from benchmarks.del_vs_add import run_del_vs_add
    n, e, k, repeats = {"smoke": (2_000, 20_000, 600, 1),
                        "default": (10_000, 100_000, 3_000, 2),
                        "full": (10_000, 100_000, 3_000, 5)}[scale]
    out = []
    for alg in ("bfs", "sssp", "sswp", "ssnp", "viterbi"):
        r = run_del_vs_add(alg=alg, n=n, e=e, k=k, repeats=repeats)
        assert r["verified"], f"del_vs_add {alg} verification failed"
        out.append((f"del_vs_add/{alg}", r["t_del_s"] * 1e6,
                    f"del/add-time={r['ratio_time']:.2f}x work={r['ratio_work']:.2f}x"))
    return out


def bench_tg_sharing(scale: str):
    from benchmarks.tg_sharing import run_tg_sharing
    n, e, changes, windows = {
        "smoke": (2_000, 20_000, 800, (4,)),
        "default": (10_000, 100_000, 4_000, (4, 8, 16)),
        "full": (10_000, 100_000, 4_000, (4, 8, 16, 32)),
    }[scale]
    rows = run_tg_sharing(n=n, e=e, batch_changes=changes, windows=windows)
    out = []
    for r in rows:
        out.append((f"tg_sharing/window{r['window']}",
                    r["optimal_bat_s"] * 1e6,
                    f"dh={r['dh_edges']} opt={r['optimal_edges']} "
                    f"saving={r['optimal_saving']:.1%} "
                    f"batched-speedup dh={r['dh_bat_speedup']:.2f}x "
                    f"bisect={r['bisect_bat_speedup']:.2f}x "
                    f"opt={r['optimal_bat_speedup']:.2f}x"))
    return out


def bench_kernels(scale: str):
    """Interpret-mode kernels vs jnp oracle: correctness + oracle timing."""
    import jax
    import numpy as np
    from repro.kernels import edge_relax
    from repro.kernels.edge_relax.ref import edge_relax_ref

    n, e = (1_000, 12_000) if scale == "smoke" else (5_000, 60_000)
    key = jax.random.PRNGKey(0)
    vals = jax.random.uniform(key, (n,)) * 10
    src = jax.random.randint(jax.random.PRNGKey(1), (e,), 0, n)
    dst = jax.random.randint(jax.random.PRNGKey(2), (e,), 0, n)
    w = jax.random.uniform(jax.random.PRNGKey(3), (e,)) + 0.01
    out = []
    for op in ("min_plus", "max_min"):
        a = edge_relax(vals, src, dst, w, op=op, num_nodes=n)
        b = edge_relax_ref(vals, src, dst, w, op=op, num_nodes=n)
        fin = np.isfinite(np.asarray(b))
        assert np.allclose(np.asarray(a)[fin], np.asarray(b)[fin], rtol=1e-6)
        t0 = time.perf_counter()
        edge_relax_ref(vals, src, dst, w, op=op, num_nodes=n).block_until_ready()
        dt = time.perf_counter() - t0
        out.append((f"kernels/edge_relax/{op}", dt * 1e6, "allclose=1"))
    return out


def bench_window_slide(scale: str):
    from benchmarks.window_slide import run_window_slide_bench
    widths, snaps = {"smoke": ((2,), 6),
                     "default": ((2, 4, 8), 12),
                     "full": ((2, 4, 8, 16), 24)}[scale]
    rows = run_window_slide_bench(widths=widths, snaps=snaps)
    # equivalence is asserted inside run_window_slide_bench (bit-compare per
    # window); a mismatch raises there and the harness reports FAILED
    out = []
    for r in rows:
        out.append((f"window_slide/width{r['width']}", r["bat_s"] * 1e6,
                    f"lanes={r['lanes']} edges={r['added_edges']} "
                    f"batched-speedup={r['bat_speedup']:.2f}x"))
    return out


def bench_evolve(scale: str):
    """End-to-end wall time of every executor mode the evolve driver runs,
    verified against from-scratch fixpoints — the committed seed baseline
    (benchmarks/baselines/BENCH_evolve.json) that future PRs diff against.
    """
    import numpy as np

    from repro.core import (
        SnapshotStore,
        optimal_plan,
        run_direct_hop,
        run_direct_hop_batched,
        run_kickstarter_stream,
        run_plan,
        run_plan_batched,
        run_window_slide,
        run_window_slide_batched,
    )
    from repro.graph import make_evolving_sequence, run_to_fixpoint
    from repro.graph.semiring import ALL_SEMIRINGS

    n, e, snaps, changes, width = {
        "smoke": (2_000, 20_000, 5, 600, 3),
        "default": (10_000, 100_000, 8, 3_000, 4),
        "full": (20_000, 200_000, 10, 10_000, 4),
    }[scale]
    sr = ALL_SEMIRINGS["sssp"]
    store = SnapshotStore(make_evolving_sequence(n, e, snaps, changes, seed=0))
    plan = optimal_plan(store)

    def timed(fn):
        fn()  # warm up (compile + block caches)
        t0 = time.perf_counter()
        res = fn()
        return time.perf_counter() - t0, res

    t_ks, (ks_res, _) = timed(lambda: run_kickstarter_stream(store, sr, 0))
    modes = [
        ("dh", lambda: run_direct_hop(store, sr, 0)),
        ("dhb", lambda: run_direct_hop_batched(store, sr, 0)),
        ("ws", lambda: run_plan(store, plan, sr, 0)),
        ("wsb", lambda: run_plan_batched(store, plan, sr, 0)),
        ("window_seq", lambda: run_window_slide(store, sr, 0, width)),
        ("window_bat", lambda: run_window_slide_batched(store, sr, 0, width)),
    ]
    out = [("evolve/ks", t_ks * 1e6, f"snapshots={snaps} edges~{e}")]
    runs = {}
    for name, fn in modes:
        dt, res = timed(fn)
        runs[name] = res
        out.append((f"evolve/{name}", dt * 1e6,
                    f"speedup-vs-ks={t_ks / dt:.2f}x"))
    for i in range(snaps):
        ref = run_to_fixpoint(store.snapshot_view(i), sr, 0).values
        for name in ("dh", "dhb"):
            np.testing.assert_allclose(np.asarray(runs[name].results[i]),
                                       np.asarray(ref), rtol=1e-6)
        for name in ("ws", "wsb"):
            np.testing.assert_allclose(np.asarray(runs[name].results[i]),
                                       np.asarray(ref), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(ks_res[i]), np.asarray(ref),
                                   rtol=1e-6)
    for wnd, vals in runs["window_bat"].results.items():
        np.testing.assert_array_equal(np.asarray(vals),
                                      np.asarray(runs["window_seq"].results[wnd]))
    return out


BENCHES = {
    "table1": bench_table1,
    "del_vs_add": bench_del_vs_add,
    "tg_sharing": bench_tg_sharing,
    "window_slide": bench_window_slide,
    "kernels": bench_kernels,
    "evolve": bench_evolve,
}


def write_bench_json(out_dir: pathlib.Path, bench: str, status: str,
                     rows, error: str | None) -> pathlib.Path:
    """Emit BENCH_<bench>.json (schema: docs/BENCHMARKS.md)."""
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"BENCH_{bench}.json"
    path.write_text(json.dumps({
        "bench": bench,
        "schema_version": BENCH_SCHEMA_VERSION,
        "generated_unix": time.time(),
        "status": status,
        "error": error,
        "rows": [{"name": n, "us_per_call": us, "derived": d}
                 for n, us, d in rows],
    }, indent=2) + "\n")
    return path


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    scale_group = p.add_mutually_exclusive_group()
    scale_group.add_argument("--full", action="store_true",
                             help="larger graphs (paper-representative)")
    scale_group.add_argument("--smoke", action="store_true",
                             help="tiny graphs — the CI tier: correctness "
                                  "asserts + artifact emission in minutes")
    p.add_argument("--only", default=None, choices=list(BENCHES))
    p.add_argument("--out-dir", default=".", type=pathlib.Path,
                   help="directory for the BENCH_<bench>.json files")
    args = p.parse_args(argv)
    scale = "full" if args.full else "smoke" if args.smoke else "default"

    print("name,us_per_call,derived")
    ok = True
    for name, fn in BENCHES.items():
        if args.only and name != args.only:
            continue
        try:
            rows = list(fn(scale))
            for row in rows:
                print(f"{row[0]},{row[1]:.1f},{row[2]}")
            write_bench_json(args.out_dir, name, "ok", rows, None)
        except Exception as exc:  # noqa: BLE001
            ok = False
            print(f"{name},NaN,FAILED:{exc}")
            write_bench_json(args.out_dir, name, "failed", [], str(exc))
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())

"""Streaming slide campaigns: incremental anchor maintenance vs cold rebuilds.

The window analogue of a streaming ingest: an advancing window sequence is
consumed as campaigns of ``campaign_width`` windows
(``run_window_stream_batched``, core/window.py). The streamed path maintains
its anchor state incrementally — 1 from-scratch rebuild + one
``incremental_additions`` hop per later campaign — while the cold baseline
(``run_window_slide_batched`` per campaign, same anchors) rebuilds its
anchor from the base snapshot every campaign. Both paths run with warm block
caches and cold anchor caches after a compile warm-up, results are
bit-compared per window each round, and the streamed path must perform
STRICTLY FEWER anchor rebuilds — a benchmark row is also the acceptance
check for the scheduler.

    PYTHONPATH=src python -m benchmarks.window_stream [--smoke] [--overlap]

``--overlap`` benches the OTHER sharing axis (``run_window_overlap_bench``):
N overlapping streams registered on one ``AnchorChain`` — later streams hop
off the chain links earlier streams left pinned — against each stream
running solo on its own store. Rebuild/hop counts and frontier-masked edge
work are reported for both, every window is bit-compared, and the shared
path must rebuild strictly fewer anchors in total (docs/STREAMING.md
explains the chain).

``--smoke`` runs a tiny graph for a seconds-long local check; CI covers the
same paths via the bench job's ``benchmarks.run --smoke`` harness pass and
diffs the emitted BENCH_window_stream.json / BENCH_window_overlap.json
against the committed smoke baselines (scripts/bench_gate.py; see
docs/BENCHMARKS.md).
"""

import argparse
import time

import numpy as np

from repro.core import (
    AnchorChain,
    SnapshotStore,
    WindowStream,
    optimal_campaigns,
    run_window_slide_batched,
    run_window_stream_batched,
    slide_windows,
)
from repro.graph import make_evolving_sequence
from repro.graph.semiring import ALL_SEMIRINGS


def run_window_stream_bench(n=10_000, e=100_000, snaps=12, batch_changes=4_000,
                            widths=(3, 4), campaign_width=3, step=1, seed=0,
                            alg="sssp", source=0):
    """Rows of {width, campaigns, stream/cold wall+work+rebuild counts}."""
    sr = ALL_SEMIRINGS[alg]
    seq = make_evolving_sequence(n, e, snaps, batch_changes, seed=seed)
    store = SnapshotStore(seq)
    rows = []
    for width in widths:
        windows = slide_windows(snaps, width, step=step)
        # Warm-up: compiles traces and builds every block both paths touch.
        run_window_stream_batched(store, sr, source, windows=windows,
                                  campaign_width=campaign_width)
        # Timed stream: warm blocks, cold anchors (the streaming scenario —
        # block assembly is ingest-side, anchor state is the query side).
        store.release(("AS",))
        stream = run_window_stream_batched(store, sr, source, windows=windows,
                                           campaign_width=campaign_width)
        # Full-Δ-seeded rerun (seed="delta"): same windows, cold anchors —
        # the strictly-more-work baseline the stability analysis is gated
        # against (bit-identity is covered by the stream-vs-cold compare
        # below plus tests/test_stability.py).
        store.release(("AS",))
        delta_seeded = run_window_stream_batched(store, sr, source,
                                                 windows=windows,
                                                 campaign_width=campaign_width,
                                                 seed="delta")
        # Timed cold baseline: one slide launch per campaign with the SAME
        # anchors; run_window_slide_batched never consults the anchor cache,
        # so every campaign pays a from-scratch anchor fixpoint.
        cold = [run_window_slide_batched(store, sr, source, windows=c,
                                         anchor=a)
                for c, a in zip(stream.campaigns, stream.anchors)]
        for cold_run, campaign in zip(cold, stream.campaigns):
            for wnd in campaign:
                np.testing.assert_array_equal(
                    np.asarray(stream.results[wnd]),
                    np.asarray(cold_run.results[wnd]),
                    err_msg=f"width {width} window {wnd}: stream != cold")
        rebuilds_cold = len(cold)
        assert stream.anchor_rebuilds < rebuilds_cold, (
            f"width {width}: streamed path must rebuild strictly fewer "
            f"anchors ({stream.anchor_rebuilds} vs {rebuilds_cold})")
        stream_work = (sum(s.edge_work for s in stream.anchor_stats)
                       + sum(s.edge_work for s in stream.hop_stats))
        delta_work = (sum(s.edge_work for s in delta_seeded.anchor_stats)
                      + sum(s.edge_work for s in delta_seeded.hop_stats))
        assert stream_work < delta_work, (
            f"width {width}: instability seeding must do strictly less "
            f"frontier-masked work than full-Δ seeding "
            f"({stream_work} vs {delta_work})")
        assert stream.stable_milli > 0, (
            f"width {width}: measured stable fraction must be positive "
            f"(got {stream.stable_milli}‰)")
        cold_work = sum(r.base_stats.edge_work
                        + sum(s.edge_work for s in r.hop_stats)
                        for r in cold)
        cold_s = sum(r.wall_s for r in cold)
        rows.append({
            "width": width,
            "campaign_width": campaign_width,
            "campaigns": len(stream.campaigns),
            "lanes": len(windows),
            "stream_s": stream.wall_s,
            "cold_s": cold_s,
            "stream_speedup": cold_s / stream.wall_s,
            "rebuilds_stream": stream.anchor_rebuilds,
            "anchor_hops": stream.anchor_hops,
            "rebuilds_cold": rebuilds_cold,
            "added_edges": stream.added_edges,
            "anchor_delta_edges": stream.anchor_delta_edges,
            "stream_work": stream_work,
            "cold_work": cold_work,
            # stable-vertex analysis: measured stable fraction (exact ‰
            # integer) and the full-Δ-seeded work the pruning beat
            "stable_fraction_milli": stream.stable_milli,
            "edge_work_delta_seed": delta_work,
        })
    return rows


def run_window_overlap_bench(n=10_000, e=100_000, snaps=12,
                             batch_changes=4_000, num_streams=3, width=4,
                             campaign_width=2, seed=0, alg="sssp", source=0):
    """N overlapping streams sharing one AnchorChain vs running solo.

    Stream s consumes a staggered suffix of the full slide plan (all
    streams end at the sequence tail, so every later stream's anchors are
    covered by the chain links earlier streams left behind — the sharing
    regime; see docs/STREAMING.md). The shared path runs every stream
    against ONE store + chain (registration up front, so early links stay
    pinned for laggards); the solo baseline runs each stream on its own
    fresh store. Every window is bit-compared and the shared path must
    perform STRICTLY FEWER anchor rebuilds in total — the bench row doubles
    as the acceptance check for chain sharing.
    """
    sr = ALL_SEMIRINGS[alg]
    seq = make_evolving_sequence(n, e, snaps, batch_changes, seed=seed)
    all_windows = slide_windows(snaps, width)
    stagger = max(1, len(all_windows) // num_streams)
    window_sets = [all_windows[s * stagger:] for s in range(num_streams)]
    assert all(window_sets), \
        f"staggering {len(all_windows)} windows over {num_streams} streams " \
        "left an empty stream — widen the plan or drop streams"

    def shared_run():
        store = SnapshotStore(seq)
        chain = AnchorChain(store, name="overlap")
        streams = [WindowStream(campaign_width=campaign_width, windows=ws,
                                name=f"overlap-{i}")
                   for i, ws in enumerate(window_sets)]
        for s in streams:
            chain.register(s)  # up front: early links stay pinned for all
        t0 = time.perf_counter()
        runs = [run_window_stream_batched(store, sr, source, stream=s,
                                          chain=chain) for s in streams]
        dt = time.perf_counter() - t0
        for s in streams:
            chain.unregister(s)
        return runs, dt, chain, store

    def solo_run():
        t0 = time.perf_counter()
        runs = [run_window_stream_batched(SnapshotStore(seq), sr, source,
                                          windows=ws,
                                          campaign_width=campaign_width)
                for ws in window_sets]
        return runs, time.perf_counter() - t0

    shared_run(), solo_run()  # warm-up: compile the campaign-shaped traces
    shared, shared_s, chain, store = shared_run()
    solo, solo_s = solo_run()
    for sh, so in zip(shared, solo):
        for wnd in so.results:
            np.testing.assert_array_equal(
                np.asarray(sh.results[wnd]), np.asarray(so.results[wnd]),
                err_msg=f"window {wnd}: shared chain != solo")
    rebuilds_shared = sum(r.anchor_rebuilds for r in shared)
    rebuilds_solo = sum(r.anchor_rebuilds for r in solo)
    assert rebuilds_shared < rebuilds_solo, (
        f"chain sharing must rebuild strictly fewer anchors "
        f"({rebuilds_shared} vs {rebuilds_solo} solo)")

    def total_work(runs):
        return sum(sum(s.edge_work for s in r.anchor_stats)
                   + sum(s.edge_work for s in r.hop_stats) for r in runs)

    return [{
        "streams": num_streams,
        "width": width,
        "campaign_width": campaign_width,
        "windows_per_stream": [len(ws) for ws in window_sets],
        "chain_links": len(chain.links),
        "rebuilds_shared": rebuilds_shared,
        "hops_shared": sum(r.anchor_hops for r in shared),
        "hits_shared": sum(r.anchor_hits for r in shared),
        "rebuilds_solo": rebuilds_solo,
        "hops_solo": sum(r.anchor_hops for r in solo),
        "added_edges": sum(r.added_edges for r in shared),
        "anchor_delta_edges": sum(r.anchor_delta_edges for r in shared),
        "shared_work": total_work(shared),
        "solo_work": total_work(solo),
        "shared_s": shared_s,
        "solo_s": solo_s,
        "shared_speedup": solo_s / shared_s,
        # planner regression canary: the Δ-volume DP's choice on stream 0's
        # windows is a pure function of the seeded graph
        "auto_widths": optimal_campaigns(store, window_sets[0],
                                         lane_budget=8).widths,
    }]


SMOKE = dict(n=400, e=3_000, snaps=6, batch_changes=200, widths=(2, 3),
             campaign_width=2)
SMOKE_OVERLAP = dict(n=400, e=3_000, snaps=6, batch_changes=200,
                     num_streams=2, width=3)


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="tiny graph (CI smoke run)")
    p.add_argument("--overlap", action="store_true",
                   help="bench N overlapping streams sharing one AnchorChain "
                        "vs running solo (instead of stream-vs-cold)")
    args = p.parse_args(argv)
    if args.overlap:
        for r in run_window_overlap_bench(**(SMOKE_OVERLAP if args.smoke
                                             else {})):
            print(f"streams={r['streams']}  windows/stream="
                  f"{r['windows_per_stream']}  chain links={r['chain_links']}  "
                  f"rebuilds {r['rebuilds_shared']} (+{r['hops_shared']} hops "
                  f"+{r['hits_shared']} hits) vs solo {r['rebuilds_solo']} "
                  f"(+{r['hops_solo']} hops)  shared {r['shared_s']:.3f}s  "
                  f"solo {r['solo_s']:.3f}s  ({r['shared_speedup']:.2f}x, "
                  f"work {r['shared_work']:,.0f} vs {r['solo_work']:,.0f})  "
                  f"auto-widths={r['auto_widths']}  bit-identical ✓")
        return 0
    rows = run_window_stream_bench(**(SMOKE if args.smoke else {}))
    for r in rows:
        print(f"width={r['width']:3d}  campaigns={r['campaigns']:3d}  "
              f"rebuilds {r['rebuilds_stream']} (+{r['anchor_hops']} hops) "
              f"vs cold {r['rebuilds_cold']}  "
              f"stream {r['stream_s']:.3f}s  cold {r['cold_s']:.3f}s  "
              f"({r['stream_speedup']:.2f}x, work {r['stream_work']:,.0f} vs "
              f"{r['cold_work']:,.0f} cold / {r['edge_work_delta_seed']:,.0f} "
              f"full-Δ, stable {r['stable_fraction_milli']}‰)  "
              f"bit-identical ✓")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

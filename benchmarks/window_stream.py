"""Streaming slide campaigns: incremental anchor maintenance vs cold rebuilds.

The window analogue of a streaming ingest: an advancing window sequence is
consumed as campaigns of ``campaign_width`` windows
(``run_window_stream_batched``, core/window.py). The streamed path maintains
its anchor state incrementally — 1 from-scratch rebuild + one
``incremental_additions`` hop per later campaign — while the cold baseline
(``run_window_slide_batched`` per campaign, same anchors) rebuilds its
anchor from the base snapshot every campaign. Both paths run with warm block
caches and cold anchor caches after a compile warm-up, results are
bit-compared per window each round, and the streamed path must perform
STRICTLY FEWER anchor rebuilds — a benchmark row is also the acceptance
check for the scheduler.

    PYTHONPATH=src python -m benchmarks.window_stream [--smoke]

``--smoke`` runs a tiny graph for a seconds-long local check; CI covers the
same path via the bench job's ``benchmarks.run --smoke`` harness pass and
diffs the emitted BENCH_window_stream.json against the committed smoke
baseline (scripts/bench_gate.py; see docs/BENCHMARKS.md).
"""

import argparse

import numpy as np

from repro.core import (
    SnapshotStore,
    run_window_slide_batched,
    run_window_stream_batched,
    slide_windows,
)
from repro.graph import make_evolving_sequence
from repro.graph.semiring import ALL_SEMIRINGS


def run_window_stream_bench(n=10_000, e=100_000, snaps=12, batch_changes=4_000,
                            widths=(3, 4), campaign_width=3, step=1, seed=0,
                            alg="sssp", source=0):
    """Rows of {width, campaigns, stream/cold wall+work+rebuild counts}."""
    sr = ALL_SEMIRINGS[alg]
    seq = make_evolving_sequence(n, e, snaps, batch_changes, seed=seed)
    store = SnapshotStore(seq)
    rows = []
    for width in widths:
        windows = slide_windows(snaps, width, step=step)
        # Warm-up: compiles traces and builds every block both paths touch.
        run_window_stream_batched(store, sr, source, windows=windows,
                                  campaign_width=campaign_width)
        # Timed stream: warm blocks, cold anchors (the streaming scenario —
        # block assembly is ingest-side, anchor state is the query side).
        store.release(("AS",))
        stream = run_window_stream_batched(store, sr, source, windows=windows,
                                           campaign_width=campaign_width)
        # Timed cold baseline: one slide launch per campaign with the SAME
        # anchors; run_window_slide_batched never consults the anchor cache,
        # so every campaign pays a from-scratch anchor fixpoint.
        cold = [run_window_slide_batched(store, sr, source, windows=c,
                                         anchor=a)
                for c, a in zip(stream.campaigns, stream.anchors)]
        for cold_run, campaign in zip(cold, stream.campaigns):
            for wnd in campaign:
                np.testing.assert_array_equal(
                    np.asarray(stream.results[wnd]),
                    np.asarray(cold_run.results[wnd]),
                    err_msg=f"width {width} window {wnd}: stream != cold")
        rebuilds_cold = len(cold)
        assert stream.anchor_rebuilds < rebuilds_cold, (
            f"width {width}: streamed path must rebuild strictly fewer "
            f"anchors ({stream.anchor_rebuilds} vs {rebuilds_cold})")
        stream_work = (sum(s.edge_work for s in stream.anchor_stats)
                       + sum(s.edge_work for s in stream.hop_stats))
        cold_work = sum(r.base_stats.edge_work
                        + sum(s.edge_work for s in r.hop_stats)
                        for r in cold)
        cold_s = sum(r.wall_s for r in cold)
        rows.append({
            "width": width,
            "campaign_width": campaign_width,
            "campaigns": len(stream.campaigns),
            "lanes": len(windows),
            "stream_s": stream.wall_s,
            "cold_s": cold_s,
            "stream_speedup": cold_s / stream.wall_s,
            "rebuilds_stream": stream.anchor_rebuilds,
            "anchor_hops": stream.anchor_hops,
            "rebuilds_cold": rebuilds_cold,
            "added_edges": stream.added_edges,
            "anchor_delta_edges": stream.anchor_delta_edges,
            "stream_work": stream_work,
            "cold_work": cold_work,
        })
    return rows


SMOKE = dict(n=400, e=3_000, snaps=6, batch_changes=200, widths=(2, 3),
             campaign_width=2)


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="tiny graph (CI smoke run)")
    args = p.parse_args(argv)
    rows = run_window_stream_bench(**(SMOKE if args.smoke else {}))
    for r in rows:
        print(f"width={r['width']:3d}  campaigns={r['campaigns']:3d}  "
              f"rebuilds {r['rebuilds_stream']} (+{r['anchor_hops']} hops) "
              f"vs cold {r['rebuilds_cold']}  "
              f"stream {r['stream_s']:.3f}s  cold {r['cold_s']:.3f}s  "
              f"({r['stream_speedup']:.2f}x, work {r['stream_work']:,.0f} vs "
              f"{r['cold_work']:,.0f})  bit-identical ✓")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

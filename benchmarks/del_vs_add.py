"""Paper §1 motivation: a deletion batch costs ≈3× an equal addition batch.

From a converged state on snapshot t, we time (a) the addition-only
incremental update for a batch of k additions and (b) the trim+re-converge
path for a batch of k deletions (KickStarter semantics), and report the
cost ratio in wall time and in frontier-masked edge work.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.kickstarter import _trim_and_reconverge
from repro.core.snapshots import SnapshotStore
from repro.graph import make_evolving_sequence, run_to_fixpoint, incremental_additions
from repro.graph.edgeset import EdgeView, keys_to_edges, make_block
from repro.graph.semiring import ALL_SEMIRINGS


def run_del_vs_add(n=20_000, e=200_000, k=5_000, alg="sssp", seed=0,
                   source=0, repeats=3):
    sr = ALL_SEMIRINGS[alg]
    seq = make_evolving_sequence(n, e, 2, 2 * k, seed=seed)
    store = SnapshotStore(seq)
    base = run_to_fixpoint(store.snapshot_view(0), sr, source)
    base.values.block_until_ready()

    # -- additions: S_0 + A (the batch the generator added at t0 -> t1)
    add_blk = store.addition_block(0)
    view_add = store.snapshot_view(0).extended(add_blk)
    t_add, w_add = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = incremental_additions(view_add, add_blk, sr, base.values, base.parent)
        res.values.block_until_ready()
        t_add.append(time.perf_counter() - t0)
        w_add.append(float(res.edge_work))

    # -- deletions: S_0 - D (the batch the generator deleted at t0 -> t1)
    del_keys = store.deletion_keys(0)
    ds, dd = keys_to_edges(del_keys, n)
    pad = (-len(ds)) % store.granule
    ds = np.concatenate([ds, np.zeros(pad, np.int32)])
    dd = np.concatenate([dd, np.full(pad, n, np.int32)])
    after_del = np.setdiff1d(seq.snapshot_keys[0], del_keys, assume_unique=True)
    s2, d2 = keys_to_edges(after_del, n)
    blk2 = make_block(s2, d2, seq.weights_for(after_del), n,
                      granule=store.granule, pad_pow2=store.pad_pow2)
    empty_add = make_block(np.zeros(0, np.int32), np.zeros(0, np.int32),
                           np.zeros(0, np.float32), n, granule=store.granule)
    t_del, w_del, tainted = [], [], 0
    for _ in range(repeats):
        t0 = time.perf_counter()
        res2, tn = _trim_and_reconverge(sr, n, 10_000, base.values, base.parent,
                                        jnp.asarray(ds), jnp.asarray(dd),
                                        empty_add, (blk2,))
        res2.values.block_until_ready()
        t_del.append(time.perf_counter() - t0)
        w_del.append(float(res2.edge_work))
        tainted = int(tn)

    # exactness
    ref = run_to_fixpoint(EdgeView((blk2,), n), sr, source)
    ok = bool(np.allclose(np.asarray(res2.values), np.asarray(ref.values)))
    return {
        "alg": alg,
        "t_add_s": float(np.median(t_add)),
        "t_del_s": float(np.median(t_del)),
        "ratio_time": float(np.median(t_del) / np.median(t_add)),
        "ratio_work": float((np.median(w_del) + 1) / (np.median(w_add) + 1)),
        "tainted": tainted,
        "verified": ok,
    }


if __name__ == "__main__":
    for alg in ("bfs", "sssp", "sswp", "ssnp", "viterbi"):
        r = run_del_vs_add(alg=alg)
        print(f"{alg:8s} add {r['t_add_s']*1e3:7.1f}ms  del {r['t_del_s']*1e3:7.1f}ms  "
              f"time-ratio {r['ratio_time']:.2f}x  work-ratio {r['ratio_work']:.2f}x  "
              f"tainted {r['tainted']}  ok={r['verified']}")

"""Paper Table 1 reproduction: KickStarter vs CommonGraph DH / WS.

Protocol (paper §3, scaled to this container per DESIGN.md §7.4): n
snapshots separated by batches of edge changes split 50/50 between
additions and deletions; five benchmarks (BFS, SSSP, SSWP, SSNP, Viterbi);
average execution time for the whole window, reported as KS time and
DH / WS speedups — the same table layout as the paper.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import (
    SnapshotStore,
    optimal_plan,
    run_direct_hop,
    run_direct_hop_batched,
    run_kickstarter_stream,
    run_plan,
)
from repro.graph import make_evolving_sequence, run_to_fixpoint
from repro.graph.semiring import ALL_SEMIRINGS

ALG_ORDER = ["bfs", "sssp", "sswp", "ssnp", "viterbi"]


@dataclasses.dataclass
class Table1Row:
    graph: str
    alg: str
    ks_time_s: float
    dh_speedup: float
    dhb_speedup: float
    ws_speedup: float
    verified: bool


def run_table1(
    graphs: dict[str, tuple[int, int]] | None = None,
    num_snapshots: int = 8,
    batch_changes: int = 10_000,
    source: int = 0,
    seed: int = 0,
    verify: bool = True,
    repeats: int = 1,
    warmup: bool = True,
) -> list[Table1Row]:
    if graphs is None:
        graphs = {"RM-50k": (50_000, 400_000), "RM-10k": (10_000, 100_000)}
    rows = []
    for gname, (n, e) in graphs.items():
        seq = make_evolving_sequence(n, e, num_snapshots, batch_changes, seed=seed)
        store = SnapshotStore(seq)
        plan = optimal_plan(store)
        for alg in ALG_ORDER:
            sr = ALL_SEMIRINGS[alg]
            t_ks = t_dh = t_dhb = t_ws = 0.0
            if warmup:  # compile everything once, untimed (steady-state times)
                run_kickstarter_stream(store, sr, source)
                run_direct_hop(store, sr, source)
                run_direct_hop_batched(store, sr, source)
                run_plan(store, plan, sr, source)
            for _ in range(repeats):
                t0 = time.perf_counter()
                ks_res, _ = run_kickstarter_stream(store, sr, source)
                t_ks += time.perf_counter() - t0
                dh = run_direct_hop(store, sr, source)
                t_dh += dh.wall_s
                dhb = run_direct_hop_batched(store, sr, source)
                t_dhb += dhb.wall_s
                ws = run_plan(store, plan, sr, source)
                t_ws += ws.wall_s
            ok = True
            if verify:
                for i in range(num_snapshots):
                    ref = run_to_fixpoint(store.snapshot_view(i), sr, source).values
                    for res in (ks_res[i], dh.results[i], dhb.results[i],
                                ws.results[i]):
                        ok &= bool(np.allclose(np.asarray(res), np.asarray(ref),
                                               rtol=1e-6, equal_nan=True))
            rows.append(Table1Row(gname, alg, t_ks / repeats,
                                  t_ks / t_dh, t_ks / t_dhb, t_ks / t_ws, ok))
    return rows


def print_table(rows: list[Table1Row]):
    print(f"{'G':10s} {'Alg':8s} {'KS time':>9s} {'DH spe.':>8s} "
          f"{'DH-batch':>9s} {'WS spe.':>8s} {'ok':>3s}")
    for r in rows:
        print(f"{r.graph:10s} {r.alg:8s} {r.ks_time_s:8.2f}s {r.dh_speedup:7.2f}x "
              f"{r.dhb_speedup:8.2f}x {r.ws_speedup:7.2f}x {'Y' if r.verified else 'N':>3s}")


if __name__ == "__main__":
    import argparse
    p = argparse.ArgumentParser()
    p.add_argument("--snapshots", type=int, default=8)
    p.add_argument("--changes", type=int, default=10_000)
    p.add_argument("--nodes", type=int, default=None)
    p.add_argument("--edges", type=int, default=None)
    a = p.parse_args()
    graphs = ({"custom": (a.nodes, a.edges)} if a.nodes else None)
    print_table(run_table1(graphs, a.snapshots, a.changes))

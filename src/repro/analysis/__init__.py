"""graphlint: AST-enforced launch/cache/sharding invariants (docs/ANALYSIS.md).

The repo's cross-cutting contracts — lane bucketing before batched
launches, canonical SnapshotStore cache tags, host-sync-free jitted hot
paths, the semiring registry surface, API-doc coverage — are exactly the
invariants no single unit test can guard: they constrain *every* call
site, including ones future PRs add. ``repro.analysis`` encodes them as
static AST rules so a violation fails CI at review time, before a masked
lane or cache-tag bug can silently corrupt served results.

Deliberately stdlib-only (``ast`` + ``pathlib``): the linter runs in CI
before any dependency is installed, and importing it never pulls in jax.

    PYTHONPATH=src python scripts/invariant_lint.py src        # CLI
    from repro.analysis import Linter; Linter().lint([path])   # library

Layout:

* :mod:`repro.analysis.linter` — the rule-engine core: parsed-module
  model, ``# graphlint: disable=RULE`` suppressions, rule registry,
  finding type, human/JSON rendering.
* :mod:`repro.analysis.rules` — rules G001–G005, G007–G010 (launch/
  cache/sync/semiring/serving/ingest/fused-launch invariants).
* :mod:`repro.analysis.apidoc` — rule G006 (docs/API.md coverage +
  docstring presence; the ast half of the old ``scripts/check_links.py``
  promoted to a first-class rule).
"""

from repro.analysis.linter import (
    Finding,
    Linter,
    Module,
    Rule,
    all_rules,
    get_rule,
    register,
    render_human,
    render_json,
)
from repro.analysis import rules as _rules      # noqa: F401  (G001-G005, G007-G010)
from repro.analysis import apidoc as _apidoc    # noqa: F401  (registers G006)

__all__ = [
    "Finding",
    "Linter",
    "Module",
    "Rule",
    "all_rules",
    "get_rule",
    "register",
    "render_human",
    "render_json",
]

"""graphlint rule-engine core: modules, rules, suppressions, findings.

The model every rule programs against:

* A :class:`Module` is one parsed source file plus the derived structure
  rules keep re-needing — a child→parent AST map, the enclosing-function
  chain of any node, per-line ``# graphlint: disable=RULE`` suppressions,
  and the module's dotted import name (for rules keyed by module, like the
  API-doc coverage rule).
* A :class:`Rule` has a stable id (``G001``…), a one-line title, and a
  ``check(module)`` generator yielding :class:`Finding` s. Rules register
  themselves with :func:`register`; :class:`Linter` runs every registered
  rule (or a selected subset) over a file tree and applies suppressions.
* Output is deterministic (findings sorted by path/line/col/rule) and
  renders either human (``path:line:col: GNNN message``) or JSON
  (:func:`render_json`, the format CI consumes).

Suppression syntax, checked per finding line:

    x = risky_thing()   # graphlint: disable=G002
    # graphlint: disable-file=G004   <- anywhere in the file: whole file

Everything here is stdlib-only so the linter can run in CI before any
dependency is installed (and so linting can never import the code under
analysis — rules read source, they never execute it).
"""

from __future__ import annotations

import ast
import contextlib
import dataclasses
import json
import pathlib
import re
from typing import Iterable, Iterator

SUPPRESS_LINE_RE = re.compile(r"#\s*graphlint:\s*disable=([A-Z0-9,\s]+)")
SUPPRESS_FILE_RE = re.compile(r"#\s*graphlint:\s*disable-file=([A-Z0-9,\s]+)")

#: Files/dirs never worth parsing.
SKIP_DIR_NAMES = {"__pycache__", ".git", ".venv", "node_modules"}

#: Markers that identify a repo root (for locating docs/API.md etc.).
ROOT_MARKERS = ("pyproject.toml", ".git")

FunctionNode = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation, anchored to a file/line/col."""

    path: str
    line: int
    col: int
    rule: str
    message: str

    def to_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message}

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


def _rule_ids(spec: str) -> set[str]:
    return {part.strip() for part in spec.split(",") if part.strip()}


def find_root(path: pathlib.Path) -> "pathlib.Path | None":
    """Nearest ancestor directory that looks like a repo root (else None)."""
    cur = path.resolve()
    if cur.is_file():
        cur = cur.parent
    for candidate in (cur, *cur.parents):
        if any((candidate / marker).exists() for marker in ROOT_MARKERS):
            return candidate
        if (candidate / "docs" / "API.md").exists():
            return candidate
    return None


class Module:
    """One parsed source file + the structure rules need to query it."""

    def __init__(self, path: pathlib.Path, source: str,
                 root: "pathlib.Path | None" = None):
        self.path = pathlib.Path(path).resolve()
        self.root = root
        self.source = source
        self.tree = ast.parse(source, filename=str(path))
        self._parent: dict[ast.AST, ast.AST] = {}
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                self._parent[child] = node
        self.line_disables: dict[int, set[str]] = {}
        self.file_disables: set[str] = set()
        for lineno, line in enumerate(source.splitlines(), start=1):
            m = SUPPRESS_FILE_RE.search(line)
            if m:
                self.file_disables |= _rule_ids(m.group(1))
                continue
            m = SUPPRESS_LINE_RE.search(line)
            if m:
                self.line_disables.setdefault(lineno, set()).update(
                    _rule_ids(m.group(1)))

    @property
    def rel(self) -> str:
        """Display path: root-relative when a root is known."""
        if self.root is not None:
            with contextlib.suppress(ValueError):
                return str(self.path.relative_to(self.root))
        return str(self.path)

    def dotted_name(self) -> str:
        """Import path of the module (``repro.core.window``), derived from
        the file path: everything after the last ``src`` component, else
        the root-relative path. ``__init__`` maps to its package."""
        parts = list(self.path.with_suffix("").parts)
        if "src" in parts:
            parts = parts[len(parts) - parts[::-1].index("src"):]
        elif self.root is not None:
            with contextlib.suppress(ValueError):
                parts = list(
                    self.path.relative_to(self.root).with_suffix("").parts)
        if parts and parts[-1] == "__init__":
            parts = parts[:-1]
        return ".".join(parts)

    def parent(self, node: ast.AST) -> "ast.AST | None":
        return self._parent.get(node)

    def function_ancestors(self, node: ast.AST) -> list[ast.AST]:
        """Enclosing function-like nodes, innermost first."""
        out = []
        cur = self._parent.get(node)
        while cur is not None:
            if isinstance(cur, FunctionNode):
                out.append(cur)
            cur = self._parent.get(cur)
        return out

    def enclosing_function(self, node: ast.AST) -> "ast.AST | None":
        """The innermost function-like node containing ``node`` (else None)."""
        ancestors = self.function_ancestors(node)
        return ancestors[0] if ancestors else None

    def suppressed(self, rule_id: str, line: int) -> bool:
        if rule_id in self.file_disables or "ALL" in self.file_disables:
            return True
        active = self.line_disables.get(line, ())
        return rule_id in active or "ALL" in active


# -- shared AST helpers (imported by the rule modules) ------------------------


def call_name(node: ast.Call) -> "str | None":
    """Rightmost name of a call target: ``pl.pallas_call(...)`` → ``pallas_call``."""
    func = node.func
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return None


def calls_named(tree: ast.AST, name: str) -> Iterator[ast.Call]:
    """Every call in ``tree`` whose target's rightmost name is ``name``."""
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and call_name(node) == name:
            yield node


def defined_function_names(tree: ast.AST) -> set[str]:
    """Names of every def/async-def anywhere in ``tree`` (methods included)."""
    return {node.name for node in ast.walk(tree)
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))}


def get_keyword(node: ast.Call, name: str) -> "ast.expr | None":
    for kw in node.keywords:
        if kw.arg == name:
            return kw.value
    return None


# -- rule base + registry -----------------------------------------------------


class Rule:
    """Base class: subclass, set ``id``/``title``/``contract``, implement
    :meth:`check`, and decorate with :func:`register`."""

    id: str = ""
    title: str = ""
    #: One-paragraph statement of the invariant (rendered by --list-rules).
    contract: str = ""

    def check(self, module: Module) -> Iterator[Finding]:
        raise NotImplementedError

    def finding(self, module: Module, node: ast.AST, message: str,
                path: "str | None" = None,
                line: "int | None" = None) -> Finding:
        """Build a finding anchored at ``node`` (or an explicit path/line —
        used by rules that report against a non-source file like API.md)."""
        return Finding(path if path is not None else module.rel,
                       line if line is not None
                       else getattr(node, "lineno", 1),
                       getattr(node, "col_offset", 0) if path is None else 0,
                       self.id, message)


_REGISTRY: dict[str, Rule] = {}


def register(cls: type) -> type:
    """Class decorator adding a rule (by its ``id``) to the global registry."""
    rule = cls()
    if not rule.id:
        raise ValueError(f"rule {cls.__name__} has no id")
    if rule.id in _REGISTRY and type(_REGISTRY[rule.id]) is not cls:
        raise ValueError(f"duplicate rule id {rule.id}")
    _REGISTRY[rule.id] = rule
    return cls


def all_rules() -> list[Rule]:
    return [_REGISTRY[rule_id] for rule_id in sorted(_REGISTRY)]


def get_rule(rule_id: str) -> Rule:
    try:
        return _REGISTRY[rule_id]
    except KeyError:
        known = ", ".join(sorted(_REGISTRY))
        raise KeyError(f"unknown rule {rule_id!r} (known: {known})") from None


# -- the linter driver --------------------------------------------------------


def iter_python_files(paths: Iterable[pathlib.Path]) -> list[pathlib.Path]:
    """Expand files/directories to a sorted, de-duplicated .py file list."""
    out: set[pathlib.Path] = set()
    for path in paths:
        path = pathlib.Path(path)
        if path.is_dir():
            for sub in path.rglob("*.py"):
                if not SKIP_DIR_NAMES & set(sub.parts):
                    out.add(sub.resolve())
        elif path.suffix == ".py":
            out.add(path.resolve())
    return sorted(out)


class Linter:
    """Runs a set of rules over files/trees and applies suppressions.

    ``rules=None`` runs every registered rule. ``root=`` overrides repo-root
    detection (tests point it at fixture trees); by default each file's
    root is found by walking up to the nearest ``pyproject.toml``/``.git``.
    """

    def __init__(self, rules: "Iterable[Rule] | None" = None,
                 root: "pathlib.Path | None" = None):
        self.rules = list(rules) if rules is not None else all_rules()
        self.root = pathlib.Path(root).resolve() if root is not None else None
        self.files_checked = 0

    def lint_file(self, path: pathlib.Path) -> list[Finding]:
        path = pathlib.Path(path)
        root = self.root if self.root is not None else find_root(path)
        module = Module(path, path.read_text(encoding="utf-8"), root)
        self.files_checked += 1
        findings = []
        for rule in self.rules:
            for f in rule.check(module):
                # Line suppressions apply to findings anchored in this
                # module; findings a rule reports against another file
                # (e.g. a stale API.md entry) cannot be suppressed here.
                if f.path == module.rel and module.suppressed(f.rule, f.line):
                    continue
                findings.append(f)
        return findings

    def lint(self, paths: Iterable[pathlib.Path]) -> list[Finding]:
        findings: list[Finding] = []
        for path in iter_python_files(paths):
            findings.extend(self.lint_file(path))
        return sorted(set(findings))


# -- output -------------------------------------------------------------------


def render_human(findings: list[Finding], files_checked: int = 0) -> str:
    if not findings:
        return f"graphlint: {files_checked} files clean"
    lines = [f.render() for f in findings]
    lines.append(f"graphlint: {len(findings)} finding(s) in "
                 f"{files_checked} files")
    return "\n".join(lines)


def render_json(findings: list[Finding], files_checked: int = 0) -> str:
    return json.dumps({
        "version": 1,
        "files_checked": files_checked,
        "count": len(findings),
        "findings": [f.to_dict() for f in findings],
    }, indent=2)

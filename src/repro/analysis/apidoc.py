"""Rule G006: docs/API.md coverage + docstrings for the documented core.

This is the ast half of the old ``scripts/check_links.py`` docs gate,
promoted to a first-class graphlint rule (check_links.py keeps the
link/anchor and embedded ``--help`` checks). One source of truth: the
hand-written ``## `repro.x.y` `` sections of docs/API.md define which
modules are *documented core*; for those modules this rule enforces, in
both directions,

* every ``### `name(...)` `` entry still names a public def/class (or
  ``Class.method``) — else a stale-entry finding anchored in API.md;
* every public module-level def/class, and every public method of a
  public class, has an entry — else an undocumented-surface finding at
  the def;
* every such public name carries a docstring — the one-line contract
  API.md summarizes must exist at the def itself.

Modules without an API.md section are out of scope (the rule is a
coverage contract for the documented core, not a docstring style gate
for the whole tree).
"""

from __future__ import annotations

import ast
import pathlib
import re
from typing import Iterator

from repro.analysis.linter import Finding, Module, Rule, register

API_MODULE_RE = re.compile(r"^##\s+`(repro\.[\w.]+)`")
API_ENTRY_RE = re.compile(r"^###\s+`([A-Za-z_][\w.]*)")

#: Parsed API.md per file path → (mtime, {module: {entry: line}}).
_API_CACHE: dict = {}


def parse_api_doc(path: pathlib.Path) -> "dict[str, dict[str, int]]":
    """``{module: {entry_name: line}}`` from the ``##``/``###`` structure
    of an API reference file; a non-module ``## `` heading closes the
    current module scope."""
    mtime = path.stat().st_mtime_ns
    cached = _API_CACHE.get(path)
    if cached is not None and cached[0] == mtime:
        return cached[1]
    sections: dict[str, dict[str, int]] = {}
    module = None
    for lineno, line in enumerate(
            path.read_text(encoding="utf-8").splitlines(), start=1):
        m = API_MODULE_RE.match(line)
        if m:
            module = m.group(1)
            sections.setdefault(module, {})
            continue
        if line.startswith("## "):
            module = None
            continue
        e = API_ENTRY_RE.match(line)
        if e and module is not None:
            sections[module].setdefault(e.group(1), lineno)
    _API_CACHE[path] = (mtime, sections)
    return sections


def public_surface(tree: ast.Module) -> "dict[str, ast.AST]":
    """Public names an API reference must cover: module-level defs/classes
    plus public methods of public classes — nested helper defs are not
    surface. Maps each name to its def node (for line anchors and
    docstring checks)."""
    names: dict[str, ast.AST] = {}
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if not node.name.startswith("_"):
                names[node.name] = node
        elif isinstance(node, ast.ClassDef) and not node.name.startswith("_"):
            names[node.name] = node
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                        and not sub.name.startswith("_"):
                    names[f"{node.name}.{sub.name}"] = sub
    return names


@register
class ApiDocCoverage(Rule):
    """G006: documented-core modules ↔ docs/API.md, with docstrings."""

    id = "G006"
    title = "docs/API.md drift or missing docstring on documented surface"
    contract = (
        "docs/API.md is the hand-written contract sheet for the core "
        "modules; CI enforces it in both directions. For every module "
        "with a '## `repro.x.y`' section: each '### `name(...)`' entry "
        "must name a live public def/class/method (stale entries are "
        "flagged in API.md itself), each public name must have an entry "
        "(new surface cannot ship undocumented), and each public name "
        "must carry a docstring — the one-line contract the reference "
        "summarizes has to exist at the def."
    )

    DOC_RELPATH = ("docs", "API.md")

    def _api_path(self, module: Module) -> "pathlib.Path | None":
        if module.root is None:
            return None
        path = module.root.joinpath(*self.DOC_RELPATH)
        return path if path.is_file() else None

    def check(self, module: Module) -> Iterator[Finding]:
        api_path = self._api_path(module)
        if api_path is None:
            return
        sections = parse_api_doc(api_path)
        entries = sections.get(module.dotted_name())
        if entries is None:
            return
        doc_rel = "/".join(self.DOC_RELPATH)
        surface = public_surface(module.tree)
        for entry, lineno in entries.items():
            if entry not in surface:
                yield self.finding(
                    module, module.tree,
                    f"stale API reference entry `{entry}` — no such public "
                    f"def/class in {module.dotted_name()}; update or drop "
                    "the entry",
                    path=doc_rel, line=lineno)
        for name, node in surface.items():
            if name not in entries:
                yield self.finding(
                    module, node,
                    f"public name {name} of {module.dotted_name()} is "
                    f"undocumented — add a '### `{name}(...)`' entry to "
                    f"{doc_rel}")
            if not ast.get_docstring(node):
                yield self.finding(
                    module, node,
                    f"{name} is documented API surface but has no "
                    "docstring — state the contract at the def, not only "
                    f"in {doc_rel}")

"""Rules G001–G005, G007–G010: the launch/cache/sync/seeding invariants.

Each rule encodes one contract the executors' module docstrings state in
prose (core/trigrid.py, core/snapshots.py, core/window.py, core/service.py,
core/ingest.py, graph/semiring.py, graph/stability.py) — see docs/ANALYSIS.md for the
catalog with real before/after examples. Rules are static and name-based: they resolve
callees by their rightmost name within one module (no cross-module import
resolution), which is exactly the granularity the contracts are written
at. Escape hatch for a deliberate exception:
``# graphlint: disable=GNNN`` on the offending line.
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.linter import (
    Finding,
    Module,
    Rule,
    call_name,
    calls_named,
    defined_function_names,
    get_keyword,
    register,
)


@register
class PallasKernelLocation(Rule):
    """G001: ``pl.pallas_call`` only inside ``repro/kernels/`` modules."""

    id = "G001"
    title = "pallas_call outside a kernels/ module"
    contract = (
        "Every pl.pallas_call lives under src/repro/kernels/*: kernels ship "
        "as <name>.py (pallas_call + BlockSpec), ops.py (jit wrapper) and "
        "ref.py (jnp oracle) with interpret-mode tests, so an ad-hoc "
        "pallas_call in an executor bypasses the compat shims "
        "(kernels/pallas_compat.py) and the oracle test pattern."
    )

    def check(self, module: Module) -> Iterator[Finding]:
        if "kernels" in module.path.parts:
            return
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Call) and call_name(node) == "pallas_call":
                yield self.finding(
                    module, node,
                    "pl.pallas_call outside src/repro/kernels/ — add a "
                    "kernel module (with ops.py wrapper + ref.py oracle) "
                    "instead of an inline kernel")
            elif isinstance(node, ast.ImportFrom):
                for alias in node.names:
                    if alias.name == "pallas_call":
                        yield self.finding(
                            module, node,
                            "importing pallas_call outside src/repro/"
                            "kernels/ — kernels own the pallas surface")


@register
class LaneBucketDiscipline(Rule):
    """G002: batched launches must use ``lane_bucket``-derived lane counts."""

    id = "G002"
    title = "batched launch without lane_bucket-derived lane count"
    contract = (
        "The shape-bucketing invariant (core/trigrid.py PR 3): every "
        "stacked lane buffer pads its lane axis to lane_bucket(lanes, "
        "data_extent) — pow2 and mesh-divisible, trailing lanes masked — "
        "so jit trace keys stay (pow2 lanes, pow2 width) and every launch "
        "shards. Raw-integer or un-bucketed num_lanes= arguments, and "
        "batched-engine launches from functions that never compute a "
        "bucket, break that invariant silently."
    )

    #: Stacking entry points whose ``num_lanes=`` must be bucket-derived.
    STACKERS = ("stack_delta_blocks", "delta_stack", "slide_stack")
    #: Batched-engine launches: the enclosing scope must compute a bucket.
    LAUNCHES = ("incremental_additions_batched", "batched_incremental")
    BUCKET_FN = "lane_bucket"

    def check(self, module: Module) -> Iterator[Finding]:
        local_defs = defined_function_names(module.tree)
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            if name in self.STACKERS:
                yield from self._check_stacker(module, node, name)
            elif name in self.LAUNCHES and name not in local_defs \
                    and not self._scope_calls_bucket(module, node):
                # Launch calls inside the defining module are engine
                # plumbing (incremental_additions_batched ->
                # batched_incremental), hence the local_defs exemption.
                yield self.finding(
                    module, node,
                    f"{name} launched from a scope that never calls "
                    f"{self.BUCKET_FN}() — pad the lane axis to "
                    "lane_bucket(lanes, data_extent) (masked trailing "
                    "lanes) before launching")

    def _check_stacker(self, module: Module, node: ast.Call,
                       name: str) -> Iterator[Finding]:
        value = get_keyword(node, "num_lanes")
        if value is None:
            yield self.finding(
                module, node,
                f"{name} without num_lanes= stacks the exact lane count — "
                "pass num_lanes=lane_bucket(lanes, data_extent) so the "
                "lane axis is pow2 and mesh-divisible")
            return
        if isinstance(value, ast.Constant):
            what = ("num_lanes=None disables"
                    if value.value is None else
                    f"raw literal num_lanes={value.value!r} bypasses")
            yield self.finding(
                module, node,
                f"{name}: {what} lane bucketing — derive the count via "
                "lane_bucket(lanes, data_extent)")
            return
        if not self._bucket_derived(module, node, value):
            yield self.finding(
                module, node,
                f"{name}: num_lanes is not derived from "
                f"{self.BUCKET_FN}() in the enclosing scope — un-bucketed "
                "lane counts fork jit traces and break mesh divisibility")

    def _bucket_derived(self, module: Module, call: ast.Call,
                        value: ast.expr) -> bool:
        if isinstance(value, ast.Call) and call_name(value) == self.BUCKET_FN:
            return True
        if not isinstance(value, ast.Name):
            return False
        scope = self._outermost_scope(module, call)
        for fn in module.function_ancestors(call):
            # Pass-through wrappers: forwarding a parameter literally named
            # num_lanes (SnapshotStore.delta_stack/slide_stack) is the
            # caller's obligation, not the wrapper's.
            args = fn.args
            params = [a.arg for a in (*args.posonlyargs, *args.args,
                                      *args.kwonlyargs)]
            if value.id == "num_lanes" and value.id in params:
                return True
        return any(
            isinstance(assign, ast.Assign)
            and isinstance(assign.value, ast.Call)
            and call_name(assign.value) == self.BUCKET_FN
            and any(isinstance(t, ast.Name) and t.id == value.id
                    for t in assign.targets)
            for assign in ast.walk(scope))

    def _scope_calls_bucket(self, module: Module, node: ast.Call) -> bool:
        return any(calls_named(self._outermost_scope(module, node),
                               self.BUCKET_FN))

    @staticmethod
    def _outermost_scope(module: Module, node: ast.AST) -> ast.AST:
        ancestors = module.function_ancestors(node)
        return ancestors[-1] if ancestors else module.tree


@register
class CanonicalCacheTags(Rule):
    """G003: SnapshotStore cache tags only via the canonical tag helpers."""

    id = "G003"
    title = "literal SnapshotStore cache tag outside the canonical helpers"
    contract = (
        "Cache tags are part of the store's pure-cache contract: every "
        "block is a pure function of (seq, tag), delta_stack tags embed "
        "the pow2 lane bucket so trace keys follow bucketed shapes, and "
        "pinning is by tag. All tag tuples are therefore built in ONE "
        "module — core/snapshots.py ('T'/'Ts'/'D'/'DS'/'A'/'AS' families, "
        "plus anchor_tag for pin/unpin callers). A literal or f-string tag "
        "anywhere else can silently alias or miss the canonical entry."
    )

    #: Callable name -> index of its tag argument.
    TAG_ARGS = {"pin": 0, "unpin": 0, "_cache_get": 0, "_cache_put": 0,
                "block_for_keys": 1}
    PRIVATE = ("_cache_get", "_cache_put")

    @staticmethod
    def _is_canonical(module: Module) -> bool:
        return any(isinstance(node, ast.ClassDef)
                   and node.name == "SnapshotStore"
                   for node in module.tree.body)

    @staticmethod
    def _literal_tag(value: ast.expr) -> bool:
        if isinstance(value, ast.JoinedStr):
            return True
        if isinstance(value, ast.Constant) and isinstance(value.value, str):
            return True
        if isinstance(value, ast.Tuple) and value.elts:
            head = value.elts[0]
            return (isinstance(head, ast.JoinedStr)
                    or (isinstance(head, ast.Constant)
                        and isinstance(head.value, str)))
        return False

    def check(self, module: Module) -> Iterator[Finding]:
        if self._is_canonical(module):
            return
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            if name not in self.TAG_ARGS:
                continue
            if name in self.PRIVATE:
                yield self.finding(
                    module, node,
                    f"SnapshotStore.{name} is private cache plumbing — go "
                    "through a canonical accessor (window_block/delta_block/"
                    "delta_stack/anchor_state_*) so tags stay bucketed")
                continue
            idx = self.TAG_ARGS[name]
            value = (node.args[idx] if len(node.args) > idx
                     else get_keyword(node, "tag"))
            if value is not None and self._literal_tag(value):
                yield self.finding(
                    module, node,
                    f"literal cache tag passed to {name}() — build tags "
                    "with the canonical helpers in core/snapshots.py "
                    "(e.g. anchor_tag) so family strings and lane-bucket "
                    "components cannot drift")


@register
class HostSyncDiscipline(Rule):
    """G004: no host syncs in jitted/hot code; timing syncs via host_sync."""

    id = "G004"
    title = "host synchronization on the device hot path"
    contract = (
        "block_until_ready()/.item()/np.asarray inside a jitted function "
        "(or anything the relax-sweep hot path calls) either fails at "
        "trace time or — worse — silently forces a host round-trip per "
        "sweep. Outside jit, wall-clock timing syncs are legal but must "
        "route through repro.graph.engine.host_sync() so the ONE "
        "sanctioned sync point is greppable; benchmark modules "
        "(benchmarks/) are allowlisted wholesale."
    )

    SYNC_METHODS = ("block_until_ready", "item")
    NUMPY_NAMES = ("np", "numpy")
    HOST_CONVERTERS = ("asarray", "array")
    SANCTIONED = "host_sync"
    HOT_SEEDS = ("relax_sweep",)
    TIMING_DIRS = ("benchmarks",)

    def check(self, module: Module) -> Iterator[Finding]:
        hot = self._hot_functions(module)
        timing_module = bool(set(self.TIMING_DIRS) & set(module.path.parts))
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            enclosing = module.enclosing_function(node)
            in_hot = enclosing in hot
            if isinstance(func, ast.Attribute) and node.args == [] \
                    and func.attr in self.SYNC_METHODS:
                if in_hot:
                    yield self.finding(
                        module, node,
                        f".{func.attr}() inside a jitted/hot-path function "
                        "— host syncs cannot live under trace; hoist to "
                        "the driver")
                elif func.attr == "block_until_ready" and not timing_module \
                        and not self._inside_sanctioned(module, node):
                    yield self.finding(
                        module, node,
                        "bare .block_until_ready() — route timing syncs "
                        "through repro.graph.engine.host_sync() (the "
                        "sanctioned sync point; benchmarks/ is allowlisted)")
            elif in_hot and isinstance(func, ast.Attribute) \
                    and func.attr in self.HOST_CONVERTERS \
                    and isinstance(func.value, ast.Name) \
                    and func.value.id in self.NUMPY_NAMES:
                yield self.finding(
                    module, node,
                    f"np.{func.attr} inside a jitted/hot-path function "
                    "materializes a traced value on host — keep the hot "
                    "path device-only")

    def _inside_sanctioned(self, module: Module, node: ast.AST) -> bool:
        return any(isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef))
                   and fn.name == self.SANCTIONED
                   for fn in module.function_ancestors(node))

    def _hot_functions(self, module: Module) -> set[ast.AST]:
        """Jit-decorated/jit-wrapped defs + everything they (transitively)
        call or nest, resolved by name within this module."""
        defs: list[ast.AST] = [n for n in ast.walk(module.tree)
                               if isinstance(n, (*self._def_types(),))]
        by_name: dict[str, list[ast.AST]] = {}
        for n in defs:
            if not isinstance(n, ast.Lambda):
                by_name.setdefault(n.name, []).append(n)

        hot: set[ast.AST] = set()
        for n in defs:
            if isinstance(n, ast.Lambda):
                continue
            if n.name in self.HOT_SEEDS or any(
                    self._mentions_jit(d) for d in n.decorator_list):
                hot.add(n)
        # jax.jit(fn) / jax.jit(lambda ...) used as an expression.
        for call in calls_named(module.tree, "jit"):
            for arg in call.args:
                if isinstance(arg, ast.Lambda):
                    hot.add(arg)
                elif isinstance(arg, ast.Name):
                    hot.update(by_name.get(arg.id, ()))

        changed = True
        while changed:
            changed = False
            for fn in list(hot):
                for node in ast.walk(fn):
                    if isinstance(node, (*self._def_types(),)) \
                            and node not in hot:
                        hot.add(node)
                        changed = True
                    elif isinstance(node, ast.Call):
                        for callee in by_name.get(call_name(node) or "", ()):
                            if callee not in hot:
                                hot.add(callee)
                                changed = True
        return hot

    @staticmethod
    def _def_types():
        return (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)

    @staticmethod
    def _mentions_jit(decorator: ast.expr) -> bool:
        return any((isinstance(n, ast.Name) and n.id == "jit")
                   or (isinstance(n, ast.Attribute) and n.attr == "jit")
                   for n in ast.walk(decorator))


@register
class SemiringSurface(Rule):
    """G005: semiring definitions complete + registered in ALL_SEMIRINGS."""

    id = "G005"
    title = "incomplete or unregistered Semiring definition"
    contract = (
        "Every monotone path semiring must supply the full contract "
        "surface (name/reduce/identity/source_value/combine, by keyword; "
        "reduce a literal 'min'/'max' — the engine branches on it "
        "statically) and, in a module that defines the ALL_SEMIRINGS "
        "registry, appear in that registry: executors, benchmarks and the "
        "evolve CLI enumerate ALL_SEMIRINGS, so an unregistered semiring "
        "is silently untested and unservable."
    )

    REQUIRED = ("name", "reduce", "identity", "source_value", "combine")
    REGISTRY = "ALL_SEMIRINGS"

    def check(self, module: Module) -> Iterator[Finding]:
        instances: dict[str, ast.Assign] = {}
        registry_value: "ast.expr | None" = None
        for stmt in module.tree.body:
            if not isinstance(stmt, ast.Assign):
                continue
            targets = [t.id for t in stmt.targets if isinstance(t, ast.Name)]
            if self.REGISTRY in targets:
                registry_value = stmt.value
            elif isinstance(stmt.value, ast.Call) \
                    and call_name(stmt.value) == "Semiring" and targets:
                instances[targets[0]] = stmt
                yield from self._check_call(module, stmt.value)
        # AnnAssign (ALL_SEMIRINGS: dict[...] = {...}) registry form.
        for stmt in module.tree.body:
            if isinstance(stmt, ast.AnnAssign) \
                    and isinstance(stmt.target, ast.Name) \
                    and stmt.target.id == self.REGISTRY:
                registry_value = stmt.value
        if registry_value is not None:
            registered = {n.id for n in ast.walk(registry_value)
                          if isinstance(n, ast.Name)}
            for name, stmt in instances.items():
                if name not in registered:
                    yield self.finding(
                        module, stmt,
                        f"Semiring {name} is not referenced by "
                        f"{self.REGISTRY} — unregistered semirings are "
                        "invisible to executors, benchmarks and the CLI")

    def _check_call(self, module: Module,
                    call: ast.Call) -> Iterator[Finding]:
        if call.args:
            yield self.finding(
                module, call,
                "Semiring(...) with positional arguments — use keywords so "
                "the contract surface is checkable and reorder-proof")
        given = {kw.arg for kw in call.keywords if kw.arg}
        missing = [k for k in self.REQUIRED if k not in given]
        if missing:
            yield self.finding(
                module, call,
                f"Semiring(...) missing required field(s) "
                f"{', '.join(missing)} — the monotone-op contract surface "
                "is name/reduce/identity/source_value/combine")
        reduce_kw = get_keyword(call, "reduce")
        if reduce_kw is not None and not (
                isinstance(reduce_kw, ast.Constant)
                and reduce_kw.value in ("min", "max")):
            yield self.finding(
                module, call,
                'Semiring reduce= must be the literal "min" or "max" — '
                "the engine selects its segment reduction statically")


@register
class ServiceSyncBoundary(Rule):
    """G007: service modules sync only at packed-launch boundaries."""

    id = "G007"
    title = "per-query host sync in a service scheduling loop"
    contract = (
        "The query service's hot loop (admission -> pack -> launch, "
        "core/service.py) must stay sync-free: the ONE host sync per "
        "packed launch lives at the campaign boundary, inside a function "
        "whose name ends with _launch (core/window.py::_slide_launch or a "
        "service-side *_launch executor). A host_sync() / "
        ".block_until_ready() / .item() anywhere else in a service module "
        "— per admitted query, per lane, per client in a scheduling loop "
        "— serializes the open-loop pipeline and destroys batching (it "
        "also makes scheduling wall-clock-dependent, breaking the "
        "machine-independent exact fields BENCH_serve gates on). Applies "
        "to modules named service; other modules keep G004's discipline."
    )

    SYNC_METHODS = ("block_until_ready", "item")
    SANCTIONED_SUFFIX = "_launch"
    MODULE_NAME = "service"

    def check(self, module: Module) -> Iterator[Finding]:
        if module.dotted_name().split(".")[-1] != self.MODULE_NAME:
            return
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            is_sync = (
                (isinstance(func, ast.Name) and func.id == "host_sync")
                or (isinstance(func, ast.Attribute)
                    and func.attr == "host_sync")
                or (isinstance(func, ast.Attribute) and node.args == []
                    and func.attr in self.SYNC_METHODS))
            if is_sync and not self._at_launch_boundary(module, node):
                label = (func.id if isinstance(func, ast.Name)
                         else f".{func.attr}")
                yield self.finding(
                    module, node,
                    f"{label} outside a *{self.SANCTIONED_SUFFIX} function "
                    "— the service hot loop syncs once per packed launch "
                    "at the campaign boundary, never per query")

    def _at_launch_boundary(self, module: Module, node: ast.AST) -> bool:
        return any(isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef))
                   and fn.name.endswith(self.SANCTIONED_SUFFIX)
                   for fn in module.function_ancestors(node))


@register
class StabilitySeedDiscipline(Rule):
    """G008: seed frontiers come from graph/stability.py, not raw Δ sweeps."""

    id = "G008"
    title = "raw relax_sweep seeding outside the stability layer"
    contract = (
        "Frontier seeding is the stable-vertex analysis' monopoly "
        "(graph/stability.py::seed_state): it applies the semiring's "
        "monotone-improvement test so stable vertices never enter the seed "
        "frontier, and it is the one place the instability/delta mode "
        "switch and stable_fraction accounting live. A direct relax_sweep "
        "call anywhere else re-derives a seed frontier from the raw Δ edge "
        "endpoint set — bypassing the pruning, the mode switch and the "
        "accounting at once. Only the stability module itself and the "
        "engine's fixpoint machinery (_fixpoint, where relax_sweep is the "
        "per-sweep step, not a seeding, and relax_sweep_fused, whose "
        "reference path iterates relax_sweep inside one fused chunk) may "
        "call it."
    )

    SWEEP = "relax_sweep"
    STABILITY_MODULE = "repro.graph.stability"
    ENGINE_MODULE = "repro.graph.engine"
    ENGINE_SANCTIONED = ("_fixpoint", "relax_sweep_fused")

    def check(self, module: Module) -> Iterator[Finding]:
        dotted = module.dotted_name()
        if dotted == self.STABILITY_MODULE:
            return
        for node in calls_named(module.tree, self.SWEEP):
            if dotted == self.ENGINE_MODULE and any(
                    isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and fn.name in self.ENGINE_SANCTIONED
                    for fn in module.function_ancestors(node)):
                continue
            yield self.finding(
                module, node,
                f"{self.SWEEP} called outside graph/stability.py — seed "
                "frontiers must come from repro.graph.stability.seed_state "
                "(the stable-vertex analysis), not a raw Δ edge sweep")


@register
class IngestCutDiscipline(Rule):
    """G009: snapshots are cut only via Watermark.cut; no ad-hoc store writes."""

    id = "G009"
    title = "snapshot write outside the watermark cut path"
    contract = (
        "A live SnapshotStore grows through exactly one write path: "
        "ingest.Watermark.cut consumes watermarked events (timestamp "
        "order, last-op-wins, redundancy filtered), maintains the running "
        "common graph, and installs the snapshot + canonical Δ pair via "
        "SnapshotStore.ingest_cut. An ingest_cut call anywhere else skips "
        "that bookkeeping (metrics, sealing, common-graph maintenance); "
        "growing the live sequence directly (.snapshot_keys/.additions/"
        ".deletions .append) desynchronizes the store's window cache from "
        "its sequence; and writing the store's _t/_blocks caches from "
        "outside core/snapshots.py plants entries the pure-cache contract "
        "cannot rebuild. All three are flagged outside their one legal "
        "home (ingest.Watermark.cut / ingest.LiveSequence.append / the "
        "SnapshotStore module itself)."
    )

    WRITE_PATH = "ingest_cut"
    INGEST_MODULE = "repro.core.ingest"
    SANCTIONED_FN = "cut"
    GROW_ATTRS = ("snapshot_keys", "additions", "deletions")
    CACHE_ATTRS = ("_t", "_blocks")

    def check(self, module: Module) -> Iterator[Finding]:
        dotted = module.dotted_name()
        in_ingest = dotted == self.INGEST_MODULE
        canonical = any(isinstance(node, ast.ClassDef)
                        and node.name == "SnapshotStore"
                        for node in module.tree.body)
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Call) \
                    and call_name(node) == self.WRITE_PATH:
                if not (in_ingest and self._in_cut(module, node)):
                    yield self.finding(
                        module, node,
                        f"{self.WRITE_PATH} called outside "
                        "ingest.Watermark.cut — snapshots are born only "
                        "from watermarked cuts (event ordering, sealing, "
                        "common-graph maintenance live there)")
            elif isinstance(node, ast.Call) and not in_ingest \
                    and self._grows_sequence(node):
                yield self.finding(
                    module, node,
                    "appending to a live sequence's snapshot_keys/"
                    "additions/deletions outside core/ingest.py — the "
                    "store's window cache would not see the new snapshot; "
                    "cut it via ingest.Watermark.cut")
            elif isinstance(node, ast.Assign) and not canonical:
                for target in node.targets:
                    attr = self._cache_subscript(target)
                    if attr is not None:
                        yield self.finding(
                            module, node,
                            f"direct write to SnapshotStore.{attr}[...] "
                            "outside core/snapshots.py — cache entries "
                            "must be installable only by the store (pure-"
                            "cache contract); use ingest_cut/the canonical "
                            "accessors")

    def _in_cut(self, module: Module, node: ast.AST) -> bool:
        return any(isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef))
                   and fn.name == self.SANCTIONED_FN
                   for fn in module.function_ancestors(node))

    def _grows_sequence(self, node: ast.Call) -> bool:
        func = node.func
        return (isinstance(func, ast.Attribute) and func.attr == "append"
                and isinstance(func.value, ast.Attribute)
                and func.value.attr in self.GROW_ATTRS)

    def _cache_subscript(self, target: ast.expr) -> "str | None":
        if isinstance(target, ast.Subscript) \
                and isinstance(target.value, ast.Attribute) \
                and target.value.attr in self.CACHE_ATTRS:
            return target.value.attr
        return None


@register
class FusedLaunchDiscipline(Rule):
    """G010: fused relax chunks launch only through the engine's fixpoint."""

    id = "G010"
    title = "fused relax chunk launched outside the sanctioned fixpoint path"
    contract = (
        "relax_sweep_fused (the fused multi-sweep chunk over "
        "kernels/edge_relax_multi) extends G008's seeding monopoly: it IS "
        "a relax-sweep sequence, so launching it from an executor re-opens "
        "the raw-Δ seeding hole G008 closed, and it additionally carries "
        "the bit-exactness contract (fused(k) == k relax_sweep "
        "applications) that only the engine's chunked fixpoint "
        "(engine._fixpoint) and the stability layer's seed sweep "
        "(graph/stability.py, k=1) are tested to preserve. Everything "
        "else reaches fused execution through the fused_k LAUNCH OPTION "
        "threaded engine -> trigrid -> window -> service — and that knob "
        "must flow from launch options (a variable or attribute), never a "
        "literal at a call site, so one configuration point controls every "
        "launch in a run and packed lanes cannot silently mix chunk sizes."
    )

    FUSED = "relax_sweep_fused"
    KNOB = "fused_k"
    STABILITY_MODULE = "repro.graph.stability"
    ENGINE_MODULE = "repro.graph.engine"
    ENGINE_SANCTIONED = "_fixpoint"

    def check(self, module: Module) -> Iterator[Finding]:
        dotted = module.dotted_name()
        if dotted != self.STABILITY_MODULE:
            for node in calls_named(module.tree, self.FUSED):
                if dotted == self.ENGINE_MODULE and any(
                        isinstance(fn, (ast.FunctionDef,
                                        ast.AsyncFunctionDef))
                        and fn.name == self.ENGINE_SANCTIONED
                        for fn in module.function_ancestors(node)):
                    continue
                yield self.finding(
                    module, node,
                    f"{self.FUSED} called outside graph/stability.py and "
                    "engine._fixpoint — executors reach fused execution "
                    "via the fused_k launch option (run_to_fixpoint/"
                    "incremental_additions/...), never by launching fused "
                    "chunks directly")
        if dotted == self.ENGINE_MODULE:
            return
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            value = get_keyword(node, self.KNOB)
            if isinstance(value, ast.Constant):
                yield self.finding(
                    module, node,
                    f"literal {self.KNOB}={value.value!r} at a call site — "
                    "the fused chunk size is a launch option: thread it "
                    "from the caller's options (a variable or attribute), "
                    "so one knob configures every launch in the run")

"""Pure-jnp oracle for the embedding_bag kernel (= models/embedding.py path)."""

from __future__ import annotations

from repro.models.embedding import embedding_bag


def embedding_bag_ref(table, ids, bags, weights, *, n_bags: int):
    return embedding_bag(table, ids, bags, n_bags, weights=weights, mode="sum")

"""jit'd public wrapper for the embedding_bag kernel (VMEM-budget dispatch)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.embedding_bag import (
    BLOCK_L,
    VMEM_TABLE_BUDGET,
    embedding_bag_pallas,
)
from repro.kernels.embedding_bag.ref import embedding_bag_ref


@functools.partial(jax.jit, static_argnames=("n_bags", "use_pallas", "interpret"))
def embedding_bag_fused(table, ids, bags, weights, *, n_bags: int,
                        use_pallas: bool = True, interpret: bool = True):
    """Fused bag-sum. Tables over the VMEM budget stream via the XLA path."""
    table_bytes = table.shape[0] * table.shape[1] * table.dtype.itemsize
    if not use_pallas or table_bytes > VMEM_TABLE_BUDGET:
        return embedding_bag_ref(table, ids, bags, weights, n_bags=n_bags)
    num_ids = ids.shape[0]
    pad = (-num_ids) % BLOCK_L
    if pad:
        ids = jnp.concatenate([ids, jnp.zeros((pad,), ids.dtype)])
        bags = jnp.concatenate([bags, jnp.full((pad,), n_bags, bags.dtype)])
        weights = jnp.concatenate([weights, jnp.zeros((pad,), weights.dtype)])
    return embedding_bag_pallas(table, ids, bags, weights, n_bags=n_bags,
                                interpret=interpret)

"""Pallas TPU kernel: fused EmbeddingBag (multi-hot gather + bag sum).

    out[b, :] = Σ_{i : bag[i]==b} weight[i] · table[ids[i], :]

JAX has no native EmbeddingBag; the reference composition
(``jnp.take`` → multiply → ``segment_sum``) round-trips the gathered rows
through HBM. This kernel fuses the three steps: a lookup chunk's rows are
gathered from the VMEM-resident table shard, scaled, and scatter-added into
the VMEM-resident bag accumulator without ever materializing the [L, D]
intermediate in HBM.

Scope (DESIGN.md §2): the table argument is a *vocabulary shard* — after the
recsys row-sharding over `model`, per-device shards of the DIEN category
table (10⁴×18) and much larger fit VMEM; the 2²³-row item table streams
through the XLA gather path instead (ops.embedding_bag_fused falls back to
ref for tables over the VMEM budget). D pads to the 128-lane boundary.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels.pallas_compat import CompilerParams

BLOCK_L = 1024
LANE = 128
VMEM_TABLE_BUDGET = 8 * 1024 * 1024  # bytes of VMEM we allow the table shard


def _kernel(table_ref, ids_ref, bags_ref, wts_ref, out_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    tbl = table_ref[...]            # [V, D_pad] resident
    ids = ids_ref[...]              # [BLOCK_L]
    bags = bags_ref[...]
    wts = wts_ref[...]
    rows = jnp.take(tbl, ids, axis=0) * wts[:, None]
    out_ref[...] = out_ref[...].at[bags].add(rows)


def embedding_bag_pallas(table, ids, bags, weights, *, n_bags: int,
                         interpret: bool = True):
    """table [V, D]; ids/bags [L] i32 (bag == n_bags for padding); weights [L]."""
    v, d = table.shape
    num_ids = ids.shape[0]
    assert num_ids % BLOCK_L == 0, \
        f"lookup count {num_ids} must be padded to {BLOCK_L}"
    d_pad = (-d) % LANE
    if d_pad:
        table = jnp.pad(table, ((0, 0), (0, d_pad)))
    dp = d + d_pad
    grid = (num_ids // BLOCK_L,)

    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((v, dp), lambda i: (0, 0)),         # resident shard
            pl.BlockSpec((BLOCK_L,), lambda i: (i,)),
            pl.BlockSpec((BLOCK_L,), lambda i: (i,)),
            pl.BlockSpec((BLOCK_L,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((n_bags + 1, dp), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_bags + 1, dp), table.dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
    )(table, ids, bags, weights)
    return out[:n_bags, :d]

"""jit'd public wrapper for the fused k-sweep relax kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.edge_relax_multi.edge_relax_multi import (
    BLOCK_E, relax_multi_pallas)
from repro.kernels.edge_relax_multi.ref import relax_multi_ref

LAYOUTS = ("edge", "csr")


@functools.partial(jax.jit, static_argnames=(
    "op", "num_nodes", "k", "layout", "track_parents", "use_pallas",
    "interpret"))
def relax_multi(values, parent, frontier, src, dst, w, allowed=None, *,
                op: str, num_nodes: int, k: int, layout: str = "edge",
                track_parents: bool = True, use_pallas: bool = True,
                interpret: bool = True):
    """Fused k-sweep frontier-masked relax; pads edges to the kernel block.

    ``allowed`` (traced int32 scalar, default ``k``) dynamically caps the
    executed sweeps below the static grid bound ``k`` — the engine uses it
    to stop a chunk at ``max_iters`` exactly. ``layout`` selects the edge
    stream order fed to the kernel: ``"edge"`` keeps the caller's order,
    ``"csr"`` pre-sorts by dst so the per-block scatter degenerates into
    segment runs (benchmarks/roofline.py compares the two). Results are
    bit-identical either way — every per-node reduction the kernel performs
    (segment min/max, smallest winning src) is permutation-invariant.

    Returns ``(values, parent, frontier, sweeps, work)``. On a real TPU
    pass interpret=False; this container is CPU-only so interpret=True is
    the default (validated in interpret mode, per the assignment).
    """
    if layout not in LAYOUTS:
        raise ValueError(f"unknown layout {layout!r}: expected one of "
                         f"{LAYOUTS}")
    if allowed is None:
        allowed = jnp.int32(k)
    if not use_pallas:
        return relax_multi_ref(values, parent, frontier, src, dst, w,
                               allowed, op=op, num_nodes=num_nodes, k=k,
                               track_parents=track_parents)
    e = src.shape[0]
    pad = (-e) % BLOCK_E
    if e + pad == 0:
        pad = BLOCK_E  # keep at least one (all-padding) block in the grid
    if pad:
        src = jnp.concatenate([src, jnp.zeros((pad,), src.dtype)])
        dst = jnp.concatenate([dst, jnp.full((pad,), num_nodes, dst.dtype)])
        w = jnp.concatenate([w, jnp.zeros((pad,), w.dtype)])
    if layout == "csr":
        perm = jnp.argsort(dst)  # padding (dst == num_nodes) sorts last
        src, dst, w = src[perm], dst[perm], w[perm]
    return relax_multi_pallas(values, parent, frontier, src, dst, w, allowed,
                              op=op, num_nodes=num_nodes, k=k,
                              track_parents=track_parents,
                              interpret=interpret)

"""Pallas TPU kernel: fused k-sweep frontier-masked edge relaxation.

One ``pallas_call`` runs up to ``k`` full relax sweeps (the engine's
Bellman-Ford-style rounds) back to back. The unfused engine pays one HBM
round-trip per sweep: ``_fixpoint``'s while_loop reads the frontier back to
decide convergence, and values/frontier are rematerialized from HBM every
iteration. Here the grid is ``(k, E/BLOCK_E)`` and everything a convergence
check needs stays on chip:

* node values, dependence parents and the frontier bitmask are resident
  VMEM **outputs** (BlockSpec index map pinned to block 0) carried across
  all ``k * nb`` sequential grid steps;
* the per-sweep best-candidate and winner-src accumulators live in VMEM
  scratch, re-initialized at each sweep's first edge block;
* the improved mask written at each sweep's last block *is* the next
  sweep's frontier — on-chip frontier compaction, no HBM round-trip;
* an SMEM run flag computed at each sweep's first block gates every later
  block with ``pl.when``: once the frontier empties (or the dynamic
  ``allowed`` cap is reached) the remaining sweeps retire without touching
  the edge stream — the early-exit path.

Bit-exactness contract (tests/test_kernels_diff.py): for every semiring in
the engine registry, ``(values, parent, frontier, iterations, edge_work)``
equal ``k`` sequential applications of ``engine.relax_sweep`` — including
runs that converge before ``k`` — in interpret and lowered-CPU modes.

The incremental winner merge reproduces the engine's post-hoc cross-block
parent tie-break (smallest winning src): carrying ``(best-so-far, min src
achieving it)`` and merging each block with strictly-better/equal cases is
inductively equal to merging all per-block winners against the final best.

Sentinel row ``num_nodes`` absorbs padding edges (dst == num_nodes); its
value is pinned to the reduce order's *anti-identity* (-inf for min
semirings, +inf for max) so it can never strictly improve and therefore
never re-enters the frontier.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams
from repro.kernels.edge_relax.edge_relax import ops_for

BLOCK_E = 4096
INT_MAX = jnp.iinfo(jnp.int32).max


def anti_identity(op: str) -> float:
    """The value nothing can strictly beat under ``op``'s reduce order."""
    _, reduce_kind, _ = ops_for(op)
    return float(-jnp.inf) if reduce_kind == "min" else float(jnp.inf)


def _kernel(values_in, parent_in, frontier_in, src_ref, dst_ref, w_ref,
            allowed_ref, values_out, parent_out, frontier_out, iters_out,
            work_out, best_acc, winner_acc, run_flag,
            *, op: str, num_nodes: int, blocks_per_sweep: int,
            track_parents: bool):
    combine, reduce_kind, ident_f = ops_for(op)
    is_min = reduce_kind == "min"
    ident = jnp.float32(ident_f)
    sweep = pl.program_id(0)
    blk = pl.program_id(1)

    @pl.when((sweep == 0) & (blk == 0))
    def _init():
        values_out[...] = values_in[...]
        parent_out[...] = parent_in[...]
        frontier_out[...] = frontier_in[...]
        iters_out[...] = jnp.zeros_like(iters_out)
        work_out[...] = jnp.zeros_like(work_out)

    @pl.when(blk == 0)
    def _sweep_init():
        live = jnp.any(frontier_out[...]) & (sweep < allowed_ref[0])
        run_flag[0] = live.astype(jnp.int32)
        best_acc[...] = jnp.full_like(best_acc, ident)
        if track_parents:
            winner_acc[...] = jnp.full_like(winner_acc, INT_MAX)

    run = run_flag[0] > 0

    @pl.when(run)
    def _block():
        vals = values_out[...]
        s, d, w = src_ref[...], dst_ref[...], w_ref[...]
        active = jnp.take(frontier_out[...], s, axis=0)
        cand = jnp.where(active, combine(jnp.take(vals, s, axis=0), w), ident)
        full_ident = jnp.full((num_nodes + 1,), ident)
        if is_min:
            blk_best = full_ident.at[d].min(cand)
        else:
            blk_best = full_ident.at[d].max(cand)
        ba = best_acc[...]
        if track_parents:
            # smallest winning src in this block, merged incrementally
            is_win = active & (cand == jnp.take(blk_best, d, axis=0))
            blk_winner = jnp.full(
                (num_nodes + 1,), INT_MAX, jnp.int32
            ).at[d].min(jnp.where(is_win, s, INT_MAX))
            wa = winner_acc[...]
            stricter = (blk_best < ba) if is_min else (blk_best > ba)
            winner_acc[...] = jnp.where(
                stricter, blk_winner,
                jnp.where(blk_best == ba, jnp.minimum(wa, blk_winner), wa))
        best_acc[...] = (jnp.minimum(ba, blk_best) if is_min
                         else jnp.maximum(ba, blk_best))
        work_out[...] = work_out[...] + jnp.sum(
            active & (d < num_nodes), dtype=jnp.float32)

    @pl.when(run & (blk == blocks_per_sweep - 1))
    def _finish():
        vals = values_out[...]
        best = best_acc[...]
        improved = (best < vals) if is_min else (best > vals)
        values_out[...] = (jnp.minimum(vals, best) if is_min
                           else jnp.maximum(vals, best))
        if track_parents:
            parent_out[...] = jnp.where(improved, winner_acc[...],
                                        parent_out[...])
        frontier_out[...] = improved
        iters_out[...] = iters_out[...] + 1


def relax_multi_pallas(values, parent, frontier, src, dst, w, allowed, *,
                       op: str, num_nodes: int, k: int,
                       track_parents: bool = True, interpret: bool = True):
    """Fused k-sweep relax over one padded edge stream.

    values [N] f32, parent [N] i32, frontier [N] bool; src/dst [E] i32
    (dst == N for padding), w [E] f32 with E a multiple of BLOCK_E;
    ``allowed`` an int32 scalar dynamically capping executed sweeps at
    ``min(k, allowed)``. Returns ``(values, parent, frontier, sweeps,
    work)`` with the sentinel row dropped.
    """
    e = src.shape[0]
    # A real error, not an assert: `python -O` strips asserts, and a
    # misaligned edge stream would silently drop the trailing partial block.
    if e == 0 or e % BLOCK_E != 0:
        raise ValueError(
            f"edge count {e} is not a positive multiple of the kernel block "
            f"BLOCK_E={BLOCK_E}; pad the edge stream (sentinel dst == "
            f"num_nodes) before calling relax_multi_pallas")
    if k < 1:
        raise ValueError(f"fused sweep count k={k} must be >= 1")
    nb = e // BLOCK_E
    anti = jnp.float32(anti_identity(op))
    values_pad = jnp.concatenate([values, anti[None]])
    parent_pad = jnp.concatenate([parent, jnp.zeros((1,), parent.dtype)])
    frontier_pad = jnp.concatenate([frontier, jnp.zeros((1,), bool)])
    resident = pl.BlockSpec((num_nodes + 1,), lambda s, i: (0,))
    tiled = pl.BlockSpec((BLOCK_E,), lambda s, i: (i,))
    scalar = pl.BlockSpec((1,), lambda s, i: (0,))
    out = pl.pallas_call(
        functools.partial(_kernel, op=op, num_nodes=num_nodes,
                          blocks_per_sweep=nb, track_parents=track_parents),
        grid=(k, nb),
        in_specs=[resident, resident, resident, tiled, tiled, tiled, scalar],
        out_specs=[resident, resident, resident, scalar, scalar],
        out_shape=[
            jax.ShapeDtypeStruct((num_nodes + 1,), values.dtype),
            jax.ShapeDtypeStruct((num_nodes + 1,), parent.dtype),
            jax.ShapeDtypeStruct((num_nodes + 1,), jnp.bool_),
            jax.ShapeDtypeStruct((1,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((num_nodes + 1,), jnp.float32),   # best_acc
            pltpu.VMEM((num_nodes + 1,), jnp.int32),     # winner_acc
            pltpu.SMEM((1,), jnp.int32),                 # run_flag
        ],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
    )(values_pad, parent_pad, frontier_pad, src, dst, w,
      jnp.asarray(allowed, jnp.int32).reshape((1,)))
    vals, par, fro, sweeps, work = out
    return (vals[:num_nodes], par[:num_nodes], fro[:num_nodes],
            sweeps[0], work[0])

"""Fused k-sweep frontier-masked relax kernel (see edge_relax_multi.py)."""

from repro.kernels.edge_relax_multi.ops import relax_multi
from repro.kernels.edge_relax_multi.ref import relax_multi_ref

__all__ = ["relax_multi", "relax_multi_ref"]

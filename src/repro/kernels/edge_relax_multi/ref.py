"""Pure-jnp oracle for the fused k-sweep relax kernel.

Mirrors ``engine.relax_sweep`` applied ``min(k, allowed)`` times over one
(padded) edge stream with early exit on an empty frontier — the same
contract the pallas kernel is differential-tested against. Self-contained
on purpose: kernels must not import the engine (the engine imports the
kernels), so the sweep semantics are restated here and the equivalence is
enforced by tests/test_kernels_diff.py rather than by sharing code.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.edge_relax.edge_relax import ops_for

INT_MAX = jnp.iinfo(jnp.int32).max


def _sweep(combine, is_min, ident, num_nodes, values, parent, frontier,
           src, dst, w, track_parents):
    """One frontier-masked sweep; returns (values, parent, improved, work)."""
    active = frontier[src]
    cand = jnp.where(active, combine(values[src], w), ident)
    if is_min:
        best = jax.ops.segment_min(cand, dst, num_nodes + 1)[:num_nodes]
    else:
        best = jax.ops.segment_max(cand, dst, num_nodes + 1)[:num_nodes]
    work = jnp.sum(active & (dst < num_nodes), dtype=jnp.float32)
    improved = (best < values) if is_min else (best > values)
    new_values = (jnp.minimum(values, best) if is_min
                  else jnp.maximum(values, best))
    if not track_parents:
        return new_values, parent, improved, work
    best_pad = jnp.concatenate([best, jnp.float32([ident])])
    is_win = active & (cand == best_pad[dst])
    winner = jax.ops.segment_min(jnp.where(is_win, src, INT_MAX), dst,
                                 num_nodes + 1)[:num_nodes]
    new_parent = jnp.where(improved, winner, parent)
    return new_values, new_parent, improved, work


def relax_multi_ref(values, parent, frontier, src, dst, w, allowed=None, *,
                    op: str, num_nodes: int, k: int,
                    track_parents: bool = True):
    """``min(k, allowed)`` sweeps with early exit — the kernel's oracle.

    Returns ``(values, parent, frontier, sweeps, work)``.
    """
    combine, reduce_kind, ident_f = ops_for(op)
    is_min = reduce_kind == "min"
    ident = jnp.float32(ident_f)
    cap = jnp.minimum(jnp.int32(k),
                      jnp.int32(k) if allowed is None
                      else jnp.asarray(allowed, jnp.int32))

    def cond(state):
        _, _, frontier, s, _ = state
        return jnp.logical_and(s < cap, jnp.any(frontier))

    def body(state):
        vals, par, fro, s, wk = state
        vals, par, improved, dw = _sweep(
            combine, is_min, ident, num_nodes, vals, par, fro, src, dst, w,
            track_parents)
        return vals, par, improved, s + 1, wk + dw

    init = (values, parent, frontier, jnp.int32(0), jnp.float32(0))
    vals, par, fro, sweeps, work = jax.lax.while_loop(cond, body, init)
    return vals, par, fro, sweeps, work

"""Pure-jnp oracle for the edge_relax kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.edge_relax.edge_relax import ops_for


def edge_relax_ref(values, src, dst, w, *, op: str, num_nodes: int):
    combine, reduce_kind, ident = ops_for(op)
    cand = combine(values[src], w)
    if reduce_kind == "min":
        out = jax.ops.segment_min(cand, dst, num_nodes + 1)
        out = jnp.minimum(out, ident)   # empty segments -> semiring identity
    else:
        out = jax.ops.segment_max(cand, dst, num_nodes + 1)
        out = jnp.maximum(out, ident)   # (e.g. Viterbi identity is 0, not -inf)
    return out[:num_nodes]

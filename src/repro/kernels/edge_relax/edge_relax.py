"""Pallas TPU kernel: semiring edge relaxation (the paper's hot loop).

    out[v] = reduce_{e : dst[e]==v} combine(values[src[e]], w[e])

Design (TPU adaptation of the CPU papers' per-vertex worklists — DESIGN.md §2):

* the edge stream is tiled through VMEM in BLOCK_E-sized chunks
  (BlockSpec over the grid's edge axis); src/dst/w chunks are the only
  HBM traffic that scales with E;
* the node-value vector stays **resident in VMEM** across all grid steps
  (per-shard node counts after (data, model) sharding are ≤ a few hundred
  kB — far under VMEM);
* the output accumulates across sequentially-executed grid steps
  (TPU grids are sequential; dimension_semantics=("arbitrary",) makes the
  carried read-modify-write legal);
* dst-sorted blocks (the substrate's standard layout) make the per-block
  scatter a near-monotone segment update, which the Mosaic compiler turns
  into runs rather than random access.

Semirings: min_plus (SSSP), min_plus_unit (BFS — unit edge cost, weights
ignored), max_min (SSWP), min_max (SSNP), max_times (Viterbi); the
engine-name → kernel-op mapping is :data:`KERNEL_OP_FOR` and is
completeness-tested against ``ALL_SEMIRINGS`` (tests/test_kernels_diff.py).
Padding edges carry dst == num_nodes and land in the sentinel row, which
the wrapper drops.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels.pallas_compat import CompilerParams

BLOCK_E = 4096

SEMIRING_OPS = {
    # name: (combine, reduce-kind, identity)
    "min_plus": (lambda v, w: v + w, "min", jnp.inf),
    "min_plus_unit": (lambda v, w: v + 1.0, "min", jnp.inf),  # BFS: unit cost
    "max_min": (lambda v, w: jnp.minimum(v, w), "max", -jnp.inf),
    "min_max": (lambda v, w: jnp.maximum(v, w), "min", jnp.inf),
    "max_times": (lambda v, w: v * w, "max", 0.0),
}

# Engine semiring name -> kernel op name. One entry per ALL_SEMIRINGS member;
# tests/test_kernels_diff.py cross-checks completeness in both directions.
KERNEL_OP_FOR = {
    "bfs": "min_plus_unit",
    "sssp": "min_plus",
    "sswp": "max_min",
    "ssnp": "min_max",
    "viterbi": "max_times",
}


class UnsupportedSemiring(KeyError):
    """A kernel was asked for a semiring op it has no lowering for."""


def ops_for(op: str):
    """Resolve ``op`` in SEMIRING_OPS, raising loudly on unknown names."""
    try:
        return SEMIRING_OPS[op]
    except KeyError as exc:
        raise UnsupportedSemiring(
            f"no kernel lowering for semiring op {op!r}; known ops: "
            f"{sorted(SEMIRING_OPS)}") from exc


def _kernel(values_ref, src_ref, dst_ref, w_ref, out_ref, *, op: str):
    combine, reduce_kind, ident = ops_for(op)
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, ident)

    vals = values_ref[...]          # [N+1] resident
    s = src_ref[...]                # [BLOCK_E]
    d = dst_ref[...]
    w = w_ref[...]
    cand = combine(jnp.take(vals, s, axis=0), w)
    acc = out_ref[...]
    if reduce_kind == "min":
        out_ref[...] = acc.at[d].min(cand)
    else:
        out_ref[...] = acc.at[d].max(cand)


def edge_relax_pallas(values, src, dst, w, *, op: str, num_nodes: int,
                      interpret: bool = True):
    """values [N] f32; src/dst [E] i32 (dst == N for padding); w [E] f32.

    Returns the [N] segment-reduced candidate vector (sentinel row dropped).
    """
    e = src.shape[0]
    # A real error, not an assert: `python -O` strips asserts, and a
    # misaligned edge stream would silently drop the trailing partial block.
    if e % BLOCK_E != 0:
        raise ValueError(
            f"edge count {e} is not a multiple of the kernel block "
            f"BLOCK_E={BLOCK_E}; pad the edge stream (sentinel dst == "
            f"num_nodes) before calling edge_relax_pallas")
    grid = (e // BLOCK_E,)
    # sentinel row N absorbs padding edges
    values_pad = jnp.concatenate([values, jnp.zeros((1,), values.dtype)])

    out = pl.pallas_call(
        functools.partial(_kernel, op=op),
        grid=grid,
        in_specs=[
            pl.BlockSpec((num_nodes + 1,), lambda i: (0,)),      # resident
            pl.BlockSpec((BLOCK_E,), lambda i: (i,)),
            pl.BlockSpec((BLOCK_E,), lambda i: (i,)),
            pl.BlockSpec((BLOCK_E,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((num_nodes + 1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((num_nodes + 1,), values.dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
    )(values_pad, src, dst, w)
    return out[:num_nodes]

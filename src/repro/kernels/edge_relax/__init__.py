from repro.kernels.edge_relax.ops import edge_relax

__all__ = ["edge_relax"]

"""jit'd public wrapper for the edge_relax kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.edge_relax.edge_relax import BLOCK_E, edge_relax_pallas
from repro.kernels.edge_relax.ref import edge_relax_ref


@functools.partial(jax.jit, static_argnames=("op", "num_nodes", "use_pallas",
                                             "interpret"))
def edge_relax(values, src, dst, w, *, op: str, num_nodes: int,
               use_pallas: bool = True, interpret: bool = True):
    """Semiring edge relaxation; pads the edge stream to the kernel block.

    On a real TPU pass interpret=False; this container is CPU-only so
    interpret=True is the default (assignment: validate in interpret mode).
    """
    if not use_pallas:
        return edge_relax_ref(values, src, dst, w, op=op, num_nodes=num_nodes)
    e = src.shape[0]
    pad = (-e) % BLOCK_E
    if pad:
        src = jnp.concatenate([src, jnp.zeros((pad,), src.dtype)])
        dst = jnp.concatenate([dst, jnp.full((pad,), num_nodes, dst.dtype)])
        w = jnp.concatenate([w, jnp.zeros((pad,), w.dtype)])
    return edge_relax_pallas(values, src, dst, w, op=op, num_nodes=num_nodes,
                             interpret=interpret)

"""Pallas TPU kernels for the framework's compute hot-spots.

Four kernels (each: <name>.py pl.pallas_call + BlockSpec, ops.py jit
wrapper, ref.py pure-jnp oracle, interpret-mode tests in tests/):

  edge_relax       the paper's hot loop — gather(val[src]) ⊕ w → segment
                   min/max by dst over dst-sorted edge blocks
  edge_relax_multi fused k-sweep relax — up to k frontier-masked sweeps in
                   one pallas_call, values/frontier VMEM-resident across
                   the grid, on-chip convergence early exit
  segment_reduce   GNN message aggregation (sum/min/max over edge messages)
  embedding_bag    fused multi-hot gather + bag reduction (recsys)

This container is CPU-only: kernels are written against the TPU model
(BlockSpec VMEM tiling, MXU-aligned last dims, sequential grid accumulation)
and validated with interpret=True, per the assignment.
"""

from repro.kernels.edge_relax.ops import edge_relax
from repro.kernels.edge_relax_multi.ops import relax_multi
from repro.kernels.segment_reduce.ops import segment_reduce
from repro.kernels.embedding_bag.ops import embedding_bag_fused

__all__ = ["edge_relax", "relax_multi", "segment_reduce",
           "embedding_bag_fused"]

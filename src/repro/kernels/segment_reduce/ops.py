"""jit'd public wrapper for the segment_reduce kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.segment_reduce.segment_reduce import (
    BLOCK_E,
    segment_reduce_pallas,
)
from repro.kernels.segment_reduce.ref import segment_reduce_ref


@functools.partial(jax.jit, static_argnames=("num_segments", "reduce",
                                             "use_pallas", "interpret"))
def segment_reduce(data, seg, *, num_segments: int, reduce: str = "sum",
                   use_pallas: bool = True, interpret: bool = True):
    if not use_pallas:
        return segment_reduce_ref(data, seg, num_segments=num_segments,
                                  reduce=reduce)
    e = data.shape[0]
    pad = (-e) % BLOCK_E
    if pad:
        data = jnp.pad(data, ((0, pad), (0, 0)))
        seg = jnp.concatenate([seg, jnp.full((pad,), num_segments, seg.dtype)])
    return segment_reduce_pallas(data, seg, num_segments=num_segments,
                                 reduce=reduce, interpret=interpret)

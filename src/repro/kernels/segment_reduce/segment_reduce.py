"""Pallas TPU kernel: 2-D segment reduction (GNN message aggregation).

    out[v, :] = reduce_{e : seg[e]==v} data[e, :]        reduce ∈ {sum, min, max}

Tiling: the edge-message stream [E, D] tiles through VMEM as
(BLOCK_E × D_pad) chunks; the [N+1, D_pad] accumulator is VMEM-resident
across the sequential grid (N = per-shard nodes after (data, model)
sharding). D pads to the 128-lane boundary so rows sit on full vregs.

This is the aggregation primitive under GCN/PNA/MeshGraphNet/GraphCast and
shares its layout contract (sentinel segment N for padding) with the
paper engine's edge blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels.pallas_compat import CompilerParams

BLOCK_E = 1024
LANE = 128

REDUCERS = {
    "sum": (lambda acc, d, vals: acc.at[d].add(vals), 0.0),
    "min": (lambda acc, d, vals: acc.at[d].min(vals), jnp.inf),
    "max": (lambda acc, d, vals: acc.at[d].max(vals), -jnp.inf),
}


def _kernel(data_ref, seg_ref, out_ref, *, reduce: str):
    scatter, ident = REDUCERS[reduce]
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, ident)

    vals = data_ref[...]            # [BLOCK_E, D_pad]
    seg = seg_ref[...]              # [BLOCK_E]
    out_ref[...] = scatter(out_ref[...], seg, vals)


def segment_reduce_pallas(data, seg, *, num_segments: int, reduce: str = "sum",
                          interpret: bool = True):
    """data [E, D] f32; seg [E] i32 (== num_segments for padding)."""
    e, d = data.shape
    # A real error, not an assert: `python -O` strips asserts, and a
    # misaligned message stream would silently drop the trailing block.
    if e % BLOCK_E != 0:
        raise ValueError(
            f"edge count {e} is not a multiple of the kernel block "
            f"BLOCK_E={BLOCK_E}; pad the message stream (sentinel segment == "
            f"num_segments) before calling segment_reduce_pallas")
    d_pad = (-d) % LANE
    if d_pad:
        data = jnp.pad(data, ((0, 0), (0, d_pad)))
    dp = d + d_pad
    grid = (e // BLOCK_E,)

    out = pl.pallas_call(
        functools.partial(_kernel, reduce=reduce),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_E, dp), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_E,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((num_segments + 1, dp), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_segments + 1, dp), data.dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
    )(data, seg)
    return out[:num_segments, :d]

"""Pure-jnp oracle for the segment_reduce kernel."""

from __future__ import annotations

import jax


def segment_reduce_ref(data, seg, *, num_segments: int, reduce: str = "sum"):
    fn = {"sum": jax.ops.segment_sum, "min": jax.ops.segment_min,
          "max": jax.ops.segment_max}[reduce]
    return fn(data, seg, num_segments + 1)[:num_segments]

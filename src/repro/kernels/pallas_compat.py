"""Version tolerance for the pallas TPU API shared by all kernels."""

from jax.experimental.pallas import tpu as pltpu

# jax<0.5 names it TPUCompilerParams; newer jax renamed it CompilerParams.
try:
    CompilerParams = pltpu.CompilerParams
except AttributeError:
    try:
        CompilerParams = pltpu.TPUCompilerParams
    except AttributeError as exc:  # pragma: no cover - future jax renames
        raise ImportError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
            "TPUCompilerParams; update repro.kernels.pallas_compat for this "
            "jax version") from exc

"""Always-on query service: many clients, one evolving graph.

The batch executors answer one window sequence at a time. This module is
the serving tier on top of them — a long-lived :class:`QueryService` that
accepts an open-loop stream of heterogeneous window queries (mixed
sources, semirings, window extents) from many registered clients and
answers them with the SAME batched machinery, plus the two layers a
multi-client setting needs:

* **Admission / batching (the packer).** Each scheduler turn collects at
  most one campaign's worth of pending windows per client, groups them by
  compatibility — identical launch options ``(semiring, max_iters, gated,
  cg_split, track_parents)`` AND the same pow2 slide-Δ width bucket, so
  packed lanes share one jit trace key — and runs each group as ONE
  ``_slide_launch``: every client's windows become lanes of a single
  masked pow2-lane ``incremental_additions_batched`` call
  (``lane_bucket`` padding is the packer; ``lane_map`` seeds each lane
  from its own query's anchor state). Grouping is a trace-sharing
  heuristic only — results never depend on which queries shared a launch,
  because each lane converges over exactly its window's common graph and
  the monotone rounded fixpoint is unique.

* **Round-robin interleaved scheduling (no starvation).** Clients are
  served in rotation: a turn walks the registry from a rotating pointer,
  draws ≤ ``campaign_width`` windows from each ready client
  (``WindowStream.take_next``), and stops adding clients once
  ``turn_budget`` lanes are reached — but ALWAYS serves at least the
  first ready client, so every turn makes progress and any ready client
  is served within ``len(clients)`` turns (the bounded-turn advancement
  property tests/test_service.py proves).

* **Shared anchor state.** Per query key the service keeps one
  :class:`AnchorChain`; every launch acquires its anchor states through
  the store's "AS" cache (hit / incremental hop / rebuild), records them
  as chain links, and reports per-client progress — so links any
  registered client may still hop from stay pinned against LRU eviction,
  and N overlapping clients with the same query do strictly fewer total
  rebuilds than solo runs, bit-identical values (the unique-fixpoint
  invariant the batch layers already enforce).

Synchronization discipline (graphlint G007): the admission → pack →
launch hot loop never syncs per query — the ONE host sync per packed
launch lives at the campaign boundary inside ``_slide_launch``
(core/window.py). Scheduling decisions are purely count-based
(never wall-clock-based), so launch composition, anchor events and all
BENCH_serve exact fields are machine-independent; wall-clock feeds only
the throughput/latency ratio metrics.

``launch/serve.py`` drives this service under a deterministic seeded
load generator; ``benchmarks/serve.py`` gates it in CI.
"""

from __future__ import annotations

import dataclasses
import itertools
import time

import jax.numpy as jnp

from repro.core.snapshots import SnapshotStore
from repro.core.trigrid import hop_added_edges
from repro.core.window import (
    CAMPAIGN_AUTO,
    AnchorChain,
    Window,
    WindowStream,
    _acquire_anchor_state,
    _slide_launch,
    _stream_qkey,
)
from repro.graph.semiring import Semiring

_CLIENT_COUNTER = itertools.count()


@dataclasses.dataclass
class ServiceClient:
    """One registered client: a named WindowStream plus its query options.

    Created by :meth:`QueryService.register` — not directly. The client
    owns the admitted-window buffer (``stream``), the completed results
    (``results``: window → converged values) and its admission→completion
    latencies; the service owns scheduling. ``horizon`` is the last
    snapshot index the client may ever query (defaults to the store's
    final snapshot): launch anchors widen to it, which keeps successive
    anchors nested so anchor maintenance stays incremental. A live
    ``feed`` (``ingest.LiveWindowFeed``) makes the horizon grow instead:
    each service turn polls the feed and admits windows the watermark has
    born, widening ``horizon`` to their newest snapshot — a horizon jump
    makes the previous anchor non-covering, so the next launch soundly
    rebuilds (or hops to) the wider anchor.
    """

    name: str
    semiring: Semiring
    source: int
    stream: WindowStream
    horizon: int
    max_iters: int = 10_000
    gated: bool = False
    cg_split: int = 1
    track_parents: bool = False
    # fused-chunk size for every launch serving this client (engine
    # fused_k). A LAUNCH option, not a query option: results are
    # bit-identical at any value, so it joins the admission okey (packed
    # lanes must share one jit trace) but NOT the anchor-state qkey
    # (states stay shareable across fused chunk sizes).
    fused_k: int = 1
    feed: "object | None" = None
    results: "dict[Window, jnp.ndarray]" = dataclasses.field(
        default_factory=dict)
    latencies_s: "list[float]" = dataclasses.field(default_factory=list)
    campaigns_done: int = 0
    _arrived: "dict[Window, float]" = dataclasses.field(default_factory=dict)

    @property
    def qkey(self) -> tuple:
        """The anchor-state cache key selecting this client's query.

        Clients with equal keys (same semiring, source and options) share
        anchor states and one :class:`AnchorChain` inside the service.
        """
        return _stream_qkey(self.semiring, self.source, self.max_iters,
                            self.gated, self.cg_split, self.track_parents)

    def pending(self) -> "list[Window]":
        """Windows admitted but not yet answered."""
        return self.stream.pending()


@dataclasses.dataclass
class LaunchRecord:
    """Accounting for one packed batched launch (the admission layer's
    output — what the batch-packing tests assert against).

    ``windows``/``clients`` are lane-parallel: lane ``k`` answered
    ``windows[k]`` for client ``clients[k]``. ``anchor_events`` holds one
    hit/hop/rebuild event per DISTINCT query key in the launch, in first-
    appearance order. ``lanes`` counts valid lanes; ``bucket`` is the pow2
    ``lane_bucket`` the launch actually shipped (``bucket - lanes`` lanes
    were masked padding).
    """

    group: tuple                 # admission compatibility key
    anchor: Window
    windows: "list[Window]"
    clients: "list[str]"         # client name per lane
    lanes: int
    bucket: int
    anchor_events: "list[str]"   # per distinct qkey: "hit"/"hop"/"rebuild"
    edge_work: float
    iterations: int


@dataclasses.dataclass
class ServiceMetrics:
    """Aggregate service counters plus derived throughput/latency.

    Count fields (admitted/completed/turns/launches/lanes/padded_lanes/
    anchor events/edge_work) are deterministic for a fixed load — they are
    BENCH_serve's exact gate fields. Wall-clock enters only through
    ``wall_s``/``latencies_s`` and the derived ratio metrics.
    """

    admitted: int = 0
    completed: int = 0
    turns: int = 0
    launches: int = 0
    lanes: int = 0
    padded_lanes: int = 0
    anchor_rebuilds: int = 0
    anchor_hops: int = 0
    anchor_hits: int = 0
    edge_work: float = 0.0
    # stability accounting over every packed launch's valid lanes:
    # seeded_vertex_lanes = Σ lanes·num_nodes, unstable_vertex_lanes =
    # Σ per-lane |instability seed set| (graph/stability.py)
    seeded_vertex_lanes: int = 0
    unstable_vertex_lanes: int = 0
    wall_s: float = 0.0
    latencies_s: "list[float]" = dataclasses.field(default_factory=list)

    @property
    def batch_occupancy(self) -> float:
        """Mean valid lanes per packed launch (> 1 ⇔ packing coalesced)."""
        return self.lanes / self.launches if self.launches else 0.0

    @property
    def stable_fraction_milli(self) -> int:
        """Measured stable fraction (‰) over all served window lanes.

        The share of vertex-lanes the stability analysis kept out of the
        seed frontier, aggregated service-wide — deterministic for a fixed
        load, so BENCH_serve gates it as an exact field. 0 before any
        launch.
        """
        if not self.seeded_vertex_lanes:
            return 0
        return round(1000 * (self.seeded_vertex_lanes
                             - self.unstable_vertex_lanes)
                     / self.seeded_vertex_lanes)

    @property
    def queries_per_sec(self) -> float:
        """Completed window queries per wall-clock second of turn time."""
        return self.completed / self.wall_s if self.wall_s > 0 else 0.0

    def latency_us(self, q: float) -> float:
        """Admission→completion latency percentile ``q`` in [0, 100], µs.

        Nearest-rank on the per-window latencies (``q=50``/``q=99`` are
        the serving bench's p50/p99); 0.0 before any completion.
        """
        if not self.latencies_s:
            return 0.0
        xs = sorted(self.latencies_s)
        rank = max(1, -(-int(q * len(xs)) // 100))  # ceil(q/100 * n), >= 1
        return xs[min(rank, len(xs)) - 1] * 1e6


def _width_bucket(edges: int) -> int:
    """Pow2 ceiling of a slide-Δ edge count (0 buckets as 1)."""
    b = 1
    while b < edges:
        b *= 2
    return b


class QueryService:
    """Long-lived multi-client query service over one evolving graph.

    Lifecycle: :meth:`register` clients (query + campaign width),
    :meth:`submit` windows as they arrive (open loop), call :meth:`turn`
    per scheduling tick — or :meth:`drain` to run turns until every
    admitted window is answered — then :meth:`unregister` finished
    clients so their anchor-chain pins release. Module docstring has the
    scheduling/packing/sharing contracts; ``launch/serve.py`` shows the
    driving idiom.

    ``lane_budget`` caps valid lanes per packed launch (compatible groups
    larger than it split, campaigns never split). ``turn_budget`` caps
    lanes drawn per scheduler turn (None = unbounded): smaller values
    trade batch occupancy for per-turn latency; at least one ready client
    is always served per turn regardless. ``seed`` picks the
    frontier-seeding mode every packed launch and anchor hop inherits
    (``"instability"`` — the stable-vertex analysis, default — or
    ``"delta"``, the full-Δ baseline; values bit-identical either way).
    """

    def __init__(self, store: SnapshotStore, *, lane_budget: int = 8,
                 turn_budget: "int | None" = None, mesh=None,
                 seed: str = "instability"):
        if lane_budget < 1:
            raise ValueError(f"lane_budget must be >= 1, got {lane_budget}")
        if turn_budget is not None and turn_budget < 1:
            raise ValueError(f"turn_budget must be >= 1, got {turn_budget}")
        self.store = store
        self.lane_budget = lane_budget
        self.turn_budget = turn_budget
        self.mesh = mesh
        self.seed = seed
        self.clients: "list[ServiceClient]" = []
        self.launch_log: "list[LaunchRecord]" = []
        self._metrics = ServiceMetrics()
        self._chains: "dict[tuple, AnchorChain]" = {}
        self._rr = 0   # rotation pointer: index of the next client to serve

    def register(self, semiring: Semiring, source: int, *,
                 campaign_width: int = 4, name: "str | None" = None,
                 horizon: "int | None" = None, max_iters: int = 10_000,
                 gated: bool = False, cg_split: int = 1,
                 track_parents: bool = False, fused_k: int = 1,
                 feed: "object | None" = None) -> ServiceClient:
        """Add a client; returns its :class:`ServiceClient` handle.

        ``campaign_width`` (int, ≤ ``lane_budget``) bounds the windows
        drawn from this client per turn — the service schedules
        count-based turns, so the Δ-volume ``"auto"`` planner is not
        accepted here (use ``run_window_stream_batched`` for planned
        solo streams). The client joins the :class:`AnchorChain` for its
        query key (created on first use), pinning shared anchor states
        until it advances past them or unregisters.

        ``fused_k`` sets the engine's fused-chunk size for every launch
        serving this client (values bit-identical at any size; clients
        only pack together when it matches — see :meth:`_pack`).
        ``feed`` attaches a live window source (``ingest.LiveWindowFeed``):
        instead of :meth:`submit` calls, every turn polls the feed and
        admits windows born by watermark cuts (``horizon`` then grows with
        the cuts; see :class:`ServiceClient`). The feed's compaction floor
        is advanced as this client's windows complete and withdrawn at
        :meth:`unregister`.
        """
        if campaign_width == CAMPAIGN_AUTO:
            raise ValueError(
                'campaign_width="auto" is the solo planner\'s mode '
                "(run_window_stream_batched); the service schedules "
                "count-based turns — pass an int campaign width")
        if not isinstance(campaign_width, int) or campaign_width < 1:
            raise ValueError(
                f"campaign_width must be an int >= 1, got {campaign_width!r}")
        if campaign_width > self.lane_budget:
            raise ValueError(
                f"campaign_width {campaign_width} exceeds the service "
                f"lane_budget {self.lane_budget}: one campaign must fit "
                "in one launch")
        if name is None:
            name = f"client-{next(_CLIENT_COUNTER)}"
        if any(c.name == name for c in self.clients):
            raise ValueError(f"client name {name!r} is already registered")
        if horizon is None:
            horizon = self.store.seq.num_snapshots - 1
        client = ServiceClient(
            name=name, semiring=semiring, source=source,
            stream=WindowStream(campaign_width, name=name), horizon=horizon,
            max_iters=max_iters, gated=gated, cg_split=cg_split,
            track_parents=track_parents, fused_k=fused_k, feed=feed)
        chain = self._chains.setdefault(
            client.qkey,
            AnchorChain(self.store, name=f"svc-chain-{len(self._chains)}"))
        chain.bind(client.qkey).register(client.stream)
        self.clients.append(client)
        return client

    def submit(self, client: ServiceClient, windows: "list[Window]") -> int:
        """Admit newly arrived windows for ``client``; returns the count.

        Windows must keep the client's sequence advancing (both endpoints
        nondecreasing — ``WindowStream.extend`` enforces it) and must end
        at or before the client's declared ``horizon`` (anchors only ever
        widen to the horizon, so a later window could not be covered).
        """
        windows = [tuple(w) for w in windows]
        for wnd in windows:
            if wnd[1] > client.horizon:
                raise ValueError(
                    f"window {wnd} ends past client {client.name!r}'s "
                    f"horizon {client.horizon}")
        client.stream.extend(windows)
        now = time.perf_counter()
        for wnd in windows:
            client._arrived[wnd] = now
        self._metrics.admitted += len(windows)
        return len(windows)

    def unregister(self, client: ServiceClient) -> None:
        """Withdraw a drained client; its anchor-chain pins release.

        Raises if the client still has pending windows — :meth:`drain`
        (or enough :meth:`turn` calls) first, so admitted queries are
        never silently dropped.
        """
        if client.pending():
            raise ValueError(
                f"client {client.name!r} still has {len(client.pending())} "
                "pending windows — drain before unregistering")
        self._chains[client.qkey].unregister(client.stream)
        if client.feed is not None:
            client.feed.close()  # withdraw the compaction floor
        self.clients.remove(client)
        if self.clients:
            self._rr %= len(self.clients)
        else:
            self._rr = 0

    def pending(self) -> int:
        """Total windows admitted but not yet answered, across clients."""
        return sum(len(c.stream.pending()) for c in self.clients)

    def turn(self) -> "list[LaunchRecord]":
        """One scheduler turn: select → pack → launch.

        Serves ready clients in rotation from the round-robin pointer,
        drawing at most one campaign each, up to ``turn_budget`` lanes
        (always at least the first ready client); packs the draws into
        compatibility groups and runs each group as one batched launch.
        Returns this turn's :class:`LaunchRecord`\\ s (empty when no
        client had pending work — an idle turn is a no-op and is not
        counted). Clients with a live ``feed`` are polled first, so
        windows born since the last turn are admitted before selection.
        """
        self._poll_feeds()
        t0 = time.perf_counter()
        selected = self._select()
        if not selected:
            return []
        records = [self._packed_launch(group, chunk)
                   for group, chunk in self._pack(selected)]
        self._metrics.turns += 1
        self._metrics.wall_s += time.perf_counter() - t0
        self._report_feeds()
        return records

    def drain(self, max_turns: int = 10_000) -> ServiceMetrics:
        """Run turns until no admitted window is unanswered; returns metrics.

        Raises ``RuntimeError`` if the backlog outlives ``max_turns``
        turns — with the per-turn progress guarantee that can only mean a
        bug, so it fails loudly instead of spinning.
        """
        turns = 0
        self._poll_feeds()  # admit already-born live windows up front
        while self.pending():
            self.turn()
            turns += 1
            if turns > max_turns:
                raise RuntimeError(
                    f"service failed to drain within {max_turns} turns")
        return self.metrics()

    def metrics(self) -> ServiceMetrics:
        """The service's live :class:`ServiceMetrics` accumulator."""
        return self._metrics

    # -- scheduling internals -------------------------------------------------

    def _poll_feeds(self) -> int:
        """Admit windows born from live feeds since the last poll.

        For each feed-backed client: poll the feed, widen the client's
        ``horizon`` to the newest born snapshot (anchors widen with it —
        the previous anchor stops covering, so the next launch soundly
        re-anchors), and route the windows through :meth:`submit` so the
        admitted/latency bookkeeping is identical to open-loop clients.
        Count-based and sync-free, like all scheduling here (G007).
        """
        admitted = 0
        for client in self.clients:
            if client.feed is None:
                continue
            born = client.feed.poll()
            if born:
                client.horizon = max(client.horizon,
                                     max(w[1] for w in born))
                admitted += self.submit(client, born)
        return admitted

    def _report_feeds(self) -> None:
        """Advance live feeds' compaction floors to consumption progress:
        the oldest snapshot a client still needs is its first unconsumed
        window's lo (``None`` = fully drained)."""
        for client in self.clients:
            if client.feed is None:
                continue
            rest = client.stream.pending()
            client.feed.advance_floor(rest[0][0] if rest else None)

    def _select(self) -> "list[tuple[ServiceClient, list[Window]]]":
        """Round-robin draw: ≤ one campaign per ready client, ≤ turn_budget
        lanes per turn, always ≥ 1 ready client served."""
        n = len(self.clients)
        start = self._rr
        picked: "list[tuple[ServiceClient, list[Window]]]" = []
        lanes = 0
        for k in range(n):
            idx = (start + k) % n
            client = self.clients[idx]
            pend = client.stream.pending()
            if not pend:
                continue
            width = min(client.stream.campaign_width, len(pend))
            if picked and self.turn_budget is not None \
                    and lanes + width > self.turn_budget:
                # budget reached: the cut client leads the next turn
                self._rr = idx
                return picked
            picked.append((client, client.stream.take_next(width)))
            lanes += width
            self._rr = (idx + 1) % n
        return picked

    def _pack(self, selected):
        """Group compatible campaigns into launches (the admission layer).

        Compatibility = identical launch options (every static jit
        argument: semiring, max_iters, gated, cg_split, track_parents,
        fused_k) AND equal pow2 width bucket of the campaign's largest
        slide-Δ
        (priced by ``hop_added_edges`` against the group's provisional
        shared anchor) — so packed lanes stack into one shape-bucketed
        trace. Groups chunk at ``lane_budget`` lanes; campaigns never
        split across launches. Deterministic: group order is sorted,
        member order follows the rotation draw.
        """
        by_options: dict = {}
        for client, campaign in selected:
            okey = (client.semiring.name, client.max_iters, client.gated,
                    client.cg_split, client.track_parents, client.fused_k)
            by_options.setdefault(okey, []).append((client, campaign))
        launches = []
        for okey in sorted(by_options):
            entries = by_options[okey]
            coarse = (min(w[0] for _, c in entries for w in c),
                      max(cl.horizon for cl, _ in entries))
            by_bucket: dict = {}
            for client, campaign in entries:
                widest = max(hop_added_edges(self.store, coarse, w)
                             for w in campaign)
                by_bucket.setdefault(_width_bucket(widest), []).append(
                    (client, campaign))
            for bkey in sorted(by_bucket):
                group_key = (okey[0], bkey)
                chunk: list = []
                lanes = 0
                for client, campaign in by_bucket[bkey]:
                    if chunk and lanes + len(campaign) > self.lane_budget:
                        launches.append((group_key, chunk))
                        chunk, lanes = [], 0
                    chunk.append((client, campaign))
                    lanes += len(campaign)
                if chunk:
                    launches.append((group_key, chunk))
        return launches

    def _packed_launch(self, group: tuple, chunk) -> LaunchRecord:
        """Run one compatibility group as ONE batched launch.

        Acquires anchor state per distinct query key (hit/hop/rebuild via
        the "AS" cache), records chain links + progress, maps each lane to
        its query's state (``lane_map``), and scatters results/latencies
        back to the owning clients. The campaign boundary: the single
        host sync per launch happens inside ``_slide_launch``.
        """
        anchor = (min(w[0] for _, campaign in chunk for w in campaign),
                  max(client.horizon for client, _ in chunk))
        states: list = []
        state_idx: "dict[tuple, int]" = {}
        events: "list[str]" = []
        anchor_view = None
        for client, _ in chunk:
            qkey = client.qkey
            if qkey in state_idx:
                continue
            view, state, stats, event, _delta = _acquire_anchor_state(
                self.store, qkey, anchor, client.semiring, client.source,
                client.max_iters, client.gated, client.cg_split,
                client.track_parents, seed=self.seed,
                fused_k=client.fused_k)
            self._chains[qkey].observe(anchor)  # pin before later puts evict
            state_idx[qkey] = len(states)
            states.append(state)
            events.append(event)
            if anchor_view is None:
                anchor_view = view
            self._metrics.edge_work += stats.edge_work
            if event == "rebuild":
                self._metrics.anchor_rebuilds += 1
            elif event == "hop":
                self._metrics.anchor_hops += 1
            else:
                self._metrics.anchor_hits += 1
        windows: "list[Window]" = []
        owners: "list[ServiceClient]" = []
        lane_map: "list[int]" = []
        for client, campaign in chunk:
            for wnd in campaign:
                windows.append(wnd)
                owners.append(client)
                lane_map.append(state_idx[client.qkey])
        lead = chunk[0][0]
        res, bucket = _slide_launch(
            self.store, lead.semiring, anchor_view, states, windows, anchor,
            max_iters=lead.max_iters, gated=lead.gated,
            track_parents=lead.track_parents, mesh=self.mesh,
            lane_map=lane_map, seed=self.seed, fused_k=lead.fused_k)
        done = time.perf_counter()
        for lane, (wnd, client) in enumerate(zip(windows, owners)):
            client.results[wnd] = res.values[lane]
            latency = done - client._arrived.pop(wnd, done)
            client.latencies_s.append(latency)
            self._metrics.latencies_s.append(latency)
        for client, campaign in chunk:
            client.campaigns_done += 1
            self._chains[client.qkey].advance(client.stream, anchor)
        work = float(jnp.sum(res.edge_work))
        self._metrics.launches += 1
        self._metrics.lanes += len(windows)
        self._metrics.padded_lanes += bucket - len(windows)
        self._metrics.completed += len(windows)
        self._metrics.edge_work += work
        self._metrics.seeded_vertex_lanes += len(windows) * self.store.num_nodes
        self._metrics.unstable_vertex_lanes += int(
            jnp.sum(res.unstable[:len(windows)]))
        record = LaunchRecord(
            group=group, anchor=anchor, windows=windows,
            clients=[c.name for c in owners], lanes=len(windows),
            bucket=bucket, anchor_events=events, edge_work=work,
            iterations=int(jnp.max(res.iterations)))
        self.launch_log.append(record)
        return record

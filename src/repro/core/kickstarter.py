"""KickStarter streaming baseline (Vora et al., ASPLOS'17) — deletions included.

This is the baseline the paper compares against, implemented faithfully in
TPU-idiomatic form (DESIGN.md §2, §7.3): snapshots are processed *in
sequence*; each transition applies a batch of deletions (expensive: trimmed
approximations) and additions (cheap: monotone re-convergence).

Deletion trimming:
  1. *seed*: any vertex whose dependence-parent edge was deleted is tainted
     — an O(|del|) gather/scatter, no key packing (int32-safe).
  2. *propagate*: taint flows down the dependence forest (``parent``), done
     with pointer doubling in ⌈log₂N⌉ dense rounds instead of KickStarter's
     pointer-chasing worklists.
  3. *reset*: tainted vertices fall back to the identity (trimmed
     approximation — still a sound over-approximation for monotone queries).
  4. *re-converge*: a full frontier-masked fixpoint re-supplies trimmed
     vertices from untainted neighbors and applies the addition batch.

The cost asymmetry the paper measures (deletions ≈ 3× additions) emerges
naturally: steps 2–4 touch the whole dependence region, while additions only
touch the improved cone.
"""

from __future__ import annotations

import dataclasses
import functools
import math
import time

import jax
import jax.numpy as jnp

from repro.core.snapshots import SnapshotStore
from repro.graph.edgeset import EdgeBlock, keys_to_edges, make_block, pad_edges
from repro.graph.engine import (
    NO_PARENT,
    FixpointResult,
    _fixpoint_jit,
    host_sync,
    run_to_fixpoint,
)
from repro.graph.semiring import Semiring
from repro.graph.stability import seed_state


def _ceil_log2(n: int) -> int:
    return max(1, int(math.ceil(math.log2(max(n, 2)))))


@functools.partial(jax.jit, static_argnums=(0, 1, 2))
def _trim_and_reconverge(semiring: Semiring, num_nodes: int, max_iters: int,
                         values, parent, del_src, del_dst,
                         add_block: EdgeBlock, next_blocks):
    """One KickStarter transition: delete-trim, add-seed, re-converge."""
    # 1. seed: tainted where the parent edge (parent[v] -> v) was deleted.
    p_pad = jnp.concatenate([parent, jnp.int32([-2])])
    hit = p_pad[del_dst] == del_src  # padded del entries: dst==num_nodes -> sentinel row
    seed = jnp.zeros((num_nodes + 1,), bool).at[del_dst].max(hit)[:num_nodes]

    # 2. propagate taint down the dependence forest (pointer doubling).
    def double(_, carry):
        t, p = carry
        safe = jnp.maximum(p, 0)
        t = t | (t[safe] & (p >= 0))
        p = jnp.where(p >= 0, p[safe], NO_PARENT)
        return t, p

    tainted, _ = jax.lax.fori_loop(0, _ceil_log2(num_nodes) + 1, double,
                                   (seed, parent))

    # 3. reset trimmed approximation.
    ident = jnp.float32(semiring.identity)
    values = jnp.where(tainted, ident, values)
    parent = jnp.where(tainted, NO_PARENT, parent)

    # 4. seed additions, then re-converge over the next snapshot's edges.
    # mode="delta" (full-Δ seeding): this is the published baseline the
    # paper compares against, so it must NOT inherit the stable-vertex
    # pruning — its measured cost stays that of real KickStarter.
    seeded = seed_state(semiring, num_nodes, values, parent, (add_block,),
                        mode="delta")
    frontier = seeded.frontier | ~tainted
    res = _fixpoint_jit(semiring, num_nodes, max_iters, seeded.values,
                        seeded.parent, frontier, next_blocks)
    return FixpointResult(res.values, res.parent, res.iterations + 1,
                          res.edge_work + seeded.seed_work), jnp.sum(tainted)


@dataclasses.dataclass
class StreamStats:
    wall_s: float
    edge_work: float
    sweeps: int
    tainted: int = 0
    mutate_s: float = 0.0


def run_kickstarter_stream(
    store: SnapshotStore,
    semiring: Semiring,
    source: int,
    max_iters: int = 10_000,
    include_mutation: bool = True,
) -> tuple[list[jnp.ndarray], list[StreamStats]]:
    """The full baseline: S_0 from scratch, then stream batches in sequence.

    Returns per-snapshot query results and per-step stats. Graph
    "mutation" (materializing each next snapshot's edge arrays — the cost
    CommonGraph's shared representation avoids) is charged to the baseline
    when ``include_mutation`` (it is what real KickStarter must do).
    """
    n = store.num_nodes
    seq = store.seq
    results: list[jnp.ndarray] = []
    stats: list[StreamStats] = []

    t0 = time.perf_counter()
    view0 = store.snapshot_view(0)
    res = run_to_fixpoint(view0, semiring, source, max_iters)
    host_sync(res.values)
    stats.append(StreamStats(time.perf_counter() - t0, float(res.edge_work),
                             int(res.iterations)))
    results.append(res.values)

    values, parent = res.values, res.parent
    for t in range(seq.num_snapshots - 1):
        t0 = time.perf_counter()
        # --- mutation: KickStarter materializes S_{t+1}'s edge structure.
        if include_mutation:
            keys_next = seq.snapshot_keys[t + 1]
            s, d = keys_to_edges(keys_next, n)
            w = seq.weights_for(keys_next)
            next_block = make_block(s, d, w, n, granule=store.granule,
                                    pad_pow2=store.pad_pow2)
        else:
            next_block = store.window_block(t + 1, t + 1)
        t_mut = time.perf_counter() - t0

        add_block = store.addition_block(t)
        dk = store.deletion_keys(t)
        ds, dd = keys_to_edges(dk, n)
        # Bucket-pad deletions exactly like edge blocks (honoring pad_pow2),
        # so varying deletion-batch sizes can't drive unbounded jit traces.
        ds, dd, _ = pad_edges(ds, dd, None, n, granule=store.granule,
                              pad_pow2=store.pad_pow2)

        res, tainted = _trim_and_reconverge(
            semiring, n, max_iters, values, parent,
            jnp.asarray(ds), jnp.asarray(dd), add_block, (next_block,))
        host_sync(res.values)
        wall = time.perf_counter() - t0
        values, parent = res.values, res.parent
        results.append(values)
        stats.append(StreamStats(wall, float(res.edge_work), int(res.iterations),
                                 int(tainted), t_mut))
    return results, stats

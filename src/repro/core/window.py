"""Sliding-window executors: many window queries as one batched launch.

The dominant query pattern over an evolving sequence is not "every
snapshot" but a *window* that slides: answer the query on ``[i, i+w)``,
then on ``[i+1, i+w+1)``, and so on (delta-based historical queries à la
Koloniari et al.; the streaming-system surveys make the same point). The
naive slide re-runs the query per window. CommonGraph makes every window
an *addition-only* hop from a shared anchor:

* Sliding is NOT deletion-free between consecutive windows — ``T(i,j) ⊄
  T(i+1,j+1)`` in general. The sound warm start is any common
  SUPER-window's apex: for windows spanning ``[lo..hi]`` the tightest is
  ``T(lo, hi)`` (every window's common graph contains it), which
  ``window_anchor`` picks by default.
* With one anchor fixpoint in hand, each window apex is reached by
  streaming ``slide_block(window, anchor)`` — pure additions. The hops are
  mutually independent, so the batched executor stacks them as lanes of a
  single ``incremental_additions_batched`` launch
  (``SnapshotStore.slide_stack``), exactly the level-batching machinery of
  ``core/trigrid.py`` with windows instead of plan levels.

Executor contract (same as core/trigrid.py, enforced by
tests/test_window.py):

* **Bit-identical results.** ``run_window_slide_batched`` returns values
  (and parents, when tracked) bit-identical to the sequential
  ``run_window_slide`` for the same windows/anchor/options: every lane
  converges over exactly the edge set the sequential hop uses (anchor
  blocks + that window's slide Δ), and the monotone fixpoint is
  order-free. Both match a from-scratch fixpoint on each window's common
  graph up to float tolerance.
* **Shape-bucketing invariant.** The stacked slide Δ has shape
  ``(pow2 lane bucket, pow2 width bucket)``: the window-lane axis pads to
  ``lane_bucket(num_windows, data_extent)`` with trailing masked lanes
  (all-sentinel Δ, anchor-state copy, ``lane_valid=False``, zero
  work/iterations), so jit traces are keyed on buckets alone and any
  window count shards over a ``data`` mesh — the replicated fallback (and
  its UserWarning) no longer exists.
* **Degenerate cases.** A single window equal to the anchor is legal: its
  Δ is empty, the seed sweep finds no improvements, and the anchor state
  is returned unchanged. Likewise ``width == num_snapshots`` yields one
  window (the global CG query itself).
* **Work accounting.** Padding never counts toward ``edge_work``; batched
  and sequential slides report equal per-window totals.
"""

from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp

from repro.core.kickstarter import StreamStats
from repro.core.snapshots import SnapshotStore
from repro.core.trigrid import _anchor_base, _shard_snapshot_axis
from repro.graph.edgeset import lane_bucket
from repro.graph.engine import (
    gather_lane_states,
    incremental_additions,
    incremental_additions_batched,
)
from repro.graph.semiring import Semiring

Window = tuple[int, int]


def slide_windows(num_snapshots: int, width: int, step: int = 1,
                  start: int = 0) -> list[Window]:
    """Window plan construction: all width-``width`` windows sliding by ``step``.

    Windows are inclusive snapshot-index pairs ``(i, i + width - 1)``; the
    last one ends at the final snapshot. Degenerate cases are explicit: a
    width covering the whole (remaining) sequence yields exactly one
    window.
    """
    if not 1 <= width <= num_snapshots - start:
        raise ValueError(
            f"window width {width} not in [1, {num_snapshots - start}] "
            f"(num_snapshots={num_snapshots}, start={start})")
    if step < 1:
        raise ValueError(f"step must be >= 1, got {step}")
    return [(i, i + width - 1)
            for i in range(start, num_snapshots - width + 1, step)]


def window_anchor(windows: list[Window]) -> Window:
    """Tightest common super-window: the span of all windows.

    Every window's common graph contains the span's (nested windows ⇒
    nested CGs), so the span apex warm-starts every slide hop with the
    largest possible shared state — strictly less Δ volume than anchoring
    at the global CG when the windows don't cover the whole sequence.
    """
    if not windows:
        raise ValueError("need at least one window")
    return min(i for i, _ in windows), max(j for _, j in windows)


@dataclasses.dataclass
class WindowSlideRun:
    results: dict[Window, jnp.ndarray]  # window -> values
    anchor: Window
    base_stats: StreamStats             # the shared anchor fixpoint
    hop_stats: list[StreamStats]        # per-window (seq) or 1 launch (batched)
    wall_s: float
    added_edges: int                    # total slide-Δ volume streamed
    # (valid lanes, lane_bucket) of the batched launch; empty when sequential
    lane_layout: "list[tuple[int, int]]" = dataclasses.field(
        default_factory=list)


def _slide_added_edges(store: SnapshotStore, windows: list[Window],
                       anchor: Window) -> int:
    a = store.window_size(*anchor)
    return sum(store.window_size(*w) - a for w in windows)


def _resolve(store: SnapshotStore, width: int | None, windows, step, start,
             anchor):
    if windows is None:
        if width is None:
            raise ValueError("pass either width= or windows=")
        windows = slide_windows(store.seq.num_snapshots, width, step=step,
                                start=start)
    windows = [tuple(w) for w in windows]
    if anchor is None:
        anchor = window_anchor(windows)
    return windows, tuple(anchor)


def run_window_slide(
    store: SnapshotStore,
    semiring: Semiring,
    source: int,
    width: int | None = None,
    *,
    windows: "list[Window] | None" = None,
    step: int = 1,
    start: int = 0,
    anchor: Window | None = None,
    max_iters: int = 10_000,
    gated: bool = False,
    cg_split: int = 1,
    track_parents: bool = False,
) -> WindowSlideRun:
    """Sequential window slide: one anchor fixpoint, then per-window hops.

    The baseline the batched executor is measured (and bit-compared)
    against: each window re-executes ``incremental_additions`` from the
    anchor state with that window's slide Δ.
    """
    t_all = time.perf_counter()
    windows, anchor = _resolve(store, width, windows, step, start, anchor)
    anchor_view, base, base_stats = _anchor_base(
        store, anchor, semiring, source, max_iters, gated, cg_split,
        track_parents)

    results: dict[Window, jnp.ndarray] = {}
    hop_stats: list[StreamStats] = []
    for wnd in windows:
        t0 = time.perf_counter()
        delta = store.slide_block(wnd, anchor)
        view = anchor_view.extended(delta)       # shared immutable blocks
        res = incremental_additions(view, delta, semiring, base.values,
                                    base.parent, max_iters, gated=gated,
                                    track_parents=track_parents)
        res.values.block_until_ready()
        hop_stats.append(StreamStats(time.perf_counter() - t0,
                                     float(res.edge_work),
                                     int(res.iterations)))
        results[wnd] = res.values
    return WindowSlideRun(results, anchor, base_stats, hop_stats,
                          time.perf_counter() - t_all,
                          _slide_added_edges(store, windows, anchor))


def run_window_slide_batched(
    store: SnapshotStore,
    semiring: Semiring,
    source: int,
    width: int | None = None,
    *,
    windows: "list[Window] | None" = None,
    step: int = 1,
    start: int = 0,
    anchor: Window | None = None,
    max_iters: int = 10_000,
    gated: bool = False,
    cg_split: int = 1,
    track_parents: bool = False,
    mesh=None,
) -> WindowSlideRun:
    """Batched window slide: every slide hop as a lane of ONE stacked launch.

    The anchor state broadcasts to all window lanes
    (``gather_lane_states`` with an all-zeros lane map), the per-window
    slide Δs stack shape-bucketed (``SnapshotStore.slide_stack``, lane axis
    padded to ``lane_bucket(num_windows, data_extent)`` with masked inert
    lanes), and one ``incremental_additions_batched`` call re-converges
    every window. On a mesh the bucketed window-lane axis ALWAYS shards
    over ``data`` exactly like the TG executor's snapshot axis
    (``launch/evolve.py --shard --window-batch``).
    """
    t_all = time.perf_counter()
    windows, anchor = _resolve(store, width, windows, step, start, anchor)
    anchor_view, base, base_stats = _anchor_base(
        store, anchor, semiring, source, max_iters, gated, cg_split,
        track_parents)

    t0 = time.perf_counter()
    data_extent = mesh.shape["data"] if mesh is not None else 1
    bucket = lane_bucket(len(windows), data_extent)
    stacked = store.slide_stack(windows, anchor, num_lanes=bucket)
    # The anchor state broadcasts to every lane, masked padding lanes
    # included: their Δ is all-sentinel, so they stay inert copies and
    # lane_valid zeroes them out of the work accounting.
    values, parent = gather_lane_states(base.values[None], base.parent[None],
                                        [0] * bucket)
    lane_valid = jnp.arange(bucket) < len(windows)
    delta_blocks = (stacked,)
    values, parent, delta_blocks, lane_valid = _shard_snapshot_axis(
        mesh, values, parent, delta_blocks, lane_valid)
    res = incremental_additions_batched(
        store.num_nodes, semiring, values, parent,
        shared_blocks=tuple(anchor_view.blocks), delta_blocks=delta_blocks,
        max_iters=max_iters, track_parents=track_parents, gated=gated,
        seed_blocks=(delta_blocks[-1],), lane_valid=lane_valid)
    res.values.block_until_ready()
    hop_stats = [StreamStats(time.perf_counter() - t0,
                             float(jnp.sum(res.edge_work)),
                             int(jnp.max(res.iterations)))]
    results = {wnd: res.values[lane] for lane, wnd in enumerate(windows)}
    return WindowSlideRun(results, anchor, base_stats, hop_stats,
                          time.perf_counter() - t_all,
                          _slide_added_edges(store, windows, anchor),
                          [(len(windows), bucket)])

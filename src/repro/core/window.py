"""Sliding-window executors: many window queries as one batched launch.

The dominant query pattern over an evolving sequence is not "every
snapshot" but a *window* that slides: answer the query on ``[i, i+w)``,
then on ``[i+1, i+w+1)``, and so on (delta-based historical queries à la
Koloniari et al.; the streaming-system surveys make the same point). The
naive slide re-runs the query per window. CommonGraph makes every window
an *addition-only* hop from a shared anchor:

* Sliding is NOT deletion-free between consecutive windows — ``T(i,j) ⊄
  T(i+1,j+1)`` in general. The sound warm start is any common
  SUPER-window's apex: for windows spanning ``[lo..hi]`` the tightest is
  ``T(lo, hi)`` (every window's common graph contains it), which
  ``window_anchor`` picks by default.
* With one anchor fixpoint in hand, each window apex is reached by
  streaming ``slide_block(window, anchor)`` — pure additions. The hops are
  mutually independent, so the batched executor stacks them as lanes of a
  single ``incremental_additions_batched`` launch
  (``SnapshotStore.slide_stack``), exactly the level-batching machinery of
  ``core/trigrid.py`` with windows instead of plan levels.

Executor contract (same as core/trigrid.py, enforced by
tests/test_window.py):

* **Bit-identical results.** ``run_window_slide_batched`` returns values
  (and parents, when tracked) bit-identical to the sequential
  ``run_window_slide`` for the same windows/anchor/options: every lane
  converges over exactly the edge set the sequential hop uses (anchor
  blocks + that window's slide Δ), and the monotone fixpoint is
  order-free. Both match a from-scratch fixpoint on each window's common
  graph up to float tolerance.
* **Shape-bucketing invariant.** The stacked slide Δ has shape
  ``(pow2 lane bucket, pow2 width bucket)``: the window-lane axis pads to
  ``lane_bucket(num_windows, data_extent)`` with trailing masked lanes
  (all-sentinel Δ, anchor-state copy, ``lane_valid=False``, zero
  work/iterations), so jit traces are keyed on buckets alone and any
  window count shards over a ``data`` mesh — the replicated fallback (and
  its UserWarning) no longer exists.
* **Degenerate cases.** A single window equal to the anchor is legal: its
  Δ is empty, the seed sweep finds no improvements, and the anchor state
  is returned unchanged. Likewise ``width == num_snapshots`` yields one
  window (the global CG query itself).
* **Work accounting.** Padding never counts toward ``edge_work``; batched
  and sequential slides report equal per-window totals.

Streaming campaigns (``WindowStream`` / ``run_window_stream_batched``) layer
cross-launch anchor reuse on top: an advancing window sequence is cut into
campaigns of ``campaign_width`` windows, each campaign runs as one batched
slide launch anchored at ``(campaign_lo, stream_hi)``, and the anchor STATE
is maintained incrementally — campaign k+1's anchor window is nested in
campaign k's (its common graph is a pure-addition extension), so k's
converged state seeds an ``incremental_additions`` hop instead of a
from-scratch rebuild. States live in ``SnapshotStore``'s LRU-cached "AS"
family, so back-to-back campaigns (and repeat stream calls) hit memory, not
recompute; eviction mid-stream costs exactly one rebuild and never changes
results. The stream contract, enforced by tests/test_window_stream.py:

* **Bit-identical to cold campaigns.** ``run_window_stream_batched`` window
  values equal ``run_window_slide_batched`` run cold per campaign (same
  windows, same anchor) bit-for-bit — the monotone rounded fixpoint of a
  window's common graph is unique, so how the anchor state was reached
  (from-scratch vs incremental hops) is unobservable in values.
* **Strictly fewer rebuilds.** A K-campaign stream performs 1 anchor
  rebuild + K−1 incremental anchor hops (plus one rebuild per mid-stream
  eviction) vs the cold path's K rebuilds.
"""

from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp

from repro.core.kickstarter import StreamStats
from repro.core.snapshots import SnapshotStore
from repro.core.trigrid import _anchor_base, _anchor_view, _shard_snapshot_axis
from repro.graph.edgeset import lane_bucket
from repro.graph.engine import (
    QueryState,
    extract_state,
    gather_lane_states,
    incremental_additions,
    incremental_additions_batched,
)
from repro.graph.semiring import Semiring

Window = tuple[int, int]


def slide_windows(num_snapshots: int, width: int, step: int = 1,
                  start: int = 0) -> list[Window]:
    """Window plan construction: all width-``width`` windows sliding by ``step``.

    Windows are inclusive snapshot-index pairs ``(i, i + width - 1)``; the
    last one ends at the final snapshot. Degenerate cases are explicit: a
    width covering the whole (remaining) sequence yields exactly one
    window.
    """
    if not 1 <= width <= num_snapshots - start:
        raise ValueError(
            f"window width {width} not in [1, {num_snapshots - start}] "
            f"(num_snapshots={num_snapshots}, start={start})")
    if step < 1:
        raise ValueError(f"step must be >= 1, got {step}")
    return [(i, i + width - 1)
            for i in range(start, num_snapshots - width + 1, step)]


def window_anchor(windows: list[Window]) -> Window:
    """Tightest common super-window: the span of all windows.

    Every window's common graph contains the span's (nested windows ⇒
    nested CGs), so the span apex warm-starts every slide hop with the
    largest possible shared state — strictly less Δ volume than anchoring
    at the global CG when the windows don't cover the whole sequence.
    """
    if not windows:
        raise ValueError("need at least one window")
    return min(i for i, _ in windows), max(j for _, j in windows)


@dataclasses.dataclass
class WindowSlideRun:
    results: dict[Window, jnp.ndarray]  # window -> values
    anchor: Window
    base_stats: StreamStats             # the shared anchor fixpoint
    hop_stats: list[StreamStats]        # per-window (seq) or 1 launch (batched)
    wall_s: float
    added_edges: int                    # total slide-Δ volume streamed
    # (valid lanes, lane_bucket) of the batched launch; empty when sequential
    lane_layout: "list[tuple[int, int]]" = dataclasses.field(
        default_factory=list)


def _slide_added_edges(store: SnapshotStore, windows: list[Window],
                       anchor: Window) -> int:
    a = store.window_size(*anchor)
    return sum(store.window_size(*w) - a for w in windows)


def _resolve(store: SnapshotStore, width: int | None, windows, step, start,
             anchor):
    if windows is None:
        if width is None:
            raise ValueError("pass either width= or windows=")
        windows = slide_windows(store.seq.num_snapshots, width, step=step,
                                start=start)
    windows = [tuple(w) for w in windows]
    if anchor is None:
        anchor = window_anchor(windows)
    return windows, tuple(anchor)


def run_window_slide(
    store: SnapshotStore,
    semiring: Semiring,
    source: int,
    width: int | None = None,
    *,
    windows: "list[Window] | None" = None,
    step: int = 1,
    start: int = 0,
    anchor: Window | None = None,
    max_iters: int = 10_000,
    gated: bool = False,
    cg_split: int = 1,
    track_parents: bool = False,
) -> WindowSlideRun:
    """Sequential window slide: one anchor fixpoint, then per-window hops.

    The baseline the batched executor is measured (and bit-compared)
    against: each window re-executes ``incremental_additions`` from the
    anchor state with that window's slide Δ.
    """
    t_all = time.perf_counter()
    windows, anchor = _resolve(store, width, windows, step, start, anchor)
    anchor_view, base, base_stats = _anchor_base(
        store, anchor, semiring, source, max_iters, gated, cg_split,
        track_parents)

    results: dict[Window, jnp.ndarray] = {}
    hop_stats: list[StreamStats] = []
    for wnd in windows:
        t0 = time.perf_counter()
        delta = store.slide_block(wnd, anchor)
        view = anchor_view.extended(delta)       # shared immutable blocks
        res = incremental_additions(view, delta, semiring, base.values,
                                    base.parent, max_iters, gated=gated,
                                    track_parents=track_parents)
        res.values.block_until_ready()
        hop_stats.append(StreamStats(time.perf_counter() - t0,
                                     float(res.edge_work),
                                     int(res.iterations)))
        results[wnd] = res.values
    return WindowSlideRun(results, anchor, base_stats, hop_stats,
                          time.perf_counter() - t_all,
                          _slide_added_edges(store, windows, anchor))


def run_window_slide_batched(
    store: SnapshotStore,
    semiring: Semiring,
    source: int,
    width: int | None = None,
    *,
    windows: "list[Window] | None" = None,
    step: int = 1,
    start: int = 0,
    anchor: Window | None = None,
    max_iters: int = 10_000,
    gated: bool = False,
    cg_split: int = 1,
    track_parents: bool = False,
    mesh=None,
) -> WindowSlideRun:
    """Batched window slide: every slide hop as a lane of ONE stacked launch.

    The anchor state broadcasts to all window lanes
    (``gather_lane_states`` with an all-zeros lane map), the per-window
    slide Δs stack shape-bucketed (``SnapshotStore.slide_stack``, lane axis
    padded to ``lane_bucket(num_windows, data_extent)`` with masked inert
    lanes), and one ``incremental_additions_batched`` call re-converges
    every window. On a mesh the bucketed window-lane axis ALWAYS shards
    over ``data`` exactly like the TG executor's snapshot axis
    (``launch/evolve.py --shard --window-batch``).
    """
    t_all = time.perf_counter()
    windows, anchor = _resolve(store, width, windows, step, start, anchor)
    anchor_view, base, base_stats = _anchor_base(
        store, anchor, semiring, source, max_iters, gated, cg_split,
        track_parents)

    t0 = time.perf_counter()
    res, bucket = _slide_launch(store, semiring, anchor_view,
                                extract_state(base), windows, anchor,
                                max_iters=max_iters, gated=gated,
                                track_parents=track_parents, mesh=mesh)
    hop_stats = [StreamStats(time.perf_counter() - t0,
                             float(jnp.sum(res.edge_work)),
                             int(jnp.max(res.iterations)))]
    results = {wnd: res.values[lane] for lane, wnd in enumerate(windows)}
    return WindowSlideRun(results, anchor, base_stats, hop_stats,
                          time.perf_counter() - t_all,
                          _slide_added_edges(store, windows, anchor),
                          [(len(windows), bucket)])


def _slide_launch(store: SnapshotStore, semiring: Semiring, anchor_view,
                  state: QueryState, windows: "list[Window]", anchor: Window,
                  *, max_iters: int, gated: bool, track_parents: bool, mesh):
    """ONE stacked launch re-converging every window from an anchor state.

    The shared campaign body of ``run_window_slide_batched`` and the
    streaming scheduler: the anchor state broadcasts to all window lanes
    (masked padding lanes included — their Δ is all-sentinel, so they stay
    inert copies and ``lane_valid`` zeroes them out of the work
    accounting), the per-window slide Δs stack shape-bucketed, and one
    ``incremental_additions_batched`` call runs the lanes (sharded over
    ``data`` when a mesh is given). Returns ``(FixpointResult, bucket)``.
    """
    data_extent = mesh.shape["data"] if mesh is not None else 1
    bucket = lane_bucket(len(windows), data_extent)
    stacked = store.slide_stack(windows, anchor, num_lanes=bucket)
    values, parent = gather_lane_states(state.values[None],
                                        state.parent[None], [0] * bucket)
    lane_valid = jnp.arange(bucket) < len(windows)
    delta_blocks = (stacked,)
    values, parent, delta_blocks, lane_valid = _shard_snapshot_axis(
        mesh, values, parent, delta_blocks, lane_valid)
    res = incremental_additions_batched(
        store.num_nodes, semiring, values, parent,
        shared_blocks=tuple(anchor_view.blocks), delta_blocks=delta_blocks,
        max_iters=max_iters, track_parents=track_parents, gated=gated,
        seed_blocks=(delta_blocks[-1],), lane_valid=lane_valid)
    res.values.block_until_ready()
    return res, bucket


# ---------------------------------------------------------------------------
# Streaming slide campaigns: cross-launch incremental anchor maintenance.
# ---------------------------------------------------------------------------


def _validate_advancing(windows: "list[Window]", tail: Window | None = None):
    prev = tail
    for wnd in windows:
        i, j = wnd
        if j < i:
            raise ValueError(f"window {wnd} is empty: need i <= j")
        if prev is not None and (i < prev[0] or j < prev[1]):
            raise ValueError(
                f"windows must advance: {wnd} steps backwards from {prev} "
                "(both endpoints must be nondecreasing)")
        prev = wnd


@dataclasses.dataclass
class WindowStream:
    """An advancing window sequence consumed campaign-by-campaign.

    The streaming producer side of ``run_window_stream_batched``: windows
    arrive in slide order (both endpoints nondecreasing — enforced), are
    buffered here, and each executor call drains the pending buffer as
    campaigns of ``campaign_width`` windows. The stream object itself holds
    no query state — anchors live in the SnapshotStore's "AS" cache family,
    which is what lets a stream span many launches (and many stream
    objects) while anchor work stays incremental.
    """

    campaign_width: int
    windows: "list[Window]" = dataclasses.field(default_factory=list)
    consumed: int = 0

    def __post_init__(self):
        if self.campaign_width < 1:
            raise ValueError(
                f"campaign_width must be >= 1, got {self.campaign_width}")
        self.windows = [tuple(w) for w in self.windows]
        _validate_advancing(self.windows)

    def extend(self, windows: "list[Window]") -> "WindowStream":
        """Append newly arrived windows (must keep the sequence advancing)."""
        windows = [tuple(w) for w in windows]
        _validate_advancing(windows,
                            tail=self.windows[-1] if self.windows else None)
        self.windows.extend(windows)
        return self

    def pending(self) -> "list[Window]":
        return self.windows[self.consumed:]

    def take(self) -> "list[Window]":
        """Drain and return the pending windows (executor entry point)."""
        out = self.pending()
        self.consumed = len(self.windows)
        return out


def stream_campaigns(windows: "list[Window]",
                     campaign_width: int) -> "list[list[Window]]":
    """Cut an advancing window sequence into consecutive campaigns.

    Campaigns are disjoint chunks of ``campaign_width`` windows (the last
    may be short); their SPANS overlap whenever consecutive windows do —
    which is exactly what the incremental anchor chain exploits.
    """
    if campaign_width < 1:
        raise ValueError(f"campaign_width must be >= 1, got {campaign_width}")
    return [windows[k:k + campaign_width]
            for k in range(0, len(windows), campaign_width)]


def _stream_qkey(semiring: Semiring, source: int, max_iters: int, gated: bool,
                 cg_split: int, track_parents: bool) -> tuple:
    """Anchor-state cache key: everything that selects the query.

    ``values`` of a converged state depend only on (semiring, source) — the
    rest is included conservatively so cached parents/behaviour always match
    the options of the run that would have rebuilt the state.
    """
    return (semiring.name, source, max_iters, gated, cg_split, track_parents)


@dataclasses.dataclass
class WindowStreamRun:
    results: dict[Window, jnp.ndarray]   # window -> values
    campaigns: "list[list[Window]]"
    anchors: "list[Window]"              # per-campaign anchor window
    # per-campaign anchor acquisition: "rebuild" (from-scratch fixpoint),
    # "hop" (incremental_additions from a cached covering state), or "hit"
    # (exact cached state — zero anchor work)
    anchor_events: "list[str]"
    anchor_stats: "list[StreamStats]"    # per-campaign anchor acquisition
    hop_stats: "list[StreamStats]"       # per-campaign stacked launch
    wall_s: float
    added_edges: int                     # total window-hop Δ volume
    anchor_delta_edges: int              # Δ volume of incremental anchor hops
    lane_layout: "list[tuple[int, int]]"

    @property
    def anchor_rebuilds(self) -> int:
        return self.anchor_events.count("rebuild")

    @property
    def anchor_hops(self) -> int:
        return self.anchor_events.count("hop")

    @property
    def anchor_hits(self) -> int:
        return self.anchor_events.count("hit")


def _acquire_anchor_state(store: SnapshotStore, qkey: tuple, anchor: Window,
                          semiring: Semiring, source: int, max_iters: int,
                          gated: bool, cg_split: int, track_parents: bool):
    """Anchor state via cache hit, incremental hop, or from-scratch rebuild.

    Returns ``(anchor_view, state, stats, event, delta_edges)`` —
    ``delta_edges`` is the hop's Δ volume (0 on hit/rebuild). The view's
    blocks UNION to exactly T(anchor) in every case (anchor view on
    hit/rebuild, cover view ⊕ hop Δ after a hop) — per-sweep reductions are
    block-partition invariant, so downstream campaign results do not depend
    on which path ran. The acquired state is (re-)cached under the anchor's
    "AS" tag.
    """
    t0 = time.perf_counter()
    state = store.anchor_state_get(qkey, anchor)
    if state is not None:
        view = _anchor_view(store, anchor, cg_split)
        return view, state, StreamStats(time.perf_counter() - t0, 0.0, 0), \
            "hit", 0
    cover = store.anchor_state_cover(qkey, anchor)
    if cover is not None:
        cover_window, cover_state = cover
        delta = store.delta_block(cover_window, anchor)
        view = _anchor_view(store, cover_window, cg_split).extended(delta)
        res = incremental_additions(view, delta, semiring, cover_state.values,
                                    cover_state.parent, max_iters,
                                    gated=gated, track_parents=track_parents)
        res.values.block_until_ready()
        state = store.anchor_state_put(qkey, anchor, extract_state(res))
        delta_edges = (store.window_size(*anchor)
                       - store.window_size(*cover_window))
        return view, state, StreamStats(time.perf_counter() - t0,
                                        float(res.edge_work),
                                        int(res.iterations)), "hop", \
            delta_edges
    anchor_view, base, base_stats = _anchor_base(
        store, anchor, semiring, source, max_iters, gated, cg_split,
        track_parents)
    state = store.anchor_state_put(qkey, anchor, extract_state(base))
    return anchor_view, state, base_stats, "rebuild", 0


def run_window_stream_batched(
    store: SnapshotStore,
    semiring: Semiring,
    source: int,
    width: int | None = None,
    *,
    windows: "list[Window] | None" = None,
    stream: WindowStream | None = None,
    step: int = 1,
    start: int = 0,
    campaign_width: int | None = None,
    max_iters: int = 10_000,
    gated: bool = False,
    cg_split: int = 1,
    track_parents: bool = False,
    mesh=None,
) -> WindowStreamRun:
    """Streaming slide campaigns with incremental anchor maintenance.

    Consumes an advancing window sequence (``stream.take()``, an explicit
    ``windows`` list, or a ``slide_windows`` plan from ``width``), cuts it
    into campaigns of ``campaign_width`` windows (default 4; a
    ``WindowStream`` carries its own width, so passing both together is an
    error), and runs each campaign as
    ONE masked pow2-lane ``incremental_additions_batched`` launch (the
    ``run_window_slide_batched`` machinery, sharded over ``data`` when a
    mesh is given).

    Campaign k anchors at ``(lo_k, stream_hi)`` — its windows' span widened
    to the stream's last snapshot. Widening is what makes the anchor chain
    monotone: campaign k+1's anchor interval is nested in campaign k's, so
    its common graph is reachable from k's converged state by PURE
    ADDITIONS, and the scheduler seeds it with one incremental hop instead
    of recomputing from the base snapshot. Anchor states are cached in the
    store's "AS" LRU family, so only the first campaign (or a campaign
    whose predecessors were evicted, or one whose stream has advanced past
    every cached cover) pays a from-scratch rebuild.

    Results are bit-identical to running ``run_window_slide_batched`` cold
    per campaign with the same anchors; the streamed path just performs
    strictly fewer anchor rebuilds (1 + evictions vs one per campaign).
    """
    t_all = time.perf_counter()
    if stream is not None:
        if windows is not None or width is not None:
            raise ValueError("pass stream= alone, not with width=/windows=")
        if campaign_width is not None:
            raise ValueError("campaign_width= conflicts with stream=: the "
                             "WindowStream carries its own campaign width")
        windows = stream.take()
        campaign_width = stream.campaign_width
    else:
        if campaign_width is None:
            campaign_width = 4
        if windows is None:
            if width is None:
                raise ValueError("pass width=, windows= or stream=")
            windows = slide_windows(store.seq.num_snapshots, width, step=step,
                                    start=start)
        windows = [tuple(w) for w in windows]
        _validate_advancing(windows)
    if not windows:
        return WindowStreamRun({}, [], [], [], [], [],
                               time.perf_counter() - t_all, 0, 0, [])
    campaigns = stream_campaigns(windows, campaign_width)
    stream_hi = windows[-1][1]
    qkey = _stream_qkey(semiring, source, max_iters, gated, cg_split,
                        track_parents)

    results: dict[Window, jnp.ndarray] = {}
    anchors: "list[Window]" = []
    anchor_events: "list[str]" = []
    anchor_stats: "list[StreamStats]" = []
    hop_stats: "list[StreamStats]" = []
    lane_layout: "list[tuple[int, int]]" = []
    added_edges = 0
    anchor_delta_edges = 0
    for campaign in campaigns:
        anchor = (min(i for i, _ in campaign), stream_hi)
        anchor_view, state, stats, event, delta_edges = _acquire_anchor_state(
            store, qkey, anchor, semiring, source, max_iters, gated, cg_split,
            track_parents)
        anchors.append(anchor)
        anchor_events.append(event)
        anchor_stats.append(stats)
        anchor_delta_edges += delta_edges
        t0 = time.perf_counter()
        res, bucket = _slide_launch(store, semiring, anchor_view, state,
                                    campaign, anchor, max_iters=max_iters,
                                    gated=gated, track_parents=track_parents,
                                    mesh=mesh)
        hop_stats.append(StreamStats(time.perf_counter() - t0,
                                     float(jnp.sum(res.edge_work)),
                                     int(jnp.max(res.iterations))))
        lane_layout.append((len(campaign), bucket))
        for lane, wnd in enumerate(campaign):
            results[wnd] = res.values[lane]
        added_edges += _slide_added_edges(store, campaign, anchor)
    return WindowStreamRun(results, campaigns, anchors, anchor_events,
                           anchor_stats, hop_stats,
                           time.perf_counter() - t_all, added_edges,
                           anchor_delta_edges, lane_layout)

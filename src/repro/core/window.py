"""Sliding-window executors: many window queries as one batched launch.

The dominant query pattern over an evolving sequence is not "every
snapshot" but a *window* that slides: answer the query on ``[i, i+w)``,
then on ``[i+1, i+w+1)``, and so on (delta-based historical queries à la
Koloniari et al.; the streaming-system surveys make the same point). The
naive slide re-runs the query per window. CommonGraph makes every window
an *addition-only* hop from a shared anchor:

* Sliding is NOT deletion-free between consecutive windows — ``T(i,j) ⊄
  T(i+1,j+1)`` in general. The sound warm start is any common
  SUPER-window's apex: for windows spanning ``[lo..hi]`` the tightest is
  ``T(lo, hi)`` (every window's common graph contains it), which
  ``window_anchor`` picks by default.
* With one anchor fixpoint in hand, each window apex is reached by
  streaming ``slide_block(window, anchor)`` — pure additions. The hops are
  mutually independent, so the batched executor stacks them as lanes of a
  single ``incremental_additions_batched`` launch
  (``SnapshotStore.slide_stack``), exactly the level-batching machinery of
  ``core/trigrid.py`` with windows instead of plan levels.

Executor contract (same as core/trigrid.py, enforced by
tests/test_window.py):

* **Bit-identical results.** ``run_window_slide_batched`` returns values
  (and parents, when tracked) bit-identical to the sequential
  ``run_window_slide`` for the same windows/anchor/options: every lane
  converges over exactly the edge set the sequential hop uses (anchor
  blocks + that window's slide Δ), and the monotone fixpoint is
  order-free. Both match a from-scratch fixpoint on each window's common
  graph up to float tolerance.
* **Shape-bucketing invariant.** The stacked slide Δ has shape
  ``(pow2 lane bucket, pow2 width bucket)``: the window-lane axis pads to
  ``lane_bucket(num_windows, data_extent)`` with trailing masked lanes
  (all-sentinel Δ, anchor-state copy, ``lane_valid=False``, zero
  work/iterations), so jit traces are keyed on buckets alone and any
  window count shards over a ``data`` mesh — the replicated fallback (and
  its UserWarning) no longer exists.
* **Degenerate cases.** A single window equal to the anchor is legal: its
  Δ is empty, the seed sweep finds no improvements, and the anchor state
  is returned unchanged. Likewise ``width == num_snapshots`` yields one
  window (the global CG query itself).
* **Work accounting.** Padding never counts toward ``edge_work``; batched
  and sequential slides report equal per-window totals.

Streaming campaigns (``WindowStream`` / ``run_window_stream_batched``) layer
cross-launch anchor reuse on top: an advancing window sequence is cut into
campaigns of ``campaign_width`` windows, each campaign runs as one batched
slide launch anchored at ``(campaign_lo, stream_hi)``, and the anchor STATE
is maintained incrementally — campaign k+1's anchor window is nested in
campaign k's (its common graph is a pure-addition extension), so k's
converged state seeds an ``incremental_additions`` hop instead of a
from-scratch rebuild. States live in ``SnapshotStore``'s LRU-cached "AS"
family, so back-to-back campaigns (and repeat stream calls) hit memory, not
recompute; eviction mid-stream costs exactly one rebuild and never changes
results. The stream contract, enforced by tests/test_window_stream.py:

* **Bit-identical to cold campaigns.** ``run_window_stream_batched`` window
  values equal ``run_window_slide_batched`` run cold per campaign (same
  windows, same anchor) bit-for-bit — the monotone rounded fixpoint of a
  window's common graph is unique, so how the anchor state was reached
  (from-scratch vs incremental hops) is unobservable in values.
* **Strictly fewer rebuilds.** A K-campaign stream performs 1 anchor
  rebuild + K−1 incremental anchor hops (plus one rebuild per mid-stream
  eviction) vs the cold path's K rebuilds.

Two layers complete the subsystem (docs/STREAMING.md is the full guide):

* **Campaign planning** (``optimal_campaigns`` / ``CampaignPlan``): the
  campaign partition itself is chosen by Δ-volume — a suffix DP over cut
  points pricing slide hops, anchor hops and the pow2 masked-lane padding
  from the same ``hop_added_edges`` atom as the TG plan DP.
  ``campaign_width="auto"`` routes the executor through it;
  ``campaign_volume`` prices any partition under the identical model, so
  auto is provably never worse than any fixed width ≤ ``lane_budget``.
* **Anchor chains** (``AnchorChain`` / ``select_chain``): overlapping
  streams share one chain of nested anchor states. Links are pinned in the
  store while any registered stream is still behind them, so a lagging
  stream's next hop source cannot be evicted; values are unaffected either
  way (unique monotone fixpoint) — sharing only converts rebuilds into
  hops/hits.
"""

from __future__ import annotations

import dataclasses
import itertools
import time

import jax.numpy as jnp
import numpy as np

from repro.core.kickstarter import StreamStats
from repro.core.snapshots import SnapshotStore, anchor_tag, tightest_cover
from repro.core.trigrid import (
    _anchor_base,
    _anchor_view,
    _shard_snapshot_axis,
    hop_added_edges,
)
from repro.graph.edgeset import lane_bucket
from repro.graph.engine import (
    QueryState,
    extract_state,
    gather_lane_states,
    host_sync,
    incremental_additions,
    incremental_additions_batched,
)
from repro.graph.semiring import Semiring
from repro.graph.stability import stable_fraction_milli

Window = tuple[int, int]


def slide_windows(num_snapshots: int, width: int, step: int = 1,
                  start: int = 0) -> list[Window]:
    """Window plan construction: all width-``width`` windows sliding by ``step``.

    Windows are inclusive snapshot-index pairs ``(i, i + width - 1)``; the
    last one ends at the final snapshot. Degenerate cases are explicit: a
    width covering the whole (remaining) sequence yields exactly one
    window.
    """
    if not 1 <= width <= num_snapshots - start:
        raise ValueError(
            f"window width {width} not in [1, {num_snapshots - start}] "
            f"(num_snapshots={num_snapshots}, start={start})")
    if step < 1:
        raise ValueError(f"step must be >= 1, got {step}")
    return [(i, i + width - 1)
            for i in range(start, num_snapshots - width + 1, step)]


def window_anchor(windows: list[Window]) -> Window:
    """Tightest common super-window: the span of all windows.

    Every window's common graph contains the span's (nested windows ⇒
    nested CGs), so the span apex warm-starts every slide hop with the
    largest possible shared state — strictly less Δ volume than anchoring
    at the global CG when the windows don't cover the whole sequence.
    """
    if not windows:
        raise ValueError("need at least one window")
    return min(i for i, _ in windows), max(j for _, j in windows)


@dataclasses.dataclass
class WindowSlideRun:
    """Result record of one window slide: per-window values plus the
    shared-anchor fixpoint stats, per-hop stats and Δ-volume/lane
    accounting the benchmarks compare executors by."""

    results: dict[Window, jnp.ndarray]  # window -> values
    anchor: Window
    base_stats: StreamStats             # the shared anchor fixpoint
    hop_stats: list[StreamStats]        # per-window (seq) or 1 launch (batched)
    wall_s: float
    added_edges: int                    # total slide-Δ volume streamed
    # (valid lanes, lane_bucket) of the batched launch; empty when sequential
    lane_layout: "list[tuple[int, int]]" = dataclasses.field(
        default_factory=list)
    # measured stable fraction (‰) over all window hops: the share of
    # vertex-lanes the stability analysis kept out of the seed frontier
    # (graph/stability.py; padding lanes excluded)
    stable_milli: int = 0


def _slide_added_edges(store: SnapshotStore, windows: list[Window],
                       anchor: Window) -> int:
    """Total slide-Δ volume of hopping every window off ``anchor``.

    Each window apex is one grid hop T(anchor) → T(window), so the volume
    is a sum of ``hop_added_edges`` atoms — the same cost atom the TG plan
    DP and the campaign planner (``optimal_campaigns``) optimize over.
    """
    return sum(hop_added_edges(store, anchor, w) for w in windows)


def _resolve(store: SnapshotStore, width: int | None, windows, step, start,
             anchor):
    if windows is None:
        if width is None:
            raise ValueError("pass either width= or windows=")
        windows = slide_windows(store.seq.num_snapshots, width, step=step,
                                start=start)
    windows = [tuple(w) for w in windows]
    if anchor is None:
        anchor = window_anchor(windows)
    return windows, tuple(anchor)


def run_window_slide(
    store: SnapshotStore,
    semiring: Semiring,
    source: int,
    width: int | None = None,
    *,
    windows: "list[Window] | None" = None,
    step: int = 1,
    start: int = 0,
    anchor: Window | None = None,
    max_iters: int = 10_000,
    gated: bool = False,
    cg_split: int = 1,
    track_parents: bool = False,
    seed: str = "instability",
    fused_k: int = 1,
) -> WindowSlideRun:
    """Sequential window slide: one anchor fixpoint, then per-window hops.

    The baseline the batched executor is measured (and bit-compared)
    against: each window re-executes ``incremental_additions`` from the
    anchor state with that window's slide Δ, seeded per the stable-vertex
    analysis (``seed="delta"`` restores full-Δ seeding; values identical).
    ``fused_k`` threads to the engine's fused-chunk launch option
    (bit-identical results at any value).
    """
    t_all = time.perf_counter()
    windows, anchor = _resolve(store, width, windows, step, start, anchor)
    anchor_view, base, base_stats = _anchor_base(
        store, anchor, semiring, source, max_iters, gated, cg_split,
        track_parents, fused_k)

    results: dict[Window, jnp.ndarray] = {}
    hop_stats: list[StreamStats] = []
    unstable_counts: list[int] = []
    for wnd in windows:
        t0 = time.perf_counter()
        delta = store.slide_block(wnd, anchor)
        view = anchor_view.extended(delta)       # shared immutable blocks
        res = incremental_additions(view, delta, semiring, base.values,
                                    base.parent, max_iters, gated=gated,
                                    track_parents=track_parents, seed=seed,
                                    fused_k=fused_k)
        host_sync(res.values)
        hop_stats.append(StreamStats(time.perf_counter() - t0,
                                     float(res.edge_work),
                                     int(res.iterations)))
        unstable_counts.append(int(res.unstable))
        results[wnd] = res.values
    return WindowSlideRun(results, anchor, base_stats, hop_stats,
                          time.perf_counter() - t_all,
                          _slide_added_edges(store, windows, anchor),
                          stable_milli=stable_fraction_milli(
                              unstable_counts, store.num_nodes))


def run_window_slide_batched(
    store: SnapshotStore,
    semiring: Semiring,
    source: int,
    width: int | None = None,
    *,
    windows: "list[Window] | None" = None,
    step: int = 1,
    start: int = 0,
    anchor: Window | None = None,
    max_iters: int = 10_000,
    gated: bool = False,
    cg_split: int = 1,
    track_parents: bool = False,
    mesh=None,
    seed: str = "instability",
    fused_k: int = 1,
) -> WindowSlideRun:
    """Batched window slide: every slide hop as a lane of ONE stacked launch.

    The anchor state broadcasts to all window lanes
    (``gather_lane_states`` with an all-zeros lane map), the per-window
    slide Δs stack shape-bucketed (``SnapshotStore.slide_stack``, lane axis
    padded to ``lane_bucket(num_windows, data_extent)`` with masked inert
    lanes), and one ``incremental_additions_batched`` call re-converges
    every window. On a mesh the bucketed window-lane axis ALWAYS shards
    over ``data`` exactly like the TG executor's snapshot axis
    (``launch/evolve.py --shard --window-batch``).
    """
    t_all = time.perf_counter()
    windows, anchor = _resolve(store, width, windows, step, start, anchor)
    anchor_view, base, base_stats = _anchor_base(
        store, anchor, semiring, source, max_iters, gated, cg_split,
        track_parents, fused_k)

    t0 = time.perf_counter()
    res, bucket = _slide_launch(store, semiring, anchor_view,
                                extract_state(base), windows, anchor,
                                max_iters=max_iters, gated=gated,
                                track_parents=track_parents, mesh=mesh,
                                seed=seed, fused_k=fused_k)
    hop_stats = [StreamStats(time.perf_counter() - t0,
                             float(jnp.sum(res.edge_work)),
                             int(jnp.max(res.iterations)))]
    results = {wnd: res.values[lane] for lane, wnd in enumerate(windows)}
    return WindowSlideRun(results, anchor, base_stats, hop_stats,
                          time.perf_counter() - t_all,
                          _slide_added_edges(store, windows, anchor),
                          [(len(windows), bucket)],
                          stable_milli=stable_fraction_milli(
                              np.asarray(res.unstable)[:len(windows)],
                              store.num_nodes))


def _slide_launch(store: SnapshotStore, semiring: Semiring, anchor_view,
                  state: "QueryState | list[QueryState]",
                  windows: "list[Window]", anchor: Window,
                  *, max_iters: int, gated: bool, track_parents: bool, mesh,
                  lane_map: "list[int] | None" = None,
                  seed: str = "instability", fused_k: int = 1):
    """ONE stacked launch re-converging every window from anchor state(s).

    The shared campaign body of ``run_window_slide_batched``, the streaming
    scheduler and the query service's admission packer. ``state`` is either
    a single :class:`QueryState` broadcast to every window lane (the
    default, ``lane_map=None``), or — when ``lane_map`` is given — a list
    of states with ``lane_map[k]`` naming the state that seeds window lane
    ``k``: how ``core/service.py`` packs same-options queries for DIFFERENT
    (semiring-compatible) sources into one launch, each lane warm-starting
    from its own query's anchor state. Masked padding lanes ride along as
    inert copies of the first mapped state — their Δ is all-sentinel and
    ``lane_valid`` zeroes them out of the work accounting. The per-window
    slide Δs stack shape-bucketed, and one
    ``incremental_additions_batched`` call runs the lanes (sharded over
    ``data`` when a mesh is given). Returns ``(FixpointResult, bucket)``.
    """
    data_extent = mesh.shape["data"] if mesh is not None else 1
    bucket = lane_bucket(len(windows), data_extent)
    stacked = store.slide_stack(windows, anchor, num_lanes=bucket)
    if lane_map is None:
        states, lane_map = [state], [0] * len(windows)
    else:
        states = list(state)
        if len(lane_map) != len(windows):
            raise ValueError(f"lane_map names {len(lane_map)} lanes for "
                             f"{len(windows)} windows")
    lane_map = list(lane_map) + [lane_map[0]] * (bucket - len(windows))
    values, parent = gather_lane_states(
        jnp.stack([s.values for s in states]),
        jnp.stack([s.parent for s in states]), lane_map)
    lane_valid = jnp.arange(bucket) < len(windows)
    delta_blocks = (stacked,)
    values, parent, delta_blocks, lane_valid = _shard_snapshot_axis(
        mesh, values, parent, delta_blocks, lane_valid)
    res = incremental_additions_batched(
        store.num_nodes, semiring, values, parent,
        shared_blocks=tuple(anchor_view.blocks), delta_blocks=delta_blocks,
        max_iters=max_iters, track_parents=track_parents, gated=gated,
        seed_blocks=(delta_blocks[-1],), lane_valid=lane_valid, seed=seed,
        fused_k=fused_k)
    host_sync(res.values)
    return res, bucket


# ---------------------------------------------------------------------------
# Streaming slide campaigns: cross-launch incremental anchor maintenance.
# ---------------------------------------------------------------------------


def _validate_advancing(windows: "list[Window]", tail: Window | None = None):
    prev = tail
    for wnd in windows:
        i, j = wnd
        if j < i:
            raise ValueError(f"window {wnd} is empty: need i <= j")
        if prev is not None and (i < prev[0] or j < prev[1]):
            raise ValueError(
                f"windows must advance: {wnd} steps backwards from {prev} "
                "(both endpoints must be nondecreasing)")
        prev = wnd


#: ``campaign_width`` sentinel: let ``optimal_campaigns`` choose the
#: partition by Δ-volume instead of cutting fixed-width chunks.
CAMPAIGN_AUTO = "auto"

_STREAM_COUNTER = itertools.count()


def _valid_campaign_width(width) -> bool:
    return width == CAMPAIGN_AUTO or (isinstance(width, int) and width >= 1)


@dataclasses.dataclass
class WindowStream:
    """An advancing window sequence consumed campaign-by-campaign.

    The streaming producer side of ``run_window_stream_batched``: windows
    arrive in slide order (both endpoints nondecreasing — enforced), are
    buffered here, and each executor call drains the pending buffer as
    campaigns of ``campaign_width`` windows (``"auto"`` = let
    ``optimal_campaigns`` pick the partition by Δ-volume). The stream
    object itself holds no query state — anchors live in the
    SnapshotStore's "AS" cache family, which is what lets a stream span
    many launches (and many stream objects) while anchor work stays
    incremental. ``name`` identifies the stream to an :class:`AnchorChain`
    when several overlapping streams share one (auto-generated unless
    given).

    ``feed`` attaches a live window source (``ingest.LiveWindowFeed``):
    the stream then blocks on the watermark instead of a precomputed
    list — every ``pending``/``take``/``take_next`` first polls the feed
    for windows born by new snapshot cuts, and every consumption reports
    progress back so the feed's compaction floor tracks the oldest
    snapshot an unconsumed window still needs.
    """

    campaign_width: "int | str"
    windows: "list[Window]" = dataclasses.field(default_factory=list)
    consumed: int = 0
    name: "str | None" = None
    feed: "object | None" = None

    def __post_init__(self):
        if not _valid_campaign_width(self.campaign_width):
            raise ValueError(
                f'campaign_width must be an int >= 1 or "auto", '
                f"got {self.campaign_width!r}")
        self.windows = [tuple(w) for w in self.windows]
        _validate_advancing(self.windows)
        if self.name is None:
            self.name = f"stream-{next(_STREAM_COUNTER)}"
        self._sync_feed()

    def _sync_feed(self) -> None:
        # Pull windows born from the live feed since the last poll. Duck-
        # typed (anything with poll()) so window.py never imports ingest.py.
        if self.feed is not None:
            born = self.feed.poll()
            if born:
                self.extend(born)

    def _report_feed(self) -> None:
        # Report consumption so the feed's compaction floor advances: the
        # oldest snapshot still needed is the first unconsumed window's lo.
        if self.feed is not None:
            rest = self.windows[self.consumed:]
            self.feed.advance_floor(rest[0][0] if rest else None)

    def extend(self, windows: "list[Window]") -> "WindowStream":
        """Append newly arrived windows (must keep the sequence advancing)."""
        windows = [tuple(w) for w in windows]
        _validate_advancing(windows,
                            tail=self.windows[-1] if self.windows else None)
        self.windows.extend(windows)
        return self

    def pending(self) -> "list[Window]":
        """Windows buffered but not yet consumed by the executor.

        With a live ``feed``, polls it first so freshly cut windows count.
        """
        self._sync_feed()
        return self.windows[self.consumed:]

    def take(self) -> "list[Window]":
        """Drain and return the pending windows (executor entry point)."""
        out = self.pending()
        self.consumed = len(self.windows)
        self._report_feed()
        return out

    def take_next(self, count: int) -> "list[Window]":
        """Consume and return up to ``count`` pending windows.

        The query service's bounded per-turn draw: one scheduler turn takes
        at most a campaign's worth of windows from each stream so no client
        monopolizes a turn (``take()`` drains everything — the
        stream-at-a-time executor's entry point). With a live ``feed`` this
        is the blocking-on-the-watermark call: it returns only windows
        whose newest snapshot has been cut, possibly none.
        """
        self._sync_feed()
        out = self.windows[self.consumed:self.consumed + count]
        self.consumed += len(out)
        self._report_feed()
        return out


def stream_campaigns(windows: "list[Window]",
                     campaign_width: int) -> "list[list[Window]]":
    """Cut an advancing window sequence into consecutive fixed-width campaigns.

    Campaigns are disjoint chunks of ``campaign_width`` windows (the last
    may be short); their SPANS overlap whenever consecutive windows do —
    which is exactly what the incremental anchor chain exploits. The
    ``"auto"`` sentinel is NOT resolved here (fixed-width chunking needs no
    store): ``run_window_stream_batched(campaign_width="auto")`` partitions
    via ``optimal_campaigns`` instead of this function.
    """
    if campaign_width == CAMPAIGN_AUTO:
        raise ValueError(
            'campaign_width="auto" needs a SnapshotStore to plan against — '
            "partition via optimal_campaigns(store, windows), which is what "
            'run_window_stream_batched(campaign_width="auto") does')
    if not _valid_campaign_width(campaign_width):
        raise ValueError(f'campaign_width must be an int >= 1 or "auto", '
                         f"got {campaign_width!r}")
    return [windows[k:k + campaign_width]
            for k in range(0, len(windows), campaign_width)]


# ---------------------------------------------------------------------------
# Campaign planner: Δ-volume DP over the campaign partition.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CampaignPlan:
    """A campaign partition of an advancing window sequence + modeled cost.

    The planner's unit of exchange: ``optimal_campaigns`` returns the
    Δ-volume-minimal plan, ``campaign_volume`` evaluates ANY partition
    (fixed-width chunkings included) under the same cost model, so plans
    are directly comparable. The model counts edges the launches actually
    process:

    * ``slide_edges`` — exact window-hop Δ volume: every window streams
      ``|T(window)| − |T(anchor)|`` addition edges off its campaign anchor.
    * ``anchor_edges`` — anchor-chain volume: the first anchor's
      from-scratch rebuild (``|T(anchor_0)|``) plus each later campaign's
      incremental hop (``|T(anchor_k)| − |T(anchor_{k−1})|``). The hops
      telescope, so this always equals ``|T(anchor_last)|`` — narrower
      last campaigns pay more here.
    * ``padding_edges`` — the pow2 masked-lane penalty: a campaign of L
      windows launches ``lane_bucket(L, data_extent)`` lanes, and each of
      the ``bucket − L`` masked lanes rides along at the campaign's widest
      slide Δ (the stacked buffer's lane width). This is device volume,
      not streamed edges — it is what makes width 5 more expensive than
      width 4 even when the exact Δ sums agree.

    ``stable_milli`` records the instability discount the model was priced
    under: the stable-vertex analysis (graph/stability.py) lets each seed
    sweep skip Δ edges leaving unreached vertices, so every
    ``hop_added_edges`` atom is scaled by ``(1000 − stable_milli) / 1000``
    before entering the slide/pad/anchor-hop terms. The default 0 prices
    raw Δ volume (no discount); a caller with a measured fraction from a
    prior run (e.g. the warm-up stream in ``launch/evolve.py``) passes it
    in so the plan prices the work the executors will actually do. The
    discount is applied at the ATOM level in both ``campaign_volume`` and
    the ``optimal_campaigns`` DP, so DP cost equals partition price and
    auto ≤ fixed-width holds for any ``stable_milli``.
    """

    campaigns: "list[list[Window]]"
    anchors: "list[Window]"              # per-campaign (lo_k, stream_hi)
    lane_budget: int
    data_extent: int
    slide_edges: int
    anchor_edges: int
    padding_edges: int
    # instability discount (‰ stable) the volumes above were priced under
    stable_milli: int = 0
    # measured-cost model (core/costmodel.SweepCostModel) the volumes were
    # priced under, or None for the raw discounted edge-count objective
    cost_model: object = None

    @property
    def widths(self) -> "list[int]":
        """Per-campaign window counts (the partition's shape)."""
        return [len(c) for c in self.campaigns]

    @property
    def total_edges(self) -> int:
        """The planner's objective: slide + anchor + masked-lane volume.

        With a ``cost_model`` the unit is integer nanoseconds of modeled
        launch time rather than discounted edge count — still an exact
        integer, so plan comparisons stay machine-independent.
        """
        return self.slide_edges + self.anchor_edges + self.padding_edges


def _instability_volume(edges: int, stable_milli: int) -> int:
    """One Δ-volume atom discounted by the modeled stable fraction (‰).

    The stability analysis keeps ``stable_milli``/1000 of vertex-lanes out
    of the seed frontier, so a hop's effective Δ volume shrinks to
    ``edges · (1000 − stable_milli) / 1000`` (floor division — integers
    keep the DP/partition-price equality exact). ``stable_milli=0`` is the
    identity, so undiscounted plans are bit-stable.
    """
    if not 0 <= stable_milli <= 1000:
        raise ValueError(f"stable_milli must be in [0, 1000], "
                         f"got {stable_milli!r}")
    return edges * (1000 - stable_milli) // 1000


def campaign_volume(store: SnapshotStore, campaigns: "list[list[Window]]",
                    *, data_extent: int = 1,
                    lane_budget: "int | None" = None,
                    stable_milli: int = 0,
                    cost_model=None) -> CampaignPlan:
    """Evaluate a campaign partition under the planner's Δ-volume model.

    Anchors each campaign exactly as ``run_window_stream_batched`` does —
    ``(campaign_lo, stream_hi)`` — and prices it per the
    :class:`CampaignPlan` field docs. Works for any partition of any
    advancing window sequence, which is what lets tests (and the planner
    itself) compare ``optimal_campaigns`` against every fixed-width
    chunking on equal terms. ``stable_milli`` applies the instability
    discount (:func:`_instability_volume`) to every hop atom — slide Δs,
    masked-lane padding and incremental anchor hops; the first anchor's
    from-scratch rebuild is NOT a Δ-seeded sweep and prices undiscounted.

    With a ``cost_model`` (core/costmodel.SweepCostModel, duck-typed) every
    hop atom prices via ``cost_model.hop_cost(edges)`` instead — the
    model's own ``stable_milli`` applies and this function's
    ``stable_milli`` argument is ignored — and the first anchor via
    ``cost_model.anchor_cost``; volumes become modeled integer nanoseconds.
    """
    if not campaigns or not all(campaigns):
        raise ValueError("campaigns must be a non-empty list of non-empty "
                         "window lists")
    windows = [w for c in campaigns for w in c]
    _validate_advancing(windows)
    stream_hi = windows[-1][1]
    anchors = [(c[0][0], stream_hi) for c in campaigns]
    if cost_model is not None:
        price = cost_model.hop_cost
        first_anchor = cost_model.anchor_cost(store.window_size(*anchors[0]))
    else:
        price = lambda edges: _instability_volume(edges, stable_milli)
        first_anchor = store.window_size(*anchors[0])
    slide = padding = 0
    for campaign, anchor in zip(campaigns, anchors):
        deltas = [price(hop_added_edges(store, anchor, w)) for w in campaign]
        slide += sum(deltas)
        bucket = lane_bucket(len(campaign), data_extent)
        padding += (bucket - len(campaign)) * max(deltas)
    anchor_edges = first_anchor + sum(
        price(hop_added_edges(store, prev, cur))
        for prev, cur in zip(anchors, anchors[1:]))
    return CampaignPlan(campaigns, anchors,
                        lane_budget if lane_budget is not None
                        else max(map(len, campaigns)),
                        data_extent, slide, anchor_edges, padding,
                        stable_milli=stable_milli, cost_model=cost_model)


def optimal_campaigns(store: SnapshotStore, windows: "list[Window]", *,
                      lane_budget: int = 8,
                      data_extent: int = 1,
                      stable_milli: int = 0,
                      cost_model=None) -> CampaignPlan:
    """Δ-volume-minimal campaign partition of an advancing window sequence.

    The streaming analogue of ``optimal_plan``'s interval DP over grid
    hops: where the TG planner chooses which hops to share *within* one
    launch tree, this DP chooses where to CUT the stream into campaigns —
    the "how much to share per launch" decision PR 4 left to a fixed
    ``campaign_width``. Suffix DP over cut points, both cost terms built
    from the same ``hop_added_edges`` atom:

    .. code-block:: text

        f(N) = 0
        f(j) = min over i in (j, min(j+lane_budget, N)]:
                 slideΔ(j, i)                       # Σ |T(w)| − |T(a_j)|
               + pad(j, i)                          # masked pow2 lanes
               + (|T(a_i)| − |T(a_j)|  if i < N)    # anchor hop into next
               + f(i)
        total = |T(a_0)| + f(0)          # a_j = (lo_j, stream_hi)

    The trade the DP resolves: wider campaigns anchor earlier (smaller
    ``|T(a_j)|``), so every window in them streams MORE slide Δ — but they
    pay fewer anchor hops and amortize the pow2 lane bucket better;
    ``lane_budget`` caps the width (device memory for one stacked launch),
    and ``data_extent`` makes the pad term mesh-aware (a campaign always
    launches a lane count divisible by the mesh's ``data`` axis). Runs in
    O(N · lane_budget) after the size table is built.

    Guarantee (property-tested): the returned plan's ``total_edges`` is
    ≤ that of EVERY fixed-width chunking with width ≤ ``lane_budget``,
    fixed widths being points in the DP's search space. ``stable_milli``
    applies the instability discount to every hop atom exactly as
    ``campaign_volume`` does (same :func:`_instability_volume` call per
    atom), so the DP's cost equals the partition's price and the auto ≤
    fixed-width guarantee holds under any discount. A ``cost_model``
    substitutes ``cost_model.hop_cost`` for that atom in BOTH the DP and
    the returned plan's pricing (``campaign_volume(..., cost_model=...)``),
    preserving the same DP-equals-price exactness — so the calibrated plan
    is never worse than any other partition *under the model*, including
    the raw-count plan re-priced by it.
    """
    windows = [tuple(w) for w in windows]
    if not windows:
        raise ValueError("need at least one window to plan campaigns")
    _validate_advancing(windows)
    if not isinstance(lane_budget, int) or lane_budget < 1:
        raise ValueError(f"lane_budget must be an int >= 1, "
                         f"got {lane_budget!r}")
    n = len(windows)
    stream_hi = windows[-1][1]
    anchor_size = [store.window_size(lo, stream_hi) for lo, _ in windows]
    window_size = [store.window_size(*w) for w in windows]
    price = (cost_model.hop_cost if cost_model is not None
             else lambda edges: _instability_volume(edges, stable_milli))

    INF = float("inf")
    f = [INF] * n + [0.0]
    cut: "list[int]" = [0] * n
    for j in range(n - 1, -1, -1):
        slide, widest = 0, 0
        for i in range(j + 1, min(j + lane_budget, n) + 1):
            delta = price(window_size[i - 1] - anchor_size[j])
            slide += delta
            widest = max(widest, delta)
            lanes = i - j
            pad = (lane_bucket(lanes, data_extent) - lanes) * widest
            hop = (price(anchor_size[i] - anchor_size[j]) if i < n else 0)
            cost = slide + pad + hop + f[i]
            if cost < f[j]:
                f[j], cut[j] = cost, i
    campaigns = []
    j = 0
    while j < n:
        campaigns.append(windows[j:cut[j]])
        j = cut[j]
    return campaign_volume(store, campaigns, data_extent=data_extent,
                           lane_budget=lane_budget,
                           stable_milli=stable_milli, cost_model=cost_model)


def _stream_qkey(semiring: Semiring, source: int, max_iters: int, gated: bool,
                 cg_split: int, track_parents: bool) -> tuple:
    """Anchor-state cache key: everything that selects the query.

    ``values`` of a converged state depend only on (semiring, source) — the
    rest is included conservatively so cached parents/behaviour always match
    the options of the run that would have rebuilt the state.
    """
    return (semiring.name, source, max_iters, gated, cg_split, track_parents)


@dataclasses.dataclass
class WindowStreamRun:
    """Result record of a streamed run: per-window values, the campaign
    partition, per-campaign anchor events (rebuild/hop/hit) and stats,
    and — in campaign_width="auto" mode — the chosen CampaignPlan."""

    results: dict[Window, jnp.ndarray]   # window -> values
    campaigns: "list[list[Window]]"
    anchors: "list[Window]"              # per-campaign anchor window
    # per-campaign anchor acquisition: "rebuild" (from-scratch fixpoint),
    # "hop" (incremental_additions from a cached covering state), or "hit"
    # (exact cached state — zero anchor work)
    anchor_events: "list[str]"
    anchor_stats: "list[StreamStats]"    # per-campaign anchor acquisition
    hop_stats: "list[StreamStats]"       # per-campaign stacked launch
    wall_s: float
    added_edges: int                     # total window-hop Δ volume
    anchor_delta_edges: int              # Δ volume of incremental anchor hops
    lane_layout: "list[tuple[int, int]]"
    # the CampaignPlan that chose the partition (campaign_width="auto" only)
    plan: "CampaignPlan | None" = None
    # measured stable fraction (‰) over all window hops in the run: the
    # share of vertex-lanes the stability analysis kept out of the seed
    # frontier (graph/stability.py; padding lanes excluded)
    stable_milli: int = 0

    @property
    def anchor_rebuilds(self) -> int:
        """Count of from-scratch anchor fixpoints in this run."""
        return self.anchor_events.count("rebuild")

    @property
    def anchor_hops(self) -> int:
        """Count of incremental anchor hops in this run."""
        return self.anchor_events.count("hop")

    @property
    def anchor_hits(self) -> int:
        """Count of exact anchor cache hits (zero anchor work)."""
        return self.anchor_events.count("hit")


def _acquire_anchor_state(store: SnapshotStore, qkey: tuple, anchor: Window,
                          semiring: Semiring, source: int, max_iters: int,
                          gated: bool, cg_split: int, track_parents: bool,
                          seed: str = "instability", fused_k: int = 1):
    """Anchor state via cache hit, incremental hop, or from-scratch rebuild.

    Returns ``(anchor_view, state, stats, event, delta_edges)`` —
    ``delta_edges`` is the hop's Δ volume (0 on hit/rebuild). The view's
    blocks UNION to exactly T(anchor) in every case (anchor view on
    hit/rebuild, cover view ⊕ hop Δ after a hop) — per-sweep reductions are
    block-partition invariant, so downstream campaign results do not depend
    on which path ran. The acquired state is (re-)cached under the anchor's
    "AS" tag. ``fused_k`` only shapes the hop/rebuild launches (bit-identical
    states at any value), which is why it is a launch option and NOT part of
    ``qkey`` — states stay shareable across fused chunk sizes.
    """
    t0 = time.perf_counter()
    state = store.anchor_state_get(qkey, anchor)
    if state is not None:
        view = _anchor_view(store, anchor, cg_split)
        return view, state, StreamStats(time.perf_counter() - t0, 0.0, 0), \
            "hit", 0
    cover = store.anchor_state_cover(qkey, anchor)
    if cover is not None:
        cover_window, cover_state = cover
        delta = store.delta_block(cover_window, anchor)
        view = _anchor_view(store, cover_window, cg_split).extended(delta)
        res = incremental_additions(view, delta, semiring, cover_state.values,
                                    cover_state.parent, max_iters,
                                    gated=gated, track_parents=track_parents,
                                    seed=seed, fused_k=fused_k)
        host_sync(res.values)
        state = store.anchor_state_put(qkey, anchor, extract_state(res))
        delta_edges = (store.window_size(*anchor)
                       - store.window_size(*cover_window))
        return view, state, StreamStats(time.perf_counter() - t0,
                                        float(res.edge_work),
                                        int(res.iterations)), "hop", \
            delta_edges
    anchor_view, base, base_stats = _anchor_base(
        store, anchor, semiring, source, max_iters, gated, cg_split,
        track_parents, fused_k)
    state = store.anchor_state_put(qkey, anchor, extract_state(base))
    return anchor_view, state, base_stats, "rebuild", 0


# ---------------------------------------------------------------------------
# Anchor chains: overlapping streams sharing one anchor-state sequence.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AnchorChain:
    """A named, refcounted chain of nested anchor states shared by streams.

    One advancing stream leaves behind a *chain* of converged anchor states
    in the store's "AS" family — interval-nested, each reachable from the
    previous by pure additions. A second stream over an overlapping window
    region can hop off those same states instead of rebuilding its own
    anchors from scratch: that is the paper's shared-additions idea applied
    ACROSS streams, one level up from the per-stream reuse PR 4 built.

    The chain object adds the lifecycle the bare cache cannot express:

    * **Registration.** Streams :meth:`register` by name; pass
      ``chain=`` to ``run_window_stream_batched`` and the scheduler
      records every anchor it acquires as a chain link
      (:meth:`observe`) and reports the stream's progress
      (:meth:`advance`).
    * **Pinning (the refcount).** A link is pinned in the store
      (``SnapshotStore.pin``) while ANY registered stream is still behind
      it — behind meaning the stream's last consumed anchor-lo has not
      passed the link's lo, so the link may yet seed one of its hops.
      Pinned links survive LRU pressure and ``release``; once every
      registered stream advances past a link it is PRUNED from the chain
      and its state returns to the LRU, so chain bookkeeping stays
      O(live links) over an unbounded stream. :meth:`unregister` a
      finished stream or its pins leak. (With no streams registered the
      links stay listed — unpinned — so a later stream can still find
      the chain via :func:`select_chain`.)
    * **Cover selection.** :meth:`cover` returns the tightest chain link
      covering a window (same tightest-|T| rule as
      ``SnapshotStore.anchor_state_cover``, restricted to this chain's
      links); :func:`select_chain` picks among several chains the one
      whose cover is tightest — how a new stream finds the chain to
      register against.

    Pinning never changes values — only whether a lagging stream pays a
    hit/hop (link retained) or a rebuild (link evicted). The chain binds to
    the first query key it serves; overlapping streams share a chain only
    when their query (semiring, source, options) agrees, else
    :meth:`bind` raises.
    """

    store: SnapshotStore
    name: str = "chain"
    qkey: "tuple | None" = None
    links: "list[Window]" = dataclasses.field(default_factory=list)
    _positions: "dict[str, int | None]" = dataclasses.field(
        default_factory=dict)
    _pinned: "set[Window]" = dataclasses.field(default_factory=set)

    def bind(self, qkey: tuple) -> "AnchorChain":
        """Bind the chain to a query key (first use wins, mismatch raises)."""
        if self.qkey is None:
            self.qkey = qkey
        elif self.qkey != qkey:
            raise ValueError(
                f"chain {self.name!r} is bound to query key {self.qkey!r}; "
                f"a stream with query key {qkey!r} cannot share it")
        return self

    @staticmethod
    def _member(stream: "WindowStream | str") -> str:
        return stream if isinstance(stream, str) else stream.name

    def register(self, stream: "WindowStream | str") -> "AnchorChain":
        """Add a stream to the chain (idempotent); pins every current link
        until the stream advances past it."""
        name = self._member(stream)
        if name not in self._positions:
            self._positions[name] = None   # behind everything
            self._repin()
        return self

    def unregister(self, stream: "WindowStream | str") -> None:
        """Remove a stream; links only it was behind unpin (and, while
        other streams remain registered, are pruned)."""
        name = self._member(stream)
        if name not in self._positions:
            raise ValueError(f"stream {name!r} is not registered with "
                             f"chain {self.name!r}")
        del self._positions[name]
        self._repin()

    def registered(self) -> "list[str]":
        """Names of currently registered streams, sorted."""
        return sorted(self._positions)

    def cover(self, window: Window) -> "Window | None":
        """Tightest chain link whose interval covers ``window`` (else None).

        Same rule as ``SnapshotStore.anchor_state_cover`` — both route
        through ``tightest_cover`` — restricted to this chain's links.
        """
        return tightest_cover(self.links, tuple(window),
                              self.store.window_size)

    def observe(self, anchor: Window) -> None:
        """Record an acquired anchor state as a chain link (scheduler hook)."""
        anchor = tuple(anchor)
        if anchor not in self.links:
            self.links.append(anchor)
            self.links.sort(key=lambda w: (w[0], -w[1]))
            self._repin()

    def advance(self, stream: "WindowStream | str", anchor: Window) -> None:
        """Report a stream's last consumed anchor; passed links unpin."""
        name = self._member(stream)
        if name not in self._positions:
            raise ValueError(f"stream {name!r} is not registered with "
                             f"chain {self.name!r}")
        self._positions[name] = anchor[0]
        self._repin()

    def _repin(self) -> None:
        """Reconcile store pins with the is-any-stream-behind rule.

        While at least one stream is registered, links every stream has
        passed are also PRUNED from the chain (they can never seed a
        registered stream's hop again, and per-campaign ``cover``/pin
        bookkeeping must stay O(live links), not O(stream lifetime)) —
        their cached states simply return to the LRU. With no streams
        registered the links are kept (unpinned) so a later stream can
        still discover the chain via ``select_chain``.
        """
        want = set()
        if self._positions:
            positions = list(self._positions.values())
            want = {link for link in self.links
                    if any(pos is None or pos <= link[0]
                           for pos in positions)}
            self.links = [link for link in self.links if link in want]
        for link in want - self._pinned:
            self.store.pin(anchor_tag(self.qkey, link))
        for link in self._pinned - want:
            self.store.unpin(anchor_tag(self.qkey, link))
        self._pinned = want


def select_chain(chains: "list[AnchorChain]", window: Window,
                 qkey: "tuple | None" = None) -> "AnchorChain | None":
    """The chain whose links give the tightest cover of ``window``.

    How an arriving stream picks its chain: among ``chains`` (optionally
    filtered to a query key), the one holding the largest-|T| covering
    link — the cover that minimizes the stream's first anchor hop. Returns
    ``None`` when no chain covers the window (the stream then starts its
    own chain with one rebuild).
    """
    best, best_size = None, -1
    for chain in chains:
        if qkey is not None and chain.qkey is not None and chain.qkey != qkey:
            continue
        link = chain.cover(window)
        if link is not None:
            size = chain.store.window_size(*link)
            if size > best_size:
                best, best_size = chain, size
    return best


def run_window_stream_batched(
    store: SnapshotStore,
    semiring: Semiring,
    source: int,
    width: int | None = None,
    *,
    windows: "list[Window] | None" = None,
    stream: WindowStream | None = None,
    step: int = 1,
    start: int = 0,
    campaign_width: "int | str | None" = None,
    lane_budget: int = 8,
    chain: "AnchorChain | None" = None,
    max_iters: int = 10_000,
    gated: bool = False,
    cg_split: int = 1,
    track_parents: bool = False,
    mesh=None,
    seed: str = "instability",
    stable_milli: int = 0,
    cost_model=None,
    fused_k: int = 1,
) -> WindowStreamRun:
    """Streaming slide campaigns with incremental anchor maintenance.

    Consumes an advancing window sequence (``stream.take()``, an explicit
    ``windows`` list, or a ``slide_windows`` plan from ``width``), cuts it
    into campaigns of ``campaign_width`` windows (default 4; a
    ``WindowStream`` carries its own width, so passing both together is an
    error), and runs each campaign as
    ONE masked pow2-lane ``incremental_additions_batched`` launch (the
    ``run_window_slide_batched`` machinery, sharded over ``data`` when a
    mesh is given).

    ``campaign_width="auto"`` hands the partition to ``optimal_campaigns``:
    an interval DP over cut points minimizing total Δ-edge volume (slide
    hops + anchor hops + the pow2 masked-lane penalty), capped at
    ``lane_budget`` windows per launch and mesh-aware (the pad term uses
    the mesh's ``data`` extent). The chosen :class:`CampaignPlan` is
    returned on the run's ``plan`` field; ``lane_budget`` is only read in
    auto mode.

    ``chain=`` (requires ``stream=``) shares anchor states across
    OVERLAPPING streams via an :class:`AnchorChain`: the stream registers
    with the chain, every anchor it acquires becomes a chain link, and
    links stay pinned against eviction while any registered stream is
    still behind them — so a second stream over the same region hops off
    the first stream's anchors (strictly fewer rebuilds than running
    solo) with bit-identical values.

    Campaign k anchors at ``(lo_k, stream_hi)`` — its windows' span widened
    to the stream's last snapshot. Widening is what makes the anchor chain
    monotone: campaign k+1's anchor interval is nested in campaign k's, so
    its common graph is reachable from k's converged state by PURE
    ADDITIONS, and the scheduler seeds it with one incremental hop instead
    of recomputing from the base snapshot. Anchor states are cached in the
    store's "AS" LRU family, so only the first campaign (or a campaign
    whose predecessors were evicted, or one whose stream has advanced past
    every cached cover) pays a from-scratch rebuild.

    Results are bit-identical to running ``run_window_slide_batched`` cold
    per campaign with the same anchors; the streamed path just performs
    strictly fewer anchor rebuilds (1 + evictions vs one per campaign).

    ``seed`` picks the frontier-seeding mode for every hop in the run
    (``"instability"`` — the stable-vertex analysis, default — or
    ``"delta"``, the full-Δ baseline; values bit-identical either way).
    ``stable_milli`` is the PLANNER HINT: the modeled stable fraction (‰)
    ``optimal_campaigns`` discounts its Δ-volume atoms by in auto mode
    (e.g. a fraction measured by a prior run over the same load); the
    run's own measured fraction comes back on the result's
    ``stable_milli`` field regardless.

    ``cost_model`` upgrades the auto-mode planner from the discounted
    edge-count proxy to measured prices (core/costmodel.SweepCostModel,
    e.g. from ``evolve --calibrate``) — it is forwarded to
    ``optimal_campaigns`` and recorded on the returned plan; ignored
    outside auto mode. ``fused_k`` is the engine's fused-chunk launch
    option, threaded to every anchor acquisition and stacked slide launch
    in the run; results are bit-identical at any value, so it is NOT part
    of the anchor-state cache key.
    """
    t_all = time.perf_counter()
    if stream is not None:
        if windows is not None or width is not None:
            raise ValueError("pass stream= alone, not with width=/windows=")
        if campaign_width is not None:
            raise ValueError("campaign_width= conflicts with stream=: the "
                             "WindowStream carries its own campaign width")
        windows = stream.take()
        campaign_width = stream.campaign_width
    else:
        if chain is not None:
            raise ValueError("chain= requires stream=: an AnchorChain tracks "
                             "named WindowStreams, so anonymous window lists "
                             "cannot register against one")
        if campaign_width is None:
            campaign_width = 4
        if windows is None:
            if width is None:
                raise ValueError("pass width=, windows= or stream=")
            windows = slide_windows(store.seq.num_snapshots, width, step=step,
                                    start=start)
        windows = [tuple(w) for w in windows]
        _validate_advancing(windows)
    qkey = _stream_qkey(semiring, source, max_iters, gated, cg_split,
                        track_parents)
    if chain is not None:
        if chain.store is not store:
            raise ValueError("chain= must share the run's SnapshotStore — "
                             "anchor states live in the store's AS family")
        chain.bind(qkey).register(stream)
    if not windows:
        return WindowStreamRun({}, [], [], [], [], [],
                               time.perf_counter() - t_all, 0, 0, [])
    plan = None
    if campaign_width == CAMPAIGN_AUTO:
        plan = optimal_campaigns(
            store, windows, lane_budget=lane_budget,
            data_extent=mesh.shape["data"] if mesh is not None else 1,
            stable_milli=stable_milli, cost_model=cost_model)
        campaigns = plan.campaigns
    else:
        campaigns = stream_campaigns(windows, campaign_width)
    stream_hi = windows[-1][1]

    results: dict[Window, jnp.ndarray] = {}
    anchors: "list[Window]" = []
    anchor_events: "list[str]" = []
    anchor_stats: "list[StreamStats]" = []
    hop_stats: "list[StreamStats]" = []
    lane_layout: "list[tuple[int, int]]" = []
    added_edges = 0
    anchor_delta_edges = 0
    unstable_counts: "list[np.ndarray]" = []
    for campaign in campaigns:
        anchor = (min(i for i, _ in campaign), stream_hi)
        anchor_view, state, stats, event, delta_edges = _acquire_anchor_state(
            store, qkey, anchor, semiring, source, max_iters, gated, cg_split,
            track_parents, seed=seed, fused_k=fused_k)
        if chain is not None:
            chain.observe(anchor)   # pin before any later put can evict it
        anchors.append(anchor)
        anchor_events.append(event)
        anchor_stats.append(stats)
        anchor_delta_edges += delta_edges
        t0 = time.perf_counter()
        res, bucket = _slide_launch(store, semiring, anchor_view, state,
                                    campaign, anchor, max_iters=max_iters,
                                    gated=gated, track_parents=track_parents,
                                    mesh=mesh, seed=seed, fused_k=fused_k)
        hop_stats.append(StreamStats(time.perf_counter() - t0,
                                     float(jnp.sum(res.edge_work)),
                                     int(jnp.max(res.iterations))))
        lane_layout.append((len(campaign), bucket))
        unstable_counts.append(np.asarray(res.unstable)[:len(campaign)])
        for lane, wnd in enumerate(campaign):
            results[wnd] = res.values[lane]
        added_edges += _slide_added_edges(store, campaign, anchor)
        if chain is not None:
            chain.advance(stream, anchor)   # links all streams passed unpin
    return WindowStreamRun(results, campaigns, anchors, anchor_events,
                           anchor_stats, hop_stats,
                           time.perf_counter() - t_all, added_edges,
                           anchor_delta_edges, lane_layout, plan,
                           stable_milli=stable_fraction_milli(
                               np.concatenate(unstable_counts),
                               store.num_nodes))

"""Snapshot store: window intersections, Δ-batches, mutation-free views.

This is the paper's graph representation (§2, third contribution): the
CommonGraph of any window plus immutable Δ-batches. Key facts exploited:

* For nested windows ``[i..j] ⊇ [a..b]``: ``T(i,j) ⊆ T(a,b)`` — a wider
  window's common graph is a subgraph of a narrower one's. Hence descending
  the Triangular Grid only ever *adds* edges, and
  ``|Δ(T(i,j) → T(a,b))| = |T(a,b)| − |T(i,j)|``.
* Snapshots are the diagonal: ``S_i = T(i,i)``.

Set algebra runs host-side on sorted int64 key arrays (this is the part of
the system that, at cluster scale, becomes a distributed sort/merge over the
ingest pipeline; on one host numpy's merge-based set ops are the right tool).
Device-side execution consumes only the padded immutable blocks.

Store contract (what every executor may assume):

* **Pure cache.** Every block is a pure function of ``(seq, tag)``: evicting
  a block and re-fetching it rebuilds a bit-identical array (same edges,
  same dst-sort order, same padding). Eviction can therefore never change
  any executor's result, only its memory/rebuild cost.
* **Bounded device memory (opt-in).** ``cache_bytes`` puts the device-block
  cache under an LRU byte budget. The batched executors retain every
  shape-bucketed ``delta_stack`` lane buffer alongside the per-hop "D"
  blocks covering the same edges; memory-tight accelerators comparing both
  executors bound that with the budget, or drop a whole block family
  explicitly via :meth:`SnapshotStore.release`.
* **Shape bucketing.** Blocks are padded to granule buckets (pow2 by
  default) so jit trace shapes depend only on the bucket, not exact ragged
  sizes (see ``graph/edgeset.py``); stacked lane buffers additionally
  bucket their LANE axis (``delta_stack``/``slide_stack`` ``num_lanes=``,
  trailing lanes all-sentinel) so trace keys are ``(pow2 lanes, pow2
  width)`` and the lane axis always divides a mesh's ``data`` extent.
  Host-side key arrays (``window_keys``) are never evicted — they are the
  cheap part and keep rebuilds exact.
* **Anchor-state family ("AS" tags).** Converged anchor query states
  (``QueryState``) live in the SAME LRU alongside edge blocks — the first
  cross-launch reuse: a streaming campaign scheduler (core/window.py) seeds
  campaign k+1's anchor from campaign k's cached state instead of
  recomputing from the base snapshot. Values of a cached state are a pure
  function of ``(window, query key)`` (the monotone rounded fixpoint is
  unique), so eviction again costs only recompute, never correctness.
* **Pinning (refcounted eviction exemption).** ``pin``/``unpin`` exempt a
  tag from LRU eviction and from ``release``. The anchor-chain scheduler
  (core/window.py::AnchorChain) pins the chain links its registered
  streams are still behind, so a memory-tight store cannot evict a state a
  lagging overlapping stream is about to hop from; once every stream has
  advanced past a link it is unpinned and ages out normally. Pinning never
  affects results — only which path (hit/hop/rebuild) acquires a state.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np

from repro.graph.edgeset import (
    EdgeBlock,
    EdgeView,
    keys_to_edges,
    make_block,
    stack_delta_blocks,
)
from repro.graph.generators import EvolvingSequence


def tightest_cover(candidates, window, size_fn):
    """Largest-|T| candidate window covering ``window`` (None if none).

    THE cover rule, in one place: a state converged on ``(ci, cj)`` can
    warm-start ``window = (a, b)`` iff ``ci <= a and b <= cj`` (its common
    graph is a subgraph), and among covers the largest ``size_fn(ci, cj)``
    minimizes the hop's Δ volume. Shared by the store's AS-family scan
    (:meth:`SnapshotStore.anchor_state_cover`) and the anchor-chain link
    selection (core/window.py ``AnchorChain.cover``) so the two can never
    disagree about which cover is tightest.
    """
    a, b = window
    best, best_size = None, -1
    for cand in candidates:
        ci, cj = cand
        if ci <= a and b <= cj:
            size = size_fn(ci, cj)
            if size > best_size:
                best, best_size = cand, size
    return best


def anchor_tag(qkey: tuple, window: "tuple[int, int]") -> tuple:
    """The canonical "AS"-family cache tag for an anchor state.

    ``("AS", qkey, (i, j))`` — THE tag constructor for anchor states, used
    by the store's own ``anchor_state_*`` accessors and by external
    pin/unpin callers (core/window.py ``AnchorChain``). Tags are cache
    identity: a hand-built tuple that drifts from this shape (family
    string, qkey structure, list-vs-tuple window) silently misses the
    cached entry or pins nothing, which is why graphlint rule G003 bans
    literal tag construction outside this module.
    """
    return ("AS", qkey, tuple(window))


@dataclasses.dataclass(frozen=True)
class CompactionStats:
    """What one :meth:`SnapshotStore.compact` call retired.

    ``horizon`` is the first snapshot index kept live after clamping to
    every registered floor and pinned "AS" anchor; ``retired`` counts
    snapshots actually freed this call; ``freed_edges`` sums the host-side
    key/Δ array entries released. ``retired == 0`` (horizon already live,
    or clamped all the way back) is a legal no-op result.
    """

    horizon: int
    retired: int
    freed_edges: int


def _tag_min_index(tag: tuple) -> "int | None":
    """Smallest snapshot index a cached block tag depends on (None = keep).

    Families: ``("T", i, j)`` / ``("Ts", i, j, n, k)`` depend on ``i``;
    ``("D", parent, child)`` and ``("DS", lanes, *hops)`` on the smallest
    window low across their hop windows; ``("A", t)`` on transition ``t``
    (snapshot ``t`` → ``t+1``); ``("AS", qkey, (i, j))`` on ``i``. Unknown
    families are kept — compaction must never guess an entry stale.
    """
    fam = tag[0]
    if fam in ("T", "Ts"):
        return int(tag[1])
    if fam == "D":
        return min(int(tag[1][0]), int(tag[2][0]))
    if fam == "DS":
        return min(int(w[0]) for hop in tag[2:] for w in hop)
    if fam == "A":
        return int(tag[1])
    if fam == "AS":
        return int(tag[2][0])
    return None


def _block_nbytes(blk) -> int:
    # Cached entries that know their own footprint (engine QueryStates via
    # the ``nbytes`` hook) report it; raw EdgeBlocks are summed directly.
    n = getattr(blk, "nbytes", None)
    if n is not None:
        return int(n)
    return sum(int(a.size) * a.dtype.itemsize for a in blk)


class SnapshotStore:
    """Caches window common-graphs T(i,j) (key arrays) and device blocks.

    ``cache_bytes`` (default ``None`` = unbounded) bounds the device-block
    cache: least-recently-used blocks are dropped once the budget is
    exceeded (the block just built is always kept, even if it alone exceeds
    the budget — callers hold a reference to it anyway). ``release`` drops
    whole block families explicitly. Both are safe: re-fetching rebuilds
    bit-identical blocks from the retained host-side key arrays.
    """

    def __init__(self, seq: EvolvingSequence, granule: int = 4096,
                 pad_pow2: bool = True, cache_bytes: int | None = None):
        self.seq = seq
        self.num_nodes = seq.num_nodes
        self.granule = granule
        self.pad_pow2 = pad_pow2
        self.cache_bytes = cache_bytes
        self._t: dict[tuple[int, int], np.ndarray] = {
            (i, i): seq.snapshot_keys[i] for i in range(seq.num_snapshots)
        }
        self._blocks: OrderedDict[tuple, EdgeBlock] = OrderedDict()
        self._cached_nbytes = 0
        self._pins: dict[tuple, int] = {}   # tag -> refcount (see pin())
        self.evictions = 0  # lifetime count, for tests/benchmarks
        self.first_live = 0  # oldest non-retired snapshot (see compact())
        self._floors: dict[str, int] = {}   # name -> oldest index needed

    # -- block cache (LRU by bytes + explicit release) -------------------------

    @property
    def cached_nbytes(self) -> int:
        """Current device-block cache footprint (padded array bytes)."""
        return self._cached_nbytes

    def _cache_get(self, tag: tuple) -> EdgeBlock | None:
        blk = self._blocks.get(tag)
        if blk is not None:
            self._blocks.move_to_end(tag)
        return blk

    def _cache_put(self, tag: tuple, blk: EdgeBlock) -> EdgeBlock:
        # Overwriting an existing tag must displace the old entry's bytes,
        # or cached_nbytes drifts upward and triggers spurious evictions.
        old = self._blocks.pop(tag, None)
        if old is not None:
            self._cached_nbytes -= _block_nbytes(old)
        self._blocks[tag] = blk
        self._cached_nbytes += _block_nbytes(blk)
        if self.cache_bytes is not None and self._cached_nbytes > self.cache_bytes:
            # LRU order, skipping pinned tags and the entry just stored (the
            # caller holds a reference to it anyway).
            for old_tag in list(self._blocks):
                if self._cached_nbytes <= self.cache_bytes \
                        or len(self._blocks) <= 1:
                    break
                if old_tag == tag or self._pins.get(old_tag):
                    continue
                self._cached_nbytes -= _block_nbytes(self._blocks.pop(old_tag))
                self.evictions += 1
        return blk

    def pin(self, tag: tuple) -> None:
        """Exempt a cached entry from LRU eviction (refcounted).

        Pins nest: each ``pin`` must be matched by one :meth:`unpin` before
        the entry returns to normal LRU management. Pinning is by tag, so it
        survives the entry being overwritten (re-``put`` under the same tag)
        and is legal before the entry exists — the anchor-chain scheduler
        (core/window.py::AnchorChain) pins "AS" states its registered
        streams are still behind. Pinned entries still count toward
        ``cache_bytes``; :meth:`release` also skips them.
        """
        self._pins[tag] = self._pins.get(tag, 0) + 1

    def unpin(self, tag: tuple) -> None:
        """Drop one pin refcount; at zero the entry rejoins the LRU."""
        n = self._pins.get(tag, 0) - 1
        if n < 0:
            raise ValueError(f"unpin without matching pin for tag {tag!r}")
        if n == 0:
            del self._pins[tag]
        else:
            self._pins[tag] = n

    def pinned_tags(self) -> "set[tuple]":
        """Tags currently exempt from eviction (for tests/diagnostics)."""
        return set(self._pins)

    def pin_count(self, tag: tuple) -> int:
        """Current pin refcount of ``tag`` (0 when unpinned).

        Diagnostic mirror of the refcount :meth:`pin`/:meth:`unpin`
        maintain — the query-service soak tests audit that every pin taken
        while streams were live has drained back to zero after
        ``unregister``.
        """
        return self._pins.get(tag, 0)

    def release(self, kinds: "tuple[str, ...] | None" = None) -> int:
        """Drop cached device blocks; returns the number of bytes released.

        ``kinds`` filters by tag family — e.g. ``("DS",)`` drops only the
        stacked ``delta_stack`` buffers the batched executors built, leaving
        the sequential executors' per-hop "D" blocks warm, and ``("AS",)``
        drops cached anchor query states (the streaming scheduler then
        rebuilds its next anchor cold). ``None`` drops everything except
        pinned entries (:meth:`pin`) — a chain link some registered stream
        still needs cannot be dropped out from under it. Host-side key
        arrays are never dropped, so subsequent fetches rebuild
        bit-identical blocks.
        """
        if isinstance(kinds, str):  # release("DS") must not match family "D"
            kinds = (kinds,)
        drop = [t for t in self._blocks
                if (kinds is None or t[0] in kinds) and not self._pins.get(t)]
        freed = 0
        for t in drop:
            freed += _block_nbytes(self._blocks.pop(t))
        self._cached_nbytes -= freed
        return freed

    # -- anchor-state cache (cross-launch reuse, streaming campaigns) ----------
    #
    # Tags are ("AS", qkey, (i, j)): qkey identifies the query (semiring,
    # source, options — see core/window.py::_stream_qkey), (i, j) the anchor
    # window the state converged on. States share the LRU byte budget with
    # edge blocks: a cached anchor family can be evicted mid-stream, which
    # costs the scheduler one rebuild and never changes results (values are
    # the unique monotone fixpoint of (window, qkey)).

    def anchor_state_get(self, qkey: tuple, window: "tuple[int, int]"):
        """Cached converged QueryState for exactly this (qkey, window)."""
        return self._cache_get(anchor_tag(qkey, window))

    def anchor_state_put(self, qkey: tuple, window: "tuple[int, int]", state):
        """Cache a converged anchor state (LRU-participating, "AS" family)."""
        return self._cache_put(anchor_tag(qkey, window), state)

    def anchor_state_cover(self, qkey: tuple, window: "tuple[int, int]"):
        """Tightest cached anchor state whose window COVERS ``window``.

        A state converged on a super-window (i, j) ⊇ (a, b) warm-starts
        T(a, b) by pure additions (T(i,j) ⊆ T(a,b)); among cached covers the
        tightest — largest |T(cover)| — minimizes the Δ volume of the hop.
        Returns ``(cover_window, state)`` or ``None``. The exact window
        itself is excluded; use :meth:`anchor_state_get` for hits.
        """
        window = tuple(window)
        best = tightest_cover(
            [tag[2] for tag in self._blocks
             if tag[0] == "AS" and tag[1] == qkey and tag[2] != window],
            window, self.window_size)
        if best is None:
            return None
        return best, self._cache_get(anchor_tag(qkey, best))  # touches LRU

    # -- window intersections -------------------------------------------------

    def window_keys(self, i: int, j: int) -> np.ndarray:
        """Sorted keys of T(i,j) = ⋂_{k∈[i..j]} S_k (cached, built left-to-right).

        Iterative from the widest cached prefix (i, k): a cold (0, n−1)
        request on a multi-thousand-snapshot sequence must not hit the
        Python recursion limit. (i, i) is always cached, so the prefix scan
        terminates.
        """
        if (i, j) in self._t:
            return self._t[(i, j)]
        if j < i:
            raise ValueError(f"window ({i}, {j}) is empty: need i <= j")
        if i < self.first_live:
            raise ValueError(
                f"window ({i}, {j}) reaches below first_live="
                f"{self.first_live}: snapshot {i} was retired by compact()")
        k = j
        while (i, k) not in self._t:
            k -= 1
        cur = self._t[(i, k)]
        for m in range(k + 1, j + 1):
            cur = np.intersect1d(cur, self.seq.snapshot_keys[m],
                                 assume_unique=True)
            self._t[(i, m)] = cur
        return cur

    def window_size(self, i: int, j: int) -> int:
        """|T(i, j)| — the edge count every Δ-volume cost model uses."""
        return int(self.window_keys(i, j).shape[0])

    def delta_keys(self, parent: tuple[int, int], child: tuple[int, int]) -> np.ndarray:
        """Edges added when descending T(parent) → T(child); child ⊆ parent window."""
        pi, pj = parent
        ci, cj = child
        if not (pi <= ci and cj <= pj):
            raise ValueError(f"child window {child} not nested in parent {parent}")
        return np.setdiff1d(self.window_keys(ci, cj), self.window_keys(pi, pj),
                            assume_unique=True)

    # -- device blocks ---------------------------------------------------------

    def block_for_keys(self, keys: np.ndarray, tag: tuple) -> EdgeBlock:
        """Immutable padded device block for a key set (cached by tag)."""
        blk = self._cache_get(tag)
        if blk is not None:
            return blk
        src, dst = keys_to_edges(keys, self.num_nodes)
        w = self.seq.weights_for(keys)
        blk = make_block(src, dst, w, self.num_nodes, granule=self.granule,
                         pad_pow2=self.pad_pow2)
        return self._cache_put(tag, blk)

    def window_block(self, i: int, j: int) -> EdgeBlock:
        """T(i, j) as a single cached device block (tag family "T")."""
        return self.block_for_keys(self.window_keys(i, j), ("T", i, j))

    def window_view_split(self, i: int, j: int, n_blocks: int) -> EdgeView:
        """Window view split into src-contiguous sub-blocks.

        Keys are src-major, so each sub-block covers a narrow source range —
        which makes the engine's block gating (frontier ∩ block sources)
        highly selective during incremental hops (EXPERIMENTS.md §Perf).
        """
        keys = self.window_keys(i, j)
        chunks = np.array_split(keys, n_blocks)
        blocks = tuple(
            self.block_for_keys(c, ("Ts", i, j, n_blocks, k))
            for k, c in enumerate(chunks) if c.size)
        return EdgeView(blocks, self.num_nodes)

    def delta_block(self, parent: tuple[int, int], child: tuple[int, int]) -> EdgeBlock:
        """The addition batch of one nested-window hop (tag family "D")."""
        return self.block_for_keys(self.delta_keys(parent, child),
                                   ("D", parent, child))

    def delta_stack(
        self, hops: "list[tuple[tuple[int, int], tuple[int, int]]]",
        num_lanes: int | None = None,
    ) -> EdgeBlock:
        """Stacked Δ-batches for several parent→child hops (one lane per hop).

        The lanes of one plan level are independent sibling hops; stacking
        them (shape-bucketed, see ``stack_delta_blocks``) turns the level
        into a single snapshot-axis launch of the batched engine. Cached by
        the hop list so re-running a plan rebuilds nothing.

        ``num_lanes`` buckets the LANE axis: the batched executors pass
        ``lane_bucket(len(hops), data_extent)`` so every stack's lane count
        is pow2 and mesh-divisible, with trailing all-sentinel masked lanes
        (see ``stack_delta_blocks``). The bucketed lane count is part of the
        cache tag, so trace keys — which follow the stacked shape — become
        ``(pow2 lanes, pow2 width)``.
        """
        tag = ("DS", num_lanes or len(hops)) + tuple(hops)
        blk = self._cache_get(tag)
        if blk is not None:
            return blk
        lanes = []
        for parent, child in hops:
            keys = self.delta_keys(parent, child)
            s, d = keys_to_edges(keys, self.num_nodes)
            lanes.append((s, d, self.seq.weights_for(keys)))
        blk = stack_delta_blocks(lanes, self.num_nodes, granule=self.granule,
                                 pad_pow2=self.pad_pow2, num_lanes=num_lanes)
        return self._cache_put(tag, blk)

    def snapshot_view(self, i: int) -> EdgeView:
        """Standalone single-block view of S_i (used by from-scratch baselines)."""
        return EdgeView((self.window_block(i, i),), self.num_nodes)

    def common_graph_view(self, i: int | None = None,
                          j: int | None = None) -> EdgeView:
        """Single-block view of T(i, j); defaults to the global common graph
        over the live range (``first_live`` .. last snapshot)."""
        if i is None:
            i = self.first_live
        if j is None:
            j = self.seq.num_snapshots - 1
        return EdgeView((self.window_block(i, j),), self.num_nodes)

    # -- change batches (for the KickStarter streaming baseline) ---------------

    def addition_block(self, t: int) -> EdgeBlock:
        """Edges added at transition t → t+1."""
        return self.block_for_keys(self.seq.additions[t], ("A", t))

    def deletion_keys(self, t: int) -> np.ndarray:
        """Keys deleted at transition t → t+1 (KickStarter baseline input)."""
        return self.seq.deletions[t]

    # -- live ingestion (core/ingest.py) ---------------------------------------
    #
    # The one write path that grows the store after construction. A live
    # store wraps a mutable sequence (ingest.LiveSequence); `ingest_cut`
    # appends one snapshot + canonical Δ pair per watermark cut, and
    # `compact` retires snapshots no registered window floor or pinned "AS"
    # anchor still needs. Graphlint rule G009 confines `ingest_cut` calls to
    # `Watermark.cut` and bans ad-hoc cache writes from ingestion paths.

    def ingest_cut(self, keys: np.ndarray, added: np.ndarray,
                   deleted: np.ndarray, common: "np.ndarray | None" = None,
                   common_lo: "int | None" = None) -> int:
        """Install one cut snapshot + Δ pair; returns its index.

        The ingestion write path (called from ``ingest.Watermark.cut`` —
        graphlint G009 flags any other caller): appends to the live
        sequence, registers the new diagonal ``(idx, idx)`` in the window
        cache (``window_keys``' prefix scan requires every diagonal), and,
        when the watermark passes its incrementally maintained common
        graph (``common`` spanning ``[common_lo .. idx]``), installs it so
        anchor queries at the live base pay no re-intersection. Requires a
        mutable sequence (``ingest.LiveSequence``); a frozen
        ``EvolvingSequence`` store is input-only and raises ``TypeError``.
        """
        append = getattr(self.seq, "append", None)
        if append is None:
            raise TypeError(
                "ingest_cut needs a mutable live sequence "
                "(ingest.LiveSequence); EvolvingSequence stores are "
                "precomputed inputs")
        idx = append(keys, added, deleted)
        self._t[(idx, idx)] = keys
        if common is not None and common_lo is not None and common_lo != idx:
            self._t[(common_lo, idx)] = common
        return idx

    def set_floor(self, name: str, index: int) -> None:
        """Register/advance a named compaction floor: "snapshots older than
        ``index`` are no longer needed by this consumer". ``compact`` clamps
        its horizon to the minimum registered floor, so a consumer that
        never advances its floor simply prevents retirement."""
        self._floors[name] = int(index)

    def drop_floor(self, name: str) -> None:
        """Withdraw a named floor (missing names are a no-op)."""
        self._floors.pop(name, None)

    @property
    def stored_edges(self) -> int:
        """Host-side edge entries currently stored (snapshot keys + Δ pairs).

        The compaction yardstick: ``compact`` must strictly shrink this
        when it retires anything (acceptance criterion of the ingestion
        PR). Retired entries are ``None`` and count zero.
        """
        seq = self.seq
        arrays = list(seq.snapshot_keys) + list(seq.additions) \
            + list(seq.deletions)
        return sum(int(a.shape[0]) for a in arrays if a is not None)

    def compact(self, before: "int | None" = None) -> CompactionStats:
        """Retire snapshots older than every consumer still needs.

        The horizon starts at ``before`` (default: the latest snapshot)
        and clamps DOWN to (a) every floor registered via
        :meth:`set_floor` — live window feeds keep the snapshots their
        unconsumed windows span — and (b) every pinned "AS" anchor's
        window low: a pinned anchor state is a promise some stream will
        hop from it, and the hop's Δ keys need the anchor window's
        intersection. Snapshots below the clamped horizon are freed
        (host key/Δ arrays become ``None`` placeholders so absolute
        indices never shift), stale window-cache entries and device
        blocks referencing them are purged (pinned tags skipped — they are
        unreachable only until unpinned), and ``first_live`` advances.
        Requires a mutable live sequence, like :meth:`ingest_cut`.
        """
        seq = self.seq
        if not isinstance(seq.snapshot_keys, list):
            raise TypeError(
                "compact needs a mutable live sequence "
                "(ingest.LiveSequence); EvolvingSequence stores are "
                "precomputed inputs")
        horizon = seq.num_snapshots - 1 if before is None else int(before)
        for floor in self._floors.values():
            horizon = min(horizon, floor)
        for tag in self._pins:
            if tag[0] == "AS":
                horizon = min(horizon, int(tag[2][0]))
        horizon = max(horizon, self.first_live)
        freed = 0
        for i in range(self.first_live, horizon):
            freed += int(seq.snapshot_keys[i].shape[0])
            seq.snapshot_keys[i] = None
            if seq.additions[i] is not None:
                freed += int(seq.additions[i].shape[0])
                freed += int(seq.deletions[i].shape[0])
                seq.additions[i] = None
                seq.deletions[i] = None
        retired = horizon - self.first_live
        if retired:
            for w in [w for w in self._t if w[0] < horizon]:
                del self._t[w]
            for tag in list(self._blocks):
                low = _tag_min_index(tag)
                if low is not None and low < horizon \
                        and not self._pins.get(tag):
                    self._cached_nbytes -= _block_nbytes(
                        self._blocks.pop(tag))
            self.first_live = horizon
        return CompactionStats(horizon=horizon, retired=retired,
                               freed_edges=freed)

    # -- sliding windows (full-paper feature) -----------------------------------
    #
    # Sliding [i..j] → [i+1..j+1] is NOT deletion-free from the old apex:
    # T(i,j) ⊄ T(i+1,j+1) in general (an old-CG edge may be absent from
    # S_{j+1}). The sound anchor is any SUPER-window apex — widest available
    # is the global CG, which is ⊆ every window's CG — from which every new
    # window apex is reachable by additions only. ``slide_block`` packages
    # that hop; it is just delta_block with the anchor made explicit, so all
    # nesting validation and caching carry over.

    def slide_block(self, new_window: tuple[int, int],
                    anchor: tuple[int, int] | None = None) -> EdgeBlock:
        """Addition batch hopping the anchor apex state to ``new_window``'s apex.

        ``anchor`` defaults to the global window (always a valid super-window).
        The anchor's query state warm-starts the new apex exactly (monotone
        additions only) — see tests/test_core.py::test_sliding_window_hop.
        """
        if anchor is None:
            anchor = (self.first_live, self.seq.num_snapshots - 1)
        return self.delta_block(anchor, new_window)

    def slide_stack(self, windows: "list[tuple[int, int]]",
                    anchor: tuple[int, int] | None = None,
                    num_lanes: int | None = None) -> EdgeBlock:
        """Stacked slide deltas: one lane per window, all hopping from ``anchor``.

        The batched window-slide executor's block assembly: every
        ``slide_block(window, anchor)`` becomes one lane of a single stacked
        EdgeBlock (shape-bucketed like any ``delta_stack``), so the whole
        slide runs as ONE ``incremental_additions_batched`` launch
        (core/window.py). ``anchor`` defaults to the global window;
        ``num_lanes`` buckets the lane axis exactly as in ``delta_stack``.
        """
        if anchor is None:
            anchor = (self.first_live, self.seq.num_snapshots - 1)
        return self.delta_stack([(anchor, w) for w in windows],
                                num_lanes=num_lanes)

"""Measured-cost calibration for the Δ-volume planners (SweepCostModel).

The TG interval DP (core/trigrid.py::optimal_plan) and the campaign DP
(core/window.py::optimal_campaigns) are exact optimizers — but over a
*proxy* objective: raw added-edge counts, latterly discounted by the
measured stable fraction (PR 8's ``stable_milli``). The proxy assumes a
hop's cost is proportional to its Δ volume with zero per-launch overhead,
which the fused-sweep work (kernels/edge_relax_multi) makes visibly wrong:
once convergence checks stop round-tripping HBM, the fixed per-sweep price
shrinks while the per-edge price stays, so plans that trade a few more
hops for less Δ volume (or vice versa) flip order.

:class:`SweepCostModel` closes the loop: an affine cost

    hop_cost(Δ)  =  per_edge_nanos · live(Δ)  +  per_sweep_nanos

fit from *measured* sweep timings (``evolve --calibrate``), where
``live(Δ)`` is the stable-vertex discount the planners already apply
(``Δ · (1000 − stable_milli) / 1000``, integer arithmetic). Both
coefficients are integers so DP costs remain exact integer prices — two
plans compare the same way on every host, which is what lets the benches
gate "calibrated plan never worse than the raw-count plan" as a schema-v2
exact field (benchmarks/run.py::bench_kernels).

With ``cost_model=None`` every planner prices exactly as before; a model
with ``per_edge_nanos=1, per_sweep_nanos=0`` reproduces the raw/discounted
edge-count objective identically.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Sequence

from repro.graph.engine import host_sync, run_to_fixpoint


def _instability_volume(edges: int, stable_milli: int) -> int:
    """The planners' live-edge discount (see core/window.py)."""
    if not 0 <= stable_milli <= 1000:
        raise ValueError(f"stable_milli {stable_milli} outside [0, 1000]")
    return edges * (1000 - stable_milli) // 1000


@dataclasses.dataclass(frozen=True)
class SweepCostModel:
    """Affine measured cost of one incremental hop, in integer nanoseconds.

    ``per_edge_nanos`` is the marginal price of streaming one live Δ edge
    through a frontier-masked sweep; ``per_sweep_nanos`` is the fixed
    per-launch price (dispatch + convergence check — what the fused kernel
    amortizes over ``fused_k`` sweeps); ``stable_milli`` folds in the
    stable-vertex discount the planners previously applied to raw counts.
    """

    per_edge_nanos: int
    per_sweep_nanos: int
    stable_milli: int = 0

    def hop_cost(self, added_edges: int) -> int:
        """Price of an incremental hop streaming ``added_edges`` Δ edges."""
        live = _instability_volume(added_edges, self.stable_milli)
        return live * self.per_edge_nanos + self.per_sweep_nanos

    def anchor_cost(self, edges: int) -> int:
        """Price of a from-scratch anchor build over ``edges`` edges.

        Undiscounted — a cold anchor has no stable incumbent state to
        skip, mirroring the raw planners' undiscounted first-anchor term.
        """
        return edges * self.per_edge_nanos + self.per_sweep_nanos

    @classmethod
    def fit(cls, samples: Sequence[tuple[int, int]], *,
            stable_milli: int = 0) -> "SweepCostModel":
        """Least-squares affine fit from ``(edges, nanos)`` measurements.

        Needs >= 2 samples at distinct edge scales for a full affine fit;
        with a degenerate spread it falls back to a pure per-edge model.
        Coefficients are rounded to integers, ``per_edge_nanos`` clamped to
        >= 1 so a hop's price always grows with its Δ volume.
        """
        if not samples:
            raise ValueError("SweepCostModel.fit needs at least one sample")
        xs = [float(e) for e, _ in samples]
        ys = [float(t) for _, t in samples]
        n = len(samples)
        mx = sum(xs) / n
        my = sum(ys) / n
        var = sum((x - mx) ** 2 for x in xs)
        if var == 0.0:
            per_edge = max(1, round(my / mx)) if mx else 1
            return cls(per_edge, 0, stable_milli)
        slope = sum((x - mx) * (y - my) for x, y in zip(xs, ys)) / var
        per_edge = max(1, round(slope))
        per_sweep = max(0, round(my - slope * mx))
        return cls(per_edge, per_sweep, stable_milli)


def measure_sweep_nanos(view, semiring, source, *, gated: bool = False,
                        track_parents: bool = False, fused_k: int = 1,
                        repeats: int = 3) -> int:
    """Measured wall nanoseconds of ONE frontier-masked sweep over ``view``.

    Converges the query once (untimed, also the jit warm-up), then times a
    warm all-on-frontier re-sweep capped at a single iteration — a full
    pass over every edge that improves nothing, i.e. exactly the per-sweep
    price the planners buy per unit of Δ volume. Best-of-``repeats``
    through the public engine API (the fused launch path when
    ``fused_k`` > 1), synced via the sanctioned :func:`host_sync`.
    """
    base = run_to_fixpoint(view, semiring, source, gated=gated,
                           track_parents=track_parents, fused_k=fused_k)
    host_sync(base.values)

    def once() -> int:
        t0 = time.perf_counter_ns()
        res = run_to_fixpoint(view, semiring, source, 1, values=base.values,
                              parent=base.parent, gated=gated,
                              track_parents=track_parents, fused_k=fused_k)
        host_sync(res.values)
        return time.perf_counter_ns() - t0

    once()  # compile the warm-start trace before timing
    return min(once() for _ in range(repeats))


def calibrate(store, semiring, source, *, stable_milli: int = 0,
              gated: bool = False, track_parents: bool = False,
              fused_k: int = 1, repeats: int = 3) -> SweepCostModel:
    """Fit a :class:`SweepCostModel` from two measured sweep scales.

    Times one sweep over the store's common graph (the smallest window
    view) and one over its first snapshot (common graph ∪ its Δs), giving
    two honestly different edge scales on the exact views the executors
    launch. ``stable_milli`` (from a prior measured run, e.g. the warm-up
    stream in ``evolve --calibrate``) is folded into the returned model's
    hop discount.
    """
    last = store.seq.num_snapshots - 1
    scales = [(0, last), (0, 0)]
    samples = []
    for (i, j) in scales:
        edges = store.window_size(i, j)
        nanos = measure_sweep_nanos(
            store.common_graph_view(i, j), semiring, source, gated=gated,
            track_parents=track_parents, fused_k=fused_k, repeats=repeats)
        samples.append((edges, nanos))
    return SweepCostModel.fit(samples, stable_milli=stable_milli)

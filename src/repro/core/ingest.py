"""Live ingestion: snapshots born from an edge firehose.

Everything upstream of this module assumes snapshots and Δ-batches are
*precomputed inputs* (``make_evolving_sequence`` → ``SnapshotStore``).
The real evolving-graph scenario is the other way around: a stream of
edge **events** arrives (GraphOne's fine-grained ingestion with
analytics-chosen visibility, SNIPPETS.md §3; the Besta et al.
streaming-graph-systems survey, PAPERS.md), and snapshots are *cut* from
it. This module is that ingestion layer — the event side of the
CommonGraph machinery:

* :class:`EdgeLog` — the append-only event log. ``append(src, dst, w,
  op, ts)`` records add/delete events with bounded-buffer backpressure
  (``max_pending_events`` + a block/drop/spill policy, all surfaced in
  :class:`IngestMetrics`).
* :class:`Watermark` — visibility control. ``advance(ts)`` moves the
  watermark monotonically; ``cut()`` consumes every buffered event at or
  below it (in timestamp order, last-op-wins per edge) and materializes
  ONE new snapshot + canonical Δ-batch pair into the
  :class:`~repro.core.snapshots.SnapshotStore` via
  ``SnapshotStore.ingest_cut`` — the only sanctioned write path
  (graphlint rule G009).
* **Online common-graph maintenance.** The paper's
  deletion-to-addition conversion, done incrementally: the running
  common graph obeys ``T(lo, k+1) = T(lo, k) ∖ dels_k`` (a cut's applied
  additions are disjoint from the previous snapshot, so they can never
  enter the intersection), so each cut *shrinks* the common-graph lower
  bound by exactly its deletions — additions only ever land in the
  per-snapshot Δ-batches, exactly as the batch formulation converts
  every deletion into downstream additions. The shrinkage is metered
  (``common_shrinkage``) and the maintained intersection is installed in
  the store's window cache so anchor queries at the live base pay no
  re-intersection.
* :class:`LiveSequence` — the mutable, duck-typed counterpart of
  ``EvolvingSequence`` a live store grows over
  (``SnapshotStore(LiveSequence(n))``); weights remain a pure hash of
  the edge key, so an edge deleted and re-added keeps its weight and a
  replayed trace is bit-identical to its precomputed counterpart.
* :class:`LiveWindowFeed` — the bridge to the query layers: emits each
  slide window the moment its last snapshot is cut, so a
  ``WindowStream`` (or ``QueryService`` client) registered with
  ``feed=`` blocks on the watermark instead of a precomputed window
  list, and registers a compaction floor for the snapshots its pending
  windows still need.
* :func:`events_from_sequence` / :func:`replay_events` — seeded trace
  replay: flatten an ``EvolvingSequence`` into events and drive
  log → watermark → cuts, one snapshot per distinct timestamp. The
  acceptance contract (tests/test_ingest.py, ``bench_ingest``): replayed
  snapshots, Δ-batches and query results across all five semirings are
  bit-identical to the precomputed-input path.

Retirement is the inverse of birth: ``SnapshotStore.compact`` (driven
here via :meth:`Watermark.compact`) retires snapshots that have fallen
out of every registered window floor and every pinned "AS" anchor,
folding their storage back — strictly fewer stored edges, metered as
``compactions``/``retired_snapshots``/``freed_edges``.

docs/INGESTION.md is the doctested guide to this module.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import NamedTuple

import numpy as np

from repro.core.snapshots import SnapshotStore
from repro.graph.edgeset import edge_keys, keys_to_edges, merge_changes
from repro.graph.generators import edge_weights

#: Legal event operations.
OPS = ("add", "del")

#: Legal backpressure policies for a bounded :class:`EdgeLog`.
POLICIES = ("block", "drop", "spill")

_FEED_COUNTER = itertools.count()


class BackpressureStall(RuntimeError):
    """Raised by ``EdgeLog.append`` under the ``"block"`` policy when the
    pending buffer is full — the producer must cut (or drop) before
    appending more. Each raise is metered as one ``stalls``."""


class EdgeEvent(NamedTuple):
    """One immutable edge event: ``(ts, src, dst, op, w)``.

    ``op`` is ``"add"`` or ``"del"``; ``w`` is an optional payload weight
    recorded for provenance — materialized blocks derive weights from the
    edge key (``edge_weights``), which is what keeps a deleted-then-
    re-added edge's weight stable and replay bit-identical to the
    precomputed path.
    """

    ts: int
    src: int
    dst: int
    op: str = "add"
    w: "float | None" = None


@dataclasses.dataclass
class IngestMetrics:
    """Ingestion counters, shared by one log/watermark pair.

    Every field is a deterministic integer for a fixed event trace —
    exactly what ``bench_ingest`` gates as schema-v2 exact fields:
    ``events`` (accepted appends, spilled included), ``late_events``
    (rejected: at or below the last cut), ``stalls``/``dropped``/
    ``spilled`` (backpressure, per policy), ``cuts``,
    ``applied_additions``/``applied_deletions`` (edges that actually
    changed a snapshot), ``redundant_events`` (no-ops: add of present,
    del of absent, or superseded by a later same-edge event in the same
    cut), ``common_shrinkage`` (edges deletions removed from the running
    common graph), and the compaction trio ``compactions``/
    ``retired_snapshots``/``freed_edges``.
    """

    events: int = 0
    late_events: int = 0
    stalls: int = 0
    dropped: int = 0
    spilled: int = 0
    cuts: int = 0
    applied_additions: int = 0
    applied_deletions: int = 0
    redundant_events: int = 0
    common_shrinkage: int = 0
    compactions: int = 0
    retired_snapshots: int = 0
    freed_edges: int = 0


@dataclasses.dataclass
class LiveSequence:
    """A mutable evolving sequence a live ``SnapshotStore`` grows over.

    Duck-types ``repro.graph.generators.EvolvingSequence`` (``num_nodes``,
    ``snapshot_keys``, ``additions``, ``deletions``, ``weights_for``,
    ``num_snapshots``) but holds *lists* that ``append`` extends — the
    store reads ``num_snapshots`` dynamically, so snapshots cut after the
    store was built are fully first-class. Compaction may replace retired
    entries with ``None`` placeholders; absolute snapshot indices never
    shift. Weights are the same pure key hash as the precomputed path
    (``weight_seed``), which is what makes live replay bit-identical to
    ``make_evolving_sequence`` inputs.
    """

    num_nodes: int
    snapshot_keys: "list[np.ndarray | None]" = dataclasses.field(
        default_factory=list)
    additions: "list[np.ndarray | None]" = dataclasses.field(
        default_factory=list)
    deletions: "list[np.ndarray | None]" = dataclasses.field(
        default_factory=list)
    weight_seed: int = 0

    @property
    def num_snapshots(self) -> int:
        """Snapshots cut so far (compaction never shrinks this)."""
        return len(self.snapshot_keys)

    def weights_for(self, keys: np.ndarray) -> np.ndarray:
        """Per-edge weights: the same pure key hash as EvolvingSequence."""
        return edge_weights(keys, self.weight_seed)

    def append(self, keys: np.ndarray, added: np.ndarray,
               deleted: np.ndarray) -> int:
        """Append one cut snapshot + its transition Δ pair; returns its index.

        The first snapshot has no incoming transition, so ``added``/
        ``deleted`` are recorded only from the second snapshot on —
        keeping ``len(additions) == num_snapshots - 1`` exactly like
        ``EvolvingSequence``. Reached only via ``SnapshotStore.ingest_cut``
        (graphlint G009 flags other callers).
        """
        idx = len(self.snapshot_keys)
        self.snapshot_keys.append(keys)
        if idx > 0:
            self.additions.append(added)
            self.deletions.append(deleted)
        return idx


class EdgeLog:
    """Append-only edge-event log with bounded-buffer backpressure.

    Producers call :meth:`append` (or :meth:`extend`); the paired
    :class:`Watermark` consumes buffered events at each ``cut()``. Events
    may arrive out of timestamp order as long as they are above the last
    cut's watermark — at or below it they are **late**, rejected and
    metered (``late_events``).

    ``max_pending_events`` bounds the pending buffer; ``policy`` picks
    what happens at the bound:

    * ``"block"`` — refuse the event: meter one ``stalls`` and raise
      :class:`BackpressureStall`; the producer must cut first.
    * ``"drop"`` — discard the event (lossy), metered as ``dropped``.
    * ``"spill"`` — divert to an unbounded spill buffer (lossless,
      metered as ``spilled``); spilled events rejoin at the next cut in
      timestamp-then-arrival order, so results stay deterministic.
    """

    def __init__(self, num_nodes: int, *,
                 max_pending_events: "int | None" = None,
                 policy: str = "block",
                 metrics: "IngestMetrics | None" = None):
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, "
                             f"got {policy!r}")
        if max_pending_events is not None and max_pending_events < 1:
            raise ValueError(f"max_pending_events must be >= 1, "
                             f"got {max_pending_events}")
        self.num_nodes = num_nodes
        self.max_pending_events = max_pending_events
        self.policy = policy
        self.metrics = metrics if metrics is not None else IngestMetrics()
        self._pending: "list[tuple[int, EdgeEvent]]" = []  # (arrival, event)
        self._spill: "list[tuple[int, EdgeEvent]]" = []
        self._arrivals = itertools.count()
        self._sealed_ts: "int | None" = None   # last cut watermark
        self._latest_ts = 0                    # default-ts tick

    def append(self, src: int, dst: int, w: "float | None" = None,
               op: str = "add", ts: "int | None" = None) -> "EdgeEvent | None":
        """Record one edge event; returns it, or ``None`` if rejected.

        ``ts=None`` stamps the latest timestamp seen so far (0 initially)
        — events belong to the current tick until the producer stamps a
        later one. Late events (``ts`` at or below the last cut) are
        rejected and metered; a full buffer applies the backpressure
        policy (see class docstring).
        """
        if op not in OPS:
            raise ValueError(f"op must be one of {OPS}, got {op!r}")
        if not (0 <= src < self.num_nodes and 0 <= dst < self.num_nodes):
            raise ValueError(f"edge ({src}, {dst}) out of range for "
                             f"{self.num_nodes} nodes")
        if ts is None:
            ts = self._latest_ts
        ts = int(ts)
        if self._sealed_ts is not None and ts <= self._sealed_ts:
            self.metrics.late_events += 1
            return None
        event = EdgeEvent(ts, int(src), int(dst), op,
                          None if w is None else float(w))
        if (self.max_pending_events is not None
                and len(self._pending) >= self.max_pending_events):
            if self.policy == "block":
                self.metrics.stalls += 1
                raise BackpressureStall(
                    f"EdgeLog pending buffer full "
                    f"({self.max_pending_events} events): cut the "
                    "watermark before appending more")
            if self.policy == "drop":
                self.metrics.dropped += 1
                return None
            self.metrics.spilled += 1
            self._spill.append((next(self._arrivals), event))
        else:
            self._pending.append((next(self._arrivals), event))
        self.metrics.events += 1
        self._latest_ts = max(self._latest_ts, ts)
        return event

    def extend(self, events) -> int:
        """Append an iterable of :class:`EdgeEvent`; returns the accepted count.

        Backpressure applies per event (a ``"block"`` stall propagates);
        late/dropped events do not count.
        """
        accepted = 0
        for ev in events:
            if self.append(ev.src, ev.dst, w=ev.w, op=ev.op,
                           ts=ev.ts) is not None:
                accepted += 1
        return accepted

    def pending_events(self) -> int:
        """Events buffered (pending + spilled) and not yet cut."""
        return len(self._pending) + len(self._spill)

    def _take_upto(self, ts: int) -> "list[EdgeEvent]":
        """Remove and return every buffered event with ``event.ts <= ts``,
        sorted by (timestamp, arrival order) — the cut's deterministic
        consumption order, spill included."""
        taken, kept_p, kept_s = [], [], []
        for bucket, kept in ((self._pending, kept_p), (self._spill, kept_s)):
            for arrival, ev in bucket:
                (taken if ev.ts <= ts else kept).append((arrival, ev))
        self._pending, self._spill = kept_p, kept_s
        taken.sort(key=lambda item: (item[1].ts, item[0]))
        return [ev for _, ev in taken]

    def _seal(self, ts: int) -> None:
        """Mark ``ts`` consumed: later appends at or below it are late."""
        if self._sealed_ts is None or ts > self._sealed_ts:
            self._sealed_ts = ts


class Watermark:
    """Watermark-based snapshot cuts over one ``EdgeLog``/``SnapshotStore``.

    ``advance(ts)`` declares "every event at or below ``ts`` has
    arrived"; ``cut()`` then materializes those events as ONE new
    snapshot + Δ-batch pair — the only sanctioned
    ``SnapshotStore.ingest_cut`` call site (graphlint rule G009).
    Between cuts the watermark also maintains the running common graph
    online (module docstring: ``T(lo, k+1) = T(lo, k) ∖ dels_k``) and,
    via :meth:`compact`, drives snapshot retirement.
    """

    def __init__(self, log: EdgeLog, store: SnapshotStore):
        self.log = log
        self.store = store
        self.metrics = log.metrics
        self._ts: "int | None" = None
        self._common: "np.ndarray | None" = None
        self._common_lo = 0

    @property
    def ts(self) -> "int | None":
        """Current watermark timestamp (``None`` before any advance)."""
        return self._ts

    def advance(self, ts: int) -> "Watermark":
        """Move the watermark forward (monotone; regressions raise)."""
        ts = int(ts)
        if self._ts is not None and ts < self._ts:
            raise ValueError(f"watermark cannot regress: {ts} < {self._ts}")
        self._ts = ts
        return self

    def cut(self) -> "int | None":
        """Materialize one snapshot from all events at or below the watermark.

        Consumes the log's eligible events in (timestamp, arrival) order
        with last-op-wins semantics per edge, filters no-ops (add of a
        present edge, delete of an absent one — metered as
        ``redundant_events``), and installs the new snapshot + canonical
        Δ pair via ``SnapshotStore.ingest_cut`` together with the
        incrementally maintained common graph. Returns the new snapshot
        index — or ``None`` when no eligible event arrived and a snapshot
        already exists (an empty cut never duplicates a snapshot). The
        consumed timestamp range is sealed: appending at or below it
        afterwards is late.
        """
        if self._ts is None:
            raise ValueError("advance() the watermark before cutting")
        store, metrics = self.store, self.metrics
        events = self.log._take_upto(self._ts)
        num_before = store.seq.num_snapshots
        if not events and num_before > 0:
            self.log._seal(self._ts)
            return None
        if num_before:
            current = store.window_keys(num_before - 1, num_before - 1)
        else:
            current = np.empty(0, np.int64)

        last_op: "dict[int, str]" = {}
        for ev in events:
            key = int(edge_keys(np.int64(ev.src), np.int64(ev.dst),
                                store.num_nodes))
            last_op[key] = ev.op
        add_keys = np.sort(np.array(
            [k for k, op in last_op.items() if op == "add"], dtype=np.int64))
        del_keys = np.sort(np.array(
            [k for k, op in last_op.items() if op == "del"], dtype=np.int64))
        add_is_new = ~np.isin(add_keys, current, assume_unique=True)
        del_is_present = np.isin(del_keys, current, assume_unique=True)
        applied_adds = add_keys[add_is_new]
        applied_dels = del_keys[del_is_present]
        metrics.redundant_events += (len(events) - len(last_op)
                                     + int((~add_is_new).sum())
                                     + int((~del_is_present).sum()))
        metrics.applied_additions += int(applied_adds.shape[0])
        metrics.applied_deletions += int(applied_dels.shape[0])
        new_keys = merge_changes(current, applied_adds, applied_dels)

        if num_before == 0:
            # First cut: the snapshot IS the running common graph.
            self._common, self._common_lo = new_keys, store.first_live
            idx = store.ingest_cut(new_keys,
                                   np.empty(0, np.int64),
                                   np.empty(0, np.int64))
        else:
            if self._common is None or self._common_lo != store.first_live:
                # (Re)base after compaction moved the live window.
                self._common = store.window_keys(store.first_live,
                                                 num_before - 1)
                self._common_lo = store.first_live
            # The incremental deletion-to-addition conversion: additions
            # are disjoint from the previous snapshot (hence from its
            # intersection), so only deletions shrink the common graph.
            shrunk = np.setdiff1d(self._common, applied_dels,
                                  assume_unique=True)
            metrics.common_shrinkage += int(self._common.shape[0]
                                            - shrunk.shape[0])
            self._common = shrunk
            idx = store.ingest_cut(new_keys, applied_adds, applied_dels,
                                   common=shrunk,
                                   common_lo=self._common_lo)
        metrics.cuts += 1
        self.log._seal(self._ts)
        return idx

    def compact(self, before: "int | None" = None):
        """Retire snapshots via ``SnapshotStore.compact`` and meter it.

        Forwards to the store (which clamps the horizon to every
        registered floor and every pinned "AS" anchor), accumulates
        ``compactions``/``retired_snapshots``/``freed_edges``, and — when
        anything was retired — marks the running common graph for lazy
        rebasing at the next cut (the old intersection spanned retired
        snapshots and would under-approximate the narrower live window).
        Returns the store's ``CompactionStats``.
        """
        stats = self.store.compact(before)
        self.metrics.compactions += 1
        self.metrics.retired_snapshots += stats.retired
        self.metrics.freed_edges += stats.freed_edges
        if stats.retired:
            self._common = None
        return stats


class LiveWindowFeed:
    """Emits slide windows the moment their newest snapshot is cut.

    The bridge between ingestion and the query layers: attach one feed to
    one ``WindowStream(feed=...)`` (or ``QueryService.register(...,
    feed=...)`` client) and ``poll()`` after cuts — each width-``width``
    window ``(lo, lo + width - 1)`` is *born* when snapshot
    ``lo + width - 1`` exists, so consumers block on the watermark
    instead of a precomputed window list. The feed also registers a
    compaction floor under its name: the store may never retire a
    snapshot an unconsumed (or future) window still needs. One feed
    serves one consumer (it holds a single emission cursor).
    """

    def __init__(self, store: SnapshotStore, width: int, step: int = 1,
                 name: "str | None" = None):
        if width < 1:
            raise ValueError(f"width must be >= 1, got {width}")
        if step < 1:
            raise ValueError(f"step must be >= 1, got {step}")
        self.store = store
        self.width = width
        self.step = step
        self.name = name if name is not None else f"feed-{next(_FEED_COUNTER)}"
        self.next_lo = store.first_live
        store.set_floor(self.name, self.next_lo)

    def poll(self) -> "list[tuple[int, int]]":
        """Windows born since the last poll (empty when none), in order."""
        born = []
        last = self.store.seq.num_snapshots - 1
        while self.next_lo + self.width - 1 <= last:
            born.append((self.next_lo, self.next_lo + self.width - 1))
            self.next_lo += self.step
        return born

    def advance_floor(self, lo: "int | None" = None) -> None:
        """Report consumer progress: the oldest snapshot still needed.

        ``lo`` is the consumer's first *unconsumed* window low (``None``
        = fully drained, the floor moves to the next unborn window's
        low). Compaction can then retire everything older.
        """
        floor = self.next_lo if lo is None else min(int(lo), self.next_lo)
        self.store.set_floor(self.name, floor)

    def close(self) -> None:
        """Withdraw the feed's compaction floor (consumer finished)."""
        self.store.drop_floor(self.name)


def events_from_sequence(seq) -> "list[EdgeEvent]":
    """Flatten an evolving sequence into a replayable edge-event trace.

    Timestamp 0 carries every edge of snapshot 0 as an add; timestamp
    ``t + 1`` carries transition ``t``'s deletions then additions.
    Replaying the trace with one cut per distinct timestamp
    (:func:`replay_events`) reproduces ``seq`` exactly — same snapshot
    key sets, same canonical Δ-batches — which is the bit-identity
    contract ``bench_ingest`` and tests/test_ingest.py gate.
    """
    events: "list[EdgeEvent]" = []

    def emit(ts: int, keys: np.ndarray, op: str) -> None:
        src, dst = keys_to_edges(keys, seq.num_nodes)
        events.extend(EdgeEvent(ts, int(s), int(d), op)
                      for s, d in zip(src, dst))

    emit(0, seq.snapshot_keys[0], "add")
    for t in range(len(seq.additions)):
        emit(t + 1, seq.deletions[t], "del")
        emit(t + 1, seq.additions[t], "add")
    return events


def replay_events(log: EdgeLog, watermark: Watermark, events, *,
                  on_cut=None) -> "list[int]":
    """Drive a ts-sorted event trace through log → watermark → cuts.

    Appends each event and cuts once per distinct timestamp (the trace's
    tick = one snapshot), calling ``on_cut(snapshot_index)`` after each
    materialized cut — the hook where a live consumer drains its
    ``WindowStream`` or turns its ``QueryService``. Under the ``"block"``
    policy the bounded buffer must hold one tick's events (the cut at
    every boundary empties it); ``"spill"`` replays any trace losslessly;
    ``"drop"`` replays lossily (no bit-identity). Returns the cut
    snapshot indices.
    """
    cuts: "list[int]" = []

    def cut_now(ts: int) -> None:
        idx = watermark.advance(ts).cut()
        if idx is not None:
            cuts.append(idx)
            if on_cut is not None:
                on_cut(idx)

    prev_ts: "int | None" = None
    for ev in events:
        if prev_ts is not None and ev.ts < prev_ts:
            raise ValueError(
                f"replay_events needs a ts-sorted trace: {ev.ts} after "
                f"{prev_ts} (sort the events, or feed the log directly)")
        if prev_ts is not None and ev.ts > prev_ts:
            cut_now(prev_ts)
        log.append(ev.src, ev.dst, w=ev.w, op=ev.op, ts=ev.ts)
        prev_ts = ev.ts
    if prev_ts is not None:
        cut_now(prev_ts)
    return cuts

"""Triangular Grid (TG) work-sharing scheduler (paper §2, second contribution).

TG node T(i,j) = common graph of snapshots i..j; apex = T(0,n−1) = the
CommonGraph; leaves = the snapshots. Descending a grid edge only *adds*
edges, and because nested windows give nested common graphs the addition
volume of any hop (i,j)→(a,b) is exactly |T(a,b)| − |T(i,j)| — so optimal
work sharing over the grid is a clean interval DP:

    cost(i,j) = 0                                  if i == j
    cost(i,j) = min_m  (|T(i,m)| − |T(i,j)|) + cost(i,m)
                     + (|T(m+1,j)| − |T(i,j)|) + cost(m+1,j)

The paper explores the grid with red-arrow schedules; the DP finds the
edge-volume-optimal schedule among all direct hops in the grid (one-level
descents are the m∈{i, j−1} cases, so the paper's schedules are in the DP's
search space). A balanced-bisection plan is provided as the simple heuristic
for comparison; Direct-Hop is the degenerate star plan.

Execution walks the plan tree: each node's state hops from its parent state
via the addition-only incremental engine; each node's edge view = parent's
view ⊕ one Δ block (immutable, shared — zero mutation). Sibling subtrees are
*independent* — the per-level batched executor stacks them on a snapshot
axis (paper's parallelism claim; sharded over `data` on a mesh).
"""

from __future__ import annotations

import dataclasses
import functools
import time

import jax.numpy as jnp
import numpy as np

from repro.core.kickstarter import StreamStats
from repro.core.snapshots import SnapshotStore
from repro.graph.edgeset import EdgeView
from repro.graph.engine import incremental_additions, run_to_fixpoint
from repro.graph.semiring import Semiring

Window = tuple[int, int]


@dataclasses.dataclass
class PlanNode:
    window: Window
    children: list["PlanNode"]

    def leaves(self) -> list[Window]:
        if not self.children:
            return [self.window]
        out = []
        for c in self.children:
            out.extend(c.leaves())
        return out


def optimal_plan(store: SnapshotStore, i: int = 0, j: int | None = None) -> PlanNode:
    """Interval-DP plan minimizing total added-edge volume."""
    if j is None:
        j = store.seq.num_snapshots - 1
    size = store.window_size  # cached |T(a,b)|

    @functools.lru_cache(maxsize=None)
    def cost(a: int, b: int) -> int:
        if a == b:
            return 0
        best = None
        for m in range(a, b):
            c = ((size(a, m) - size(a, b)) + cost(a, m)
                 + (size(m + 1, b) - size(a, b)) + cost(m + 1, b))
            best = c if best is None else min(best, c)
        return best

    @functools.lru_cache(maxsize=None)
    def split(a: int, b: int) -> int:
        best, arg = None, a
        for m in range(a, b):
            c = ((size(a, m) - size(a, b)) + cost(a, m)
                 + (size(m + 1, b) - size(a, b)) + cost(m + 1, b))
            if best is None or c < best:
                best, arg = c, m
        return arg

    def build(a: int, b: int) -> PlanNode:
        if a == b:
            return PlanNode((a, b), [])
        m = split(a, b)
        return PlanNode((a, b), [build(a, m), build(m + 1, b)])

    return build(i, j)


def bisection_plan(i: int = 0, j: int | None = None, *, n: int | None = None) -> PlanNode:
    """Balanced bisection heuristic (no size table needed)."""
    if j is None:
        j = n - 1
    def build(a: int, b: int) -> PlanNode:
        if a == b:
            return PlanNode((a, b), [])
        m = (a + b) // 2
        return PlanNode((a, b), [build(a, m), build(m + 1, b)])
    return build(i, j)


def direct_hop_plan(i: int = 0, j: int | None = None, *, n: int | None = None) -> PlanNode:
    if j is None:
        j = n - 1
    return PlanNode((i, j), [PlanNode((k, k), []) for k in range(i, j + 1)]) \
        if i != j else PlanNode((i, i), [])


def plan_added_edges(store: SnapshotStore, plan: PlanNode) -> int:
    """Total Δ-edge volume streamed by a plan (excludes the apex itself)."""
    total = 0
    def walk(node: PlanNode):
        nonlocal total
        for c in node.children:
            total += store.window_size(*c.window) - store.window_size(*node.window)
            walk(c)
    walk(plan)
    return total


@dataclasses.dataclass
class WorkSharingRun:
    results: dict[int, jnp.ndarray]   # snapshot index -> values
    base_stats: StreamStats
    hop_stats: list[StreamStats]
    wall_s: float
    added_edges: int


def run_plan(
    store: SnapshotStore,
    plan: PlanNode,
    semiring: Semiring,
    source: int,
    max_iters: int = 10_000,
    gated: bool = False,
    cg_split: int = 1,
    track_parents: bool = False,
) -> WorkSharingRun:
    """Execute a TG plan (DFS; each hop = addition-only incremental update)."""
    t_all = time.perf_counter()
    t0 = time.perf_counter()
    apex_view = (store.window_view_split(*plan.window, cg_split) if cg_split > 1
                 else store.common_graph_view(*plan.window))
    base = run_to_fixpoint(apex_view, semiring, source, max_iters, gated=gated,
                           track_parents=track_parents)
    base.values.block_until_ready()
    base_stats = StreamStats(time.perf_counter() - t0, float(base.edge_work),
                             int(base.iterations))

    results: dict[int, jnp.ndarray] = {}
    hop_stats: list[StreamStats] = []

    def dfs(node: PlanNode, view: EdgeView, values, parent):
        if not node.children:
            results[node.window[0]] = values
            return
        for child in node.children:
            t0 = time.perf_counter()
            delta = store.delta_block(node.window, child.window)
            child_view = view.extended(delta)          # shared immutable blocks
            res = incremental_additions(child_view, delta, semiring,
                                        values, parent, max_iters, gated=gated,
                                        track_parents=track_parents)
            res.values.block_until_ready()
            hop_stats.append(StreamStats(time.perf_counter() - t0,
                                         float(res.edge_work),
                                         int(res.iterations)))
            dfs(child, child_view, res.values, res.parent)

    dfs(plan, apex_view, base.values, base.parent)
    return WorkSharingRun(results, base_stats, hop_stats,
                          time.perf_counter() - t_all,
                          plan_added_edges(store, plan))

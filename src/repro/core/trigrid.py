"""Triangular Grid (TG) work-sharing scheduler (paper §2, second contribution).

TG node T(i,j) = common graph of snapshots i..j; apex = T(0,n−1) = the
CommonGraph; leaves = the snapshots. Descending a grid edge only *adds*
edges, and because nested windows give nested common graphs the addition
volume of any hop (i,j)→(a,b) is exactly |T(a,b)| − |T(i,j)| — so optimal
work sharing over the grid is a clean interval DP:

    cost(i,j) = 0                                  if i == j
    cost(i,j) = min_m  (|T(i,m)| − |T(i,j)|) + cost(i,m)
                     + (|T(m+1,j)| − |T(i,j)|) + cost(m+1,j)

The paper explores the grid with red-arrow schedules; the DP finds the
edge-volume-optimal schedule among all direct hops in the grid (one-level
descents are the m∈{i, j−1} cases, so the paper's schedules are in the DP's
search space). A balanced-bisection plan is provided as the simple heuristic
for comparison; Direct-Hop is the degenerate star plan.

Execution walks the plan tree: each node's state hops from its parent state
via the addition-only incremental engine; each node's edge view = parent's
view ⊕ one Δ block (immutable, shared — zero mutation). Sibling subtrees are
*independent* — the per-level batched executor stacks them on a snapshot
axis (paper's parallelism claim; sharded over `data` on a mesh).

Executor contract (both ``run_plan`` and ``run_plan_batched``):

* **Bit-identical results.** For the same plan, semiring, source and
  options, the batched executor returns bit-identical values (and parents,
  when tracked) to the sequential DFS, which in turn matches the
  per-snapshot from-scratch fixpoint up to float tolerance. Each batched
  lane converges over exactly the edge set the sequential executor would
  use (apex blocks + the lane's cumulative Δ + the hop Δ), and the monotone
  fixpoint is order-free — tests/test_trigrid_batched.py enforces this.
* **Shape-bucketing invariant.** Batched levels consume
  ``SnapshotStore.delta_stack`` buffers whose stacked shape is ``(pow2 lane
  bucket, pow2 width bucket)`` — never the exact lane count or ragged Δ
  sizes. The lane axis pads to ``lane_bucket(lanes, data_extent)`` with
  trailing *masked* lanes (all-sentinel Δ, parent-state copy, frontier
  never seeded, ``lane_valid=False``), so the number of distinct jit traces
  stays bounded by bucket combinations across ALL plans, and every level
  divides the mesh's ``data`` axis.
* **Always sharded on a mesh.** With ``mesh=`` given, every level's lane
  axis shards over ``data`` — lane bucketing removed the old
  replicated-execution fallback (and its UserWarning) entirely.
* **Work accounting.** Padding edges never count toward ``edge_work``, and
  masked padding lanes are zeroed out of ``edge_work``/``iterations``; the
  batched seed relaxes only the final parent→child hop Δ (``seed_blocks``),
  so per-plan total edge work equals the sequential executor's.

The sliding-window executor (core/window.py) reuses this machinery with
windows instead of plan levels and inherits the same contract.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.core.kickstarter import StreamStats
from repro.core.snapshots import SnapshotStore
from repro.graph.edgeset import EdgeBlock, EdgeView, lane_bucket
from repro.graph.engine import (
    gather_lane_states,
    host_sync,
    incremental_additions,
    incremental_additions_batched,
    run_to_fixpoint,
)
from repro.graph.semiring import Semiring
from repro.graph.stability import stable_fraction_milli

Window = tuple[int, int]


@dataclasses.dataclass
class PlanNode:
    """A Triangular-Grid plan-tree node: a window plus its child hops.

    Every edge of the tree is an addition-only hop T(parent) → T(child)
    (nesting guarantees Δ ≥ 0); the root is the plan's apex window.
    """

    window: Window
    children: list["PlanNode"]

    def leaves(self) -> list[Window]:
        """The plan's leaf windows in DFS order (the answered snapshots)."""
        if not self.children:
            return [self.window]
        out = []
        for c in self.children:
            out.extend(c.leaves())
        return out


def hop_added_edges(store: SnapshotStore, parent: Window, child: Window) -> int:
    """Δ-edge volume of the grid hop T(parent) → T(child).

    Nested windows give nested common graphs, so the hop streams exactly
    ``|T(child)| − |T(parent)|`` addition edges — the ONE cost atom every
    Δ-volume optimizer in the repo is built from: ``optimal_plan``'s
    interval DP over hops, ``plan_added_edges`` accounting, and the
    campaign planner's DP over window partitions
    (core/window.py::optimal_campaigns).
    """
    return store.window_size(*child) - store.window_size(*parent)


def optimal_plan(store: SnapshotStore, i: int = 0, j: int | None = None,
                 cost_model=None) -> PlanNode:
    """Interval-DP plan minimizing total hop cost.

    Without ``cost_model`` a hop's price is its raw added-edge volume (the
    paper's objective). With a calibrated :class:`~repro.core.costmodel.
    SweepCostModel` each hop is priced by ``cost_model.hop_cost(Δ)`` — the
    measured affine per-edge + per-sweep cost (with the stable-vertex
    discount folded in), so the DP trades hop count against Δ volume the
    way the machine actually charges for them. Either way the DP is exact
    over integer prices.

    Bottom-up over interval spans (and an explicit-stack tree build), so
    neither the DP nor a maximally skewed optimal plan can hit Python's
    recursion limit on long snapshot sequences.
    """
    if j is None:
        j = store.seq.num_snapshots - 1
    size = store.window_size  # cached |T(a,b)|
    price = (cost_model.hop_cost if cost_model is not None
             else (lambda added: added))

    cost: dict[Window, int] = {(a, a): 0 for a in range(i, j + 1)}
    split: dict[Window, int] = {}
    for span in range(1, j - i + 1):
        for a in range(i, j + 1 - span):
            b = a + span
            s_ab = size(a, b)
            best, arg = None, a
            for m in range(a, b):
                c = (price(size(a, m) - s_ab) + cost[(a, m)]
                     + price(size(m + 1, b) - s_ab) + cost[(m + 1, b)])
                if best is None or c < best:
                    best, arg = c, m
            cost[(a, b)] = best
            split[(a, b)] = arg

    root = PlanNode((i, j), [])
    stack = [root]
    while stack:
        node = stack.pop()
        a, b = node.window
        if a == b:
            continue
        m = split[(a, b)]
        node.children = [PlanNode((a, m), []), PlanNode((m + 1, b), [])]
        stack.extend(node.children)
    return root


def _resolve_last(j: int | None, n: int | None) -> int:
    if j is None:
        if n is None:
            raise ValueError("pass either j= or n=")
        j = n - 1
    return j


def bisection_plan(i: int = 0, j: int | None = None, *, n: int | None = None) -> PlanNode:
    """Balanced bisection heuristic (no size table needed)."""
    j = _resolve_last(j, n)
    def build(a: int, b: int) -> PlanNode:
        if a == b:
            return PlanNode((a, b), [])
        m = (a + b) // 2
        return PlanNode((a, b), [build(a, m), build(m + 1, b)])
    return build(i, j)


def direct_hop_plan(i: int = 0, j: int | None = None, *, n: int | None = None) -> PlanNode:
    """The paper's star schedule: every snapshot one hop from the apex."""
    j = _resolve_last(j, n)
    return PlanNode((i, j), [PlanNode((k, k), []) for k in range(i, j + 1)]) \
        if i != j else PlanNode((i, i), [])


def plan_added_edges(store: SnapshotStore, plan: PlanNode) -> int:
    """Total Δ-edge volume streamed by a plan (excludes the apex itself)."""
    total = 0
    def walk(node: PlanNode):
        nonlocal total
        for c in node.children:
            total += hop_added_edges(store, node.window, c.window)
            walk(c)
    walk(plan)
    return total


@dataclasses.dataclass
class WorkSharingRun:
    """Result record of a TG plan execution: per-snapshot values plus the
    apex fixpoint stats, per-hop stats and timing/Δ-volume/lane accounting
    the work-sharing benchmarks compare executors by."""

    results: dict[int, jnp.ndarray]   # snapshot index -> values
    base_stats: StreamStats
    hop_stats: list[StreamStats]
    wall_s: float
    added_edges: int
    # (valid lanes, lane_bucket) per batched launch — what actually ran,
    # for lanes-per-device / padding reporting. Empty on sequential runs.
    lane_layout: "list[tuple[int, int]]" = dataclasses.field(
        default_factory=list)
    # measured stable fraction (‰) over all plan hops: the share of
    # vertex-lanes the stability analysis kept out of the seed frontier
    # (graph/stability.py; padding lanes excluded)
    stable_milli: int = 0


def _anchor_view(store, window, cg_split):
    """The anchor window's edge view, split per ``cg_split``.

    The ONE place the split policy lives: the TG/window anchor rebuilds and
    the streaming scheduler's cache-hit/cover paths (core/window.py) all
    route through here, so hit/hop/rebuild views can never diverge.
    """
    return (store.window_view_split(*window, cg_split) if cg_split > 1
            else store.common_graph_view(*window))


def _anchor_base(store, window, semiring, source, max_iters, gated, cg_split,
                 track_parents, fused_k=1):
    """Anchor-window fixpoint shared by all executors: (view, result, stats).

    The TG executors anchor at the plan apex; the sliding-window executors
    (core/window.py) anchor at the windows' common super-window.
    """
    t0 = time.perf_counter()
    apex_view = _anchor_view(store, window, cg_split)
    base = run_to_fixpoint(apex_view, semiring, source, max_iters, gated=gated,
                           track_parents=track_parents, fused_k=fused_k)
    host_sync(base.values)
    base_stats = StreamStats(time.perf_counter() - t0, float(base.edge_work),
                             int(base.iterations))
    return apex_view, base, base_stats


def run_plan(
    store: SnapshotStore,
    plan: PlanNode,
    semiring: Semiring,
    source: int,
    max_iters: int = 10_000,
    gated: bool = False,
    cg_split: int = 1,
    track_parents: bool = False,
    seed: str = "instability",
    fused_k: int = 1,
) -> WorkSharingRun:
    """Execute a TG plan (DFS; each hop = addition-only incremental update).

    ``fused_k`` threads to the engine's fused-chunk launch option
    (bit-identical results at any value; see engine.relax_sweep_fused).
    """
    t_all = time.perf_counter()
    apex_view, base, base_stats = _anchor_base(
        store, plan.window, semiring, source, max_iters, gated, cg_split,
        track_parents, fused_k)

    results: dict[int, jnp.ndarray] = {}
    hop_stats: list[StreamStats] = []
    unstable_counts: list[int] = []

    def dfs(node: PlanNode, view: EdgeView, values, parent):
        if not node.children:
            results[node.window[0]] = values
            return
        for child in node.children:
            t0 = time.perf_counter()
            delta = store.delta_block(node.window, child.window)
            child_view = view.extended(delta)          # shared immutable blocks
            res = incremental_additions(child_view, delta, semiring,
                                        values, parent, max_iters, gated=gated,
                                        track_parents=track_parents, seed=seed,
                                        fused_k=fused_k)
            host_sync(res.values)
            hop_stats.append(StreamStats(time.perf_counter() - t0,
                                         float(res.edge_work),
                                         int(res.iterations)))
            unstable_counts.append(int(res.unstable))
            dfs(child, child_view, res.values, res.parent)

    dfs(plan, apex_view, base.values, base.parent)
    return WorkSharingRun(results, base_stats, hop_stats,
                          time.perf_counter() - t_all,
                          plan_added_edges(store, plan),
                          stable_milli=stable_fraction_milli(
                              unstable_counts, store.num_nodes))


def plan_levels(plan: PlanNode) -> list[list[tuple[int, PlanNode]]]:
    """Group plan nodes by depth: level d = [(parent lane index, node), ...].

    The parent lane index points into level d−1 (the apex is the single lane
    of level −1). All nodes at one depth are independent given their parents'
    states — the invariant the level-synchronous executor batches on.
    """
    levels: list[list[tuple[int, PlanNode]]] = []
    cur = [plan]
    while True:
        nxt = [(pi, c) for pi, node in enumerate(cur) for c in node.children]
        if not nxt:
            return levels
        levels.append(nxt)
        cur = [c for _, c in nxt]


def _shard_snapshot_axis(mesh, values, parent, blocks, lane_valid):
    """Place the lane (snapshot) axis over the mesh's ``data`` axis.

    Callers bucket the lane axis to a ``lane_bucket`` count (pow2, divisible
    by the ``data`` extent) before arriving here, so a mesh launch ALWAYS
    shards — there is no replicated fallback. ``lane_valid`` rides along so
    the mask is placed lane-aligned with the states it gates.
    """
    if mesh is None:
        return values, parent, blocks, lane_valid
    if values.shape[0] % mesh.shape["data"]:
        raise ValueError(
            f"lane axis of {values.shape[0]} does not divide the "
            f"{mesh.shape['data']}-device data axis — callers must bucket "
            "lane counts with lane_bucket() before sharding")
    row = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("data"))
    values = jax.device_put(values, row)
    parent = jax.device_put(parent, row)
    lane_valid = jax.device_put(lane_valid, row)
    blocks = tuple(EdgeBlock(*(jax.device_put(a, row) for a in b))
                   for b in blocks)
    return values, parent, blocks, lane_valid


def run_plan_batched(
    store: SnapshotStore,
    plan: PlanNode,
    semiring: Semiring,
    source: int,
    max_iters: int = 10_000,
    gated: bool = False,
    cg_split: int = 1,
    track_parents: bool = False,
    mesh=None,
    seed: str = "instability",
    fused_k: int = 1,
) -> WorkSharingRun:
    """Execute a TG plan level-synchronously: one batched launch per depth.

    Siblings at the same depth of the plan tree are independent by
    construction, so each level runs as ONE ``incremental_additions_batched``
    launch: the level's ragged Δ-batches are stacked on a leading snapshot
    axis (``SnapshotStore.delta_stack``, shape-bucketed so jit traces stay
    bounded) and parent states are gathered into the lanes.

    Per-lane edge views are expressed as apex blocks (shared, broadcast) plus
    two stacked groups: the lane's *cumulative* Δ from the apex to its parent
    and the final parent→child hop Δ. For nested windows the cumulative Δ is
    exactly the union of the chain's per-hop Δs, so every lane re-converges
    over precisely the edge set the sequential executor would use — the
    monotone-fixpoint guarantee then makes the results bit-identical. The
    frontier is seeded from the hop Δ only (``seed_blocks``), matching the
    sequential seeding and its edge-work accounting.

    Each level's lane count pads to ``lane_bucket(lanes, data_extent)``:
    trailing masked lanes carry empty (all-sentinel) Δs and inert state
    copies, and only valid lanes are gathered back into ``results``. On a
    mesh the bucketed snapshot axis therefore ALWAYS shards over ``data``
    (see launch/evolve.py) — no lane count triggers replicated execution.

    ``gated`` stays exact here but buys no skip: inside vmap the block gate's
    ``lax.cond`` lowers to a select that relaxes every block for every lane.
    It is honored for the apex fixpoint (unbatched) and for result parity
    with the sequential executor, not as a batched-path speedup.
    """
    t_all = time.perf_counter()
    apex_view, base, base_stats = _anchor_base(
        store, plan.window, semiring, source, max_iters, gated, cg_split,
        track_parents, fused_k)

    results: dict[int, jnp.ndarray] = {}
    hop_stats: list[StreamStats] = []
    lane_layout: list[tuple[int, int]] = []
    unstable_counts: list = []
    if not plan.children:
        results[plan.window[0]] = base.values

    apex_window = plan.window
    n = store.num_nodes
    data_extent = mesh.shape["data"] if mesh is not None else 1
    prev_nodes = [plan]
    prev_values = base.values[None]
    prev_parent = base.parent[None]
    for level in plan_levels(plan):
        t0 = time.perf_counter()
        lanes = len(level)
        bucket = lane_bucket(lanes, data_extent)
        lane_layout.append((lanes, bucket))
        hop_stacked = store.delta_stack(
            [(prev_nodes[pi].window, c.window) for pi, c in level],
            num_lanes=bucket)
        if any(prev_nodes[pi].window != apex_window for pi, _ in level):
            prefix_stacked = store.delta_stack(
                [(apex_window, prev_nodes[pi].window) for pi, _ in level],
                num_lanes=bucket)
            delta_blocks = (prefix_stacked, hop_stacked)
        else:
            delta_blocks = (hop_stacked,)   # level 1: parents ARE the apex

        # Masked padding lanes re-run lane 0's parent state over an empty Δ:
        # no frontier is ever seeded, values stay an inert copy, and
        # lane_valid zeroes them out of the work accounting.
        lane_map = [pi for pi, _ in level] + [0] * (bucket - lanes)
        values, parent = gather_lane_states(prev_values, prev_parent, lane_map)
        lane_valid = jnp.arange(bucket) < lanes
        values, parent, delta_blocks, lane_valid = _shard_snapshot_axis(
            mesh, values, parent, delta_blocks, lane_valid)
        res = incremental_additions_batched(
            n, semiring, values, parent,
            shared_blocks=tuple(apex_view.blocks), delta_blocks=delta_blocks,
            max_iters=max_iters, track_parents=track_parents, gated=gated,
            seed_blocks=(delta_blocks[-1],), lane_valid=lane_valid, seed=seed,
            fused_k=fused_k)
        host_sync(res.values)
        hop_stats.append(StreamStats(time.perf_counter() - t0,
                                     float(jnp.sum(res.edge_work)),
                                     int(jnp.max(res.iterations))))
        unstable_counts.extend(int(u) for u in res.unstable[:lanes])
        for lane, (_, c) in enumerate(level):
            if not c.children:
                results[c.window[0]] = res.values[lane]
        prev_nodes = [c for _, c in level]
        prev_values, prev_parent = res.values, res.parent

    return WorkSharingRun(results, base_stats, hop_stats,
                          time.perf_counter() - t_all,
                          plan_added_edges(store, plan), lane_layout,
                          stable_milli=stable_fraction_milli(
                              unstable_counts, store.num_nodes))

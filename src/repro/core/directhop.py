"""CommonGraph Direct-Hop (paper §2, first red-arrow schedule).

Compute the query once on the CommonGraph apex, then hop *directly* to each
snapshot by streaming its missing-edge batch A_i = S_i \\ CG — additions
only, no deletions, no mutation (each snapshot's view = shared CG block +
its Δ block). The snapshots become independent, which the batched executor
exploits as real SPMD parallelism (one stacked snapshot axis).
"""

from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp

from repro.core.kickstarter import StreamStats
from repro.core.snapshots import SnapshotStore
from repro.core.trigrid import direct_hop_plan, run_plan_batched
from repro.graph.engine import host_sync, incremental_additions, run_to_fixpoint
from repro.graph.semiring import Semiring


@dataclasses.dataclass
class DirectHopRun:
    results: list[jnp.ndarray]
    base_stats: StreamStats          # the one-off CommonGraph fixpoint
    hop_stats: list[StreamStats]     # per-snapshot addition hops
    wall_s: float
    # (valid lanes, lane_bucket) of the batched launch; empty when sequential
    lane_layout: "list[tuple[int, int]]" = dataclasses.field(
        default_factory=list)


def run_direct_hop(
    store: SnapshotStore,
    semiring: Semiring,
    source: int,
    max_iters: int = 10_000,
    gated: bool = False,
    cg_split: int = 1,
    track_parents: bool = False,
) -> DirectHopRun:
    """Sequential Direct-Hop (for like-for-like timing against KickStarter).

    ``gated``/``cg_split``: beyond-paper block-gating optimization — the
    CommonGraph splits into src-contiguous sub-blocks and incremental sweeps
    skip blocks outside the frontier (engine.relax_sweep).
    """
    t_all = time.perf_counter()
    n_snap = store.seq.num_snapshots
    window = (0, n_snap - 1)

    t0 = time.perf_counter()
    cg_view = (store.window_view_split(*window, cg_split) if cg_split > 1
               else store.common_graph_view(*window))
    base = run_to_fixpoint(cg_view, semiring, source, max_iters, gated=gated,
                           track_parents=track_parents)
    host_sync(base.values)
    base_stats = StreamStats(time.perf_counter() - t0, float(base.edge_work),
                             int(base.iterations))

    results, hop_stats = [], []
    for i in range(n_snap):
        t0 = time.perf_counter()
        delta = store.delta_block(window, (i, i))
        view = cg_view.extended(delta)       # zero-copy shared blocks
        res = incremental_additions(view, delta, semiring,
                                    base.values, base.parent, max_iters,
                                    gated=gated, track_parents=track_parents)
        host_sync(res.values)
        results.append(res.values)
        hop_stats.append(StreamStats(time.perf_counter() - t0,
                                     float(res.edge_work), int(res.iterations)))
    return DirectHopRun(results, base_stats, hop_stats,
                        time.perf_counter() - t_all)


def run_direct_hop_batched(
    store: SnapshotStore,
    semiring: Semiring,
    source: int,
    max_iters: int = 10_000,
    gated: bool = False,
    cg_split: int = 1,
    track_parents: bool = False,
    mesh=None,
) -> DirectHopRun:
    """Batched Direct-Hop: all snapshot hops as ONE stacked computation.

    This is the paper's "additional opportunities for parallelism": with the
    sequential dependence gone, the per-snapshot Δ batches are stacked on a
    snapshot axis and the incremental fixpoint is vmapped — on a mesh this
    axis shards over `data` (launch/evolve.py).

    Implemented as the degenerate star-plan case of the level-synchronous TG
    executor (one level, one lane per snapshot), so it honors the same
    ``gated``/``cg_split``/``track_parents`` options as ``run_direct_hop``
    (``gated`` stays exact but lowers to a select under vmap — no block-skip
    speedup on the batched path; see ``run_plan_batched``).
    """
    n_snap = store.seq.num_snapshots
    ws = run_plan_batched(store, direct_hop_plan(n=n_snap), semiring, source,
                          max_iters, gated=gated, cg_split=cg_split,
                          track_parents=track_parents, mesh=mesh)
    return DirectHopRun([ws.results[i] for i in range(n_snap)],
                        ws.base_stats, ws.hop_stats, ws.wall_s,
                        ws.lane_layout)

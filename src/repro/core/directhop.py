"""CommonGraph Direct-Hop (paper §2, first red-arrow schedule).

Compute the query once on the CommonGraph apex, then hop *directly* to each
snapshot by streaming its missing-edge batch A_i = S_i \\ CG — additions
only, no deletions, no mutation (each snapshot's view = shared CG block +
its Δ block). The snapshots become independent, which the batched executor
exploits as real SPMD parallelism (one stacked snapshot axis).
"""

from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro.core.kickstarter import StreamStats
from repro.core.snapshots import SnapshotStore
from repro.graph.edgeset import EdgeBlock, EdgeView, keys_to_edges, make_block
from repro.graph.engine import (
    incremental_additions,
    incremental_additions_batched,
    run_to_fixpoint,
)
from repro.graph.semiring import Semiring


@dataclasses.dataclass
class DirectHopRun:
    results: list[jnp.ndarray]
    base_stats: StreamStats          # the one-off CommonGraph fixpoint
    hop_stats: list[StreamStats]     # per-snapshot addition hops
    wall_s: float


def run_direct_hop(
    store: SnapshotStore,
    semiring: Semiring,
    source: int,
    max_iters: int = 10_000,
    gated: bool = False,
    cg_split: int = 1,
    track_parents: bool = False,
) -> DirectHopRun:
    """Sequential Direct-Hop (for like-for-like timing against KickStarter).

    ``gated``/``cg_split``: beyond-paper block-gating optimization — the
    CommonGraph splits into src-contiguous sub-blocks and incremental sweeps
    skip blocks outside the frontier (engine.relax_sweep).
    """
    t_all = time.perf_counter()
    n_snap = store.seq.num_snapshots
    window = (0, n_snap - 1)

    t0 = time.perf_counter()
    cg_view = (store.window_view_split(*window, cg_split) if cg_split > 1
               else store.common_graph_view(*window))
    base = run_to_fixpoint(cg_view, semiring, source, max_iters, gated=gated,
                           track_parents=track_parents)
    base.values.block_until_ready()
    base_stats = StreamStats(time.perf_counter() - t0, float(base.edge_work),
                             int(base.iterations))

    results, hop_stats = [], []
    for i in range(n_snap):
        t0 = time.perf_counter()
        delta = store.delta_block(window, (i, i))
        view = cg_view.extended(delta)       # zero-copy shared blocks
        res = incremental_additions(view, delta, semiring,
                                    base.values, base.parent, max_iters,
                                    gated=gated, track_parents=track_parents)
        res.values.block_until_ready()
        results.append(res.values)
        hop_stats.append(StreamStats(time.perf_counter() - t0,
                                     float(res.edge_work), int(res.iterations)))
    return DirectHopRun(results, base_stats, hop_stats,
                        time.perf_counter() - t_all)


def run_direct_hop_batched(
    store: SnapshotStore,
    semiring: Semiring,
    source: int,
    max_iters: int = 10_000,
) -> DirectHopRun:
    """Batched Direct-Hop: all snapshot hops as ONE stacked computation.

    This is the paper's "additional opportunities for parallelism": with the
    sequential dependence gone, the per-snapshot Δ batches are stacked on a
    snapshot axis (padded to a common size) and the incremental fixpoint is
    vmapped — on a mesh this axis shards over `data` (launch/evolve.py).
    """
    t_all = time.perf_counter()
    n = store.num_nodes
    n_snap = store.seq.num_snapshots
    window = (0, n_snap - 1)

    t0 = time.perf_counter()
    cg_view = store.common_graph_view(*window)
    base = run_to_fixpoint(cg_view, semiring, source, max_iters)
    base.values.block_until_ready()
    base_stats = StreamStats(time.perf_counter() - t0, float(base.edge_work),
                             int(base.iterations))

    t0 = time.perf_counter()
    deltas = [store.delta_keys(window, (i, i)) for i in range(n_snap)]
    e_max = max(int(d.shape[0]) for d in deltas)
    srcs, dsts, ws = [], [], []
    for dk in deltas:
        s, d = keys_to_edges(dk, n)
        w = store.seq.weights_for(dk)
        blk = make_block(s, d, w, n, granule=max(e_max, 1), pad_pow2=False)
        srcs.append(blk.src); dsts.append(blk.dst); ws.append(blk.w)
    stacked = EdgeBlock(jnp.stack(srcs), jnp.stack(dsts), jnp.stack(ws))

    values = jnp.broadcast_to(base.values, (n_snap, n))
    parent = jnp.broadcast_to(base.parent, (n_snap, n))
    res = incremental_additions_batched(
        n, semiring, values, parent,
        shared_blocks=tuple(cg_view.blocks), delta_blocks=(stacked,),
        max_iters=max_iters, track_parents=False)
    res.values.block_until_ready()
    hop = StreamStats(time.perf_counter() - t0, float(jnp.sum(res.edge_work)),
                      int(jnp.max(res.iterations)))
    results = [res.values[i] for i in range(n_snap)]
    return DirectHopRun(results, base_stats, [hop], time.perf_counter() - t_all)

"""CommonGraph core — the paper's contribution as a composable JAX module.

Layers:
  ingest       live ingestion (edge-event log, watermark cuts, compaction)
  snapshots    mutation-free window/Δ representation (shared edge blocks)
  kickstarter  the streaming baseline (deletions + trimming) we compare to
  directhop    CommonGraph Direct-Hop schedule (deletion-free, star plan)
  trigrid      Triangular Grid + work-sharing plans (DP-optimal / bisection)
  window       sliding-window executors (sequential + one-launch batched)
  costmodel    measured-cost calibration for the Δ-volume planners
  service      always-on multi-client query service (admission + scheduling)
"""

from repro.core.snapshots import CompactionStats, SnapshotStore
from repro.core.ingest import (
    BackpressureStall,
    EdgeEvent,
    EdgeLog,
    IngestMetrics,
    LiveSequence,
    LiveWindowFeed,
    Watermark,
    events_from_sequence,
    replay_events,
)
from repro.core.kickstarter import StreamStats, run_kickstarter_stream
from repro.core.directhop import DirectHopRun, run_direct_hop, run_direct_hop_batched
from repro.core.trigrid import (
    PlanNode,
    WorkSharingRun,
    bisection_plan,
    direct_hop_plan,
    hop_added_edges,
    optimal_plan,
    plan_added_edges,
    plan_levels,
    run_plan,
    run_plan_batched,
)
from repro.core.costmodel import (
    SweepCostModel,
    calibrate,
    measure_sweep_nanos,
)
from repro.core.service import (
    LaunchRecord,
    QueryService,
    ServiceClient,
    ServiceMetrics,
)
from repro.core.window import (
    AnchorChain,
    CampaignPlan,
    WindowSlideRun,
    WindowStream,
    WindowStreamRun,
    campaign_volume,
    optimal_campaigns,
    run_window_slide,
    run_window_slide_batched,
    run_window_stream_batched,
    select_chain,
    slide_windows,
    stream_campaigns,
    window_anchor,
)

__all__ = [
    "AnchorChain",
    "BackpressureStall",
    "CampaignPlan",
    "CompactionStats",
    "EdgeEvent",
    "EdgeLog",
    "IngestMetrics",
    "LaunchRecord",
    "LiveSequence",
    "LiveWindowFeed",
    "Watermark",
    "events_from_sequence",
    "replay_events",
    "QueryService",
    "ServiceClient",
    "ServiceMetrics",
    "SnapshotStore",
    "SweepCostModel",
    "calibrate",
    "measure_sweep_nanos",
    "WindowSlideRun",
    "WindowStream",
    "WindowStreamRun",
    "campaign_volume",
    "optimal_campaigns",
    "run_window_slide",
    "run_window_slide_batched",
    "run_window_stream_batched",
    "select_chain",
    "slide_windows",
    "stream_campaigns",
    "window_anchor",
    "hop_added_edges",
    "StreamStats",
    "run_kickstarter_stream",
    "DirectHopRun",
    "run_direct_hop",
    "run_direct_hop_batched",
    "PlanNode",
    "WorkSharingRun",
    "bisection_plan",
    "direct_hop_plan",
    "optimal_plan",
    "plan_added_edges",
    "plan_levels",
    "run_plan",
    "run_plan_batched",
]

"""End-to-end training driver (``--arch <id>``): real steps on the local mesh.

This is the concrete counterpart of the dry-run cells: it builds a (possibly
reduced) config, synthesizes data deterministically, jits the same train
step, and runs it with checkpoint/restart + failure-drill hooks from
runtime/. Works on 1 CPU device (CI) or any real mesh.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --steps 20 --reduced --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced_config
from repro.data import DataCursor, dien_batch, gnn_full_batch, lm_batch
from repro.models.dien import dien_loss, init_dien_params
from repro.models.gnn import gnn_loss, init_gnn_params
from repro.models.transformer import init_lm_params, lm_loss
from repro.optim import adamw_init, adamw_update
from repro.runtime.checkpoint import CheckpointManager


def build(arch: str, reduced: bool, batch: int, seq: int):
    cfg, family = reduced_config(arch) if reduced else (get_arch(arch)[0], get_arch(arch)[1])

    if family == "lm":
        params_init = lambda key: init_lm_params(key, cfg)
        def loss_fn(p, b):
            return lm_loss(cfg, p, b["tokens"], b["labels"])
        def data_fn(cursor):
            return lm_batch(cursor, batch, seq, cfg.vocab)
    elif family == "gnn":
        import dataclasses as dc
        n, e = 64, 256
        cfg2 = dc.replace(cfg, d_in=16, d_out=4,
                          task="node_class" if cfg.arch in ("gcn", "pna") else "node_reg",
                          n_vars=8 if cfg.arch == "graphcast" else cfg.n_vars)
        if cfg2.arch == "graphcast":
            cfg2 = dc.replace(cfg2, d_in=8, d_out=8, task="node_reg")
        cfg = cfg2
        params_init = lambda key: init_gnn_params(key, cfg)
        def loss_fn(p, b):
            return gnn_loss(cfg, p, b)
        def data_fn(cursor):
            b = gnn_full_batch(cursor, n, e, cfg.d_in,
                               cfg.d_out, cfg.task)
            if cfg.arch == "graphcast":
                b = _graphcastify(b, n, e, cfg, cursor)
            return b
    else:  # recsys
        params_init = lambda key: init_dien_params(key, cfg)
        def loss_fn(p, b):
            return dien_loss(cfg, p, b)
        def data_fn(cursor):
            return dien_batch(cursor, batch, cfg.seq_len, cfg.n_items, cfg.n_cats)
    return cfg, family, params_init, loss_fn, data_fn


def _graphcastify(b, n, e, cfg, cursor):
    key = cursor.key()
    ks = jax.random.split(key, 4)
    m = max(n // 4, 8)
    em = 4 * m
    out = {
        "x": b["x"], "targets": jax.random.normal(ks[3], (n, cfg.n_vars)),
        "mesh_valid": jnp.ones((m,), bool),
        "g2m_src": b["src"], "g2m_dst": jax.random.randint(ks[0], (e,), 0, m),
        "g2m_feat": b["edge_feat"],
        "mesh_src": jax.random.randint(ks[1], (em,), 0, m),
        "mesh_dst": jax.random.randint(ks[2], (em,), 0, m),
        "mesh_feat": jax.random.normal(ks[0], (em, cfg.d_edge)),
        "m2g_src": jax.random.randint(ks[2], (e,), 0, m),
        "m2g_dst": b["dst"], "m2g_feat": b["edge_feat"],
    }
    return out


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--steps", type=int, default=10)
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--lr", type=float, default=1e-3)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=0)
    p.add_argument("--resume", action="store_true")
    args = p.parse_args(argv)

    cfg, family, params_init, loss_fn, data_fn = build(
        args.arch, args.reduced, args.batch, args.seq)

    params = params_init(jax.random.PRNGKey(args.seed))
    opt = adamw_init(params)
    cursor = DataCursor(seed=args.seed, step=0)
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt and args.resume:
        restored = ckpt.restore_latest()
        if restored is not None:
            params, opt, cursor = restored["params"], restored["opt"], restored["cursor"]
            print(f"[train] resumed at step {cursor.step}")

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(lambda pp: loss_fn(pp, batch))(params)
        new_p, new_o, gnorm = adamw_update(grads, opt, params, lr=args.lr,
                                           weight_decay=0.0)
        return new_p, new_o, loss, gnorm

    losses = []
    t0 = time.perf_counter()
    # Synthetic labels are random: train on the step-0 batch (memorization)
    # so the loss-decrease sanity check below is meaningful. The cursor still
    # advances (and checkpoints) exactly as a fresh-data run would.
    fixed_batch = data_fn(DataCursor(args.seed, 0))
    for i in range(cursor.step, args.steps):
        batch = fixed_batch
        params, opt, loss, gnorm = step(params, opt, batch)
        losses.append(float(loss))
        cursor.step = i + 1
        if ckpt and args.ckpt_every and (i + 1) % args.ckpt_every == 0:
            ckpt.save(i + 1, {"params": params, "opt": opt, "cursor": cursor})
        print(f"[train] {args.arch} step {i + 1} loss {float(loss):.4f} "
              f"gnorm {float(gnorm):.3f}")
    dt = time.perf_counter() - t0
    print(f"[train] done: first loss {losses[0]:.4f} -> last {losses[-1]:.4f} "
          f"({dt:.1f}s, {dt / max(len(losses),1) * 1e3:.1f} ms/step)")
    assert losses[-1] < losses[0], "loss must decrease over the run"
    return losses


if __name__ == "__main__":
    main()

"""Production mesh factory.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever this host actually has (1 CPU device in CI/smoke)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


def make_snapshot_mesh():
    """1-D ``data`` mesh over all local devices.

    The batched CommonGraph executors (run_direct_hop_batched /
    run_plan_batched) shard their leading snapshot axis over this axis —
    the paper's "breaks the sequential dependency" parallelism mapped onto
    hardware.
    """
    return jax.make_mesh((len(jax.devices()),), ("data",))

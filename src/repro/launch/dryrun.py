import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production mesh, and record memory/cost/collective analysis for §Roofline.

MUST be run as its own process (the XLA flag above locks device count at
first jax init — that is why it precedes every other import):

    PYTHONPATH=src python -m repro.launch.dryrun --arch stablelm-1.6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --json out.json
"""

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import ARCH_IDS, make_cell, shapes_for          # noqa: E402
from repro.configs.base import with_sharding, named                # noqa: E402
from repro.launch.mesh import make_production_mesh                 # noqa: E402

def _mesh_context(mesh):
    """jax.sharding.set_mesh when available (jax >= 0.5), else the legacy
    Mesh context manager — the cells pass explicit NamedShardings either way."""
    set_mesh = getattr(jax.sharding, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh


def _cost_dict(compiled) -> dict:
    """compiled.cost_analysis() returns a dict (jax >= 0.5) or a one-element
    list of dicts (older releases); normalize to a dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0] if cost else None
    return cost or {}


# -- collective-bytes extraction from lowered/compiled HLO --------------------

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*=\s*"
    r"((?:\([^)]*\)|[a-z0-9]+\[[^\]]*\]))", re.IGNORECASE)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result-shape bytes of every collective op in an HLO module."""
    out: dict[str, int] = {}
    for op, shape in _COLL_RE.findall(hlo_text):
        op = op.lower()
        out[op] = out.get(op, 0) + _shape_bytes(shape)
    return out


# -- per-cell dry-run ----------------------------------------------------------

def dryrun_cell(arch: str, shape: str, mesh, verbose: bool = True) -> dict:
    t0 = time.perf_counter()
    cell = make_cell(arch, shape, mesh)
    args = with_sharding(mesh, cell.in_specs, cell.args)
    out_shardings = named(mesh, cell.out_specs) if cell.out_specs is not None else None

    jitted = jax.jit(cell.fn, out_shardings=out_shardings,
                     donate_argnums=cell.donate)
    with _mesh_context(mesh):
        lowered = jitted.lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
    t_all = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    cost = _cost_dict(compiled)
    coll = collective_bytes(compiled.as_text())

    rec = {
        "cell": cell.name,
        "mesh": dict(zip(mesh.axis_names, (mesh.shape[a] for a in mesh.axis_names))),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_all - t_lower, 2),
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "collective_bytes": coll,
        "mem_per_device": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
    }
    if cell.meta:
        rec["lane_axis"] = cell.meta
    if verbose:
        print(f"[dryrun] {cell.name} mesh={rec['mesh']} "
              f"lower={rec['lower_s']}s compile={rec['compile_s']}s")
        print(f"  memory_analysis: {mem}")
        print(f"  flops={rec['flops']:.3e} bytes={rec['bytes_accessed']:.3e} "
              f"collectives={ {k: f'{v:.2e}' for k, v in coll.items()} }")
        if cell.meta:
            print(f"  lane_axis: {cell.meta}")
    return rec


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--all", action="store_true")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--commongraph", action="store_true",
                   help="also dry-run the paper engine cells")
    p.add_argument("--json", default=None)
    args = p.parse_args(argv)

    meshes = []
    if args.both_meshes:
        meshes = [make_production_mesh(multi_pod=False),
                  make_production_mesh(multi_pod=True)]
    else:
        meshes = [make_production_mesh(multi_pod=args.multi_pod)]

    cells: list[tuple[str, str]] = []
    if args.all:
        cells = [(a, s) for a in ARCH_IDS for s in shapes_for(a)]
    elif args.arch:
        shapes = [args.shape] if args.shape else shapes_for(args.arch)
        cells = [(args.arch, s) for s in shapes]

    records, failures = [], []
    for mesh in meshes:
        for arch, shape in cells:
            try:
                records.append(dryrun_cell(arch, shape, mesh))
            except Exception as e:  # noqa: BLE001 — report and continue
                traceback.print_exc()
                failures.append((arch, shape, str(mesh.shape), str(e)[:200]))
        if args.commongraph:
            from repro.configs.commongraph import COMMONGRAPH_SHAPES, make_commongraph_cell
            for cs in COMMONGRAPH_SHAPES:
                try:
                    cell = make_commongraph_cell(cs, mesh)
                    records.append(_dryrun_prepared(cell, mesh))
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    failures.append(("commongraph", cs, str(mesh.shape), str(e)[:200]))

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"records": records, "failures": failures}, f, indent=1)
    print(f"\n[dryrun] {len(records)} cells OK, {len(failures)} failed")
    for f4 in failures:
        print("  FAIL:", *f4)
    return 1 if failures else 0


def _dryrun_prepared(cell, mesh) -> dict:
    """dryrun_cell for an already-built Cell (commongraph extra cells)."""
    t0 = time.perf_counter()
    args = with_sharding(mesh, cell.in_specs, cell.args)
    out_shardings = named(mesh, cell.out_specs) if cell.out_specs is not None else None
    jitted = jax.jit(cell.fn, out_shardings=out_shardings,
                     donate_argnums=cell.donate)
    with _mesh_context(mesh):
        compiled = jitted.lower(*args).compile()
    mem = compiled.memory_analysis()
    cost = _cost_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    rec = {
        "cell": cell.name,
        "mesh": dict(zip(mesh.axis_names, (mesh.shape[a] for a in mesh.axis_names))),
        "lower_s": None,
        "compile_s": round(time.perf_counter() - t0, 2),
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "collective_bytes": coll,
        "mem_per_device": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
    }
    if cell.meta:
        # lane-bucketed cells (commongraph): lanes-per-device + padding
        # overhead of the pow2 snapshot-axis bucket (graph/edgeset.py).
        rec["lane_axis"] = cell.meta
    print(f"[dryrun] {cell.name} mesh={rec['mesh']} compile={rec['compile_s']}s")
    print(f"  memory_analysis: {mem}")
    print(f"  flops={rec['flops']:.3e} bytes={rec['bytes_accessed']:.3e} "
          f"collectives={ {k: f'{v:.2e}' for k, v in coll.items()} }")
    if cell.meta:
        print(f"  lane_axis: {cell.meta}")
    return rec


if __name__ == "__main__":
    sys.exit(main())

"""The paper's driver: evolving-graph queries over a snapshot window.

Runs all four execution modes on an R-MAT evolving sequence and reports the
Table-1-style comparison:

    PYTHONPATH=src python -m repro.launch.evolve --nodes 20000 --edges 200000 \
        --snapshots 10 --changes 10000 --alg sssp

Modes: ks (KickStarter streaming baseline), dh (CommonGraph Direct-Hop),
dhb (batched Direct-Hop — snapshot-parallel), ws (Triangular-Grid
work-sharing, DP-optimal plan), wsb (level-synchronous batched TG executor).

``--window W`` additionally runs the sliding-window executors: a width-W
window slides over the sequence and every window is answered by an
addition-only hop from the windows' common super-window apex
(core/window.py). ``--window-batch`` runs the batched slide too — all hops
as lanes of ONE stacked launch — and reports its speedup over the
sequential slide. ``--stream`` (with ``--campaign-width C``) feeds the same
windows through the streaming-campaign scheduler instead: campaigns of C
windows whose anchors are maintained incrementally across launches
(1 rebuild + hops, vs one rebuild per campaign cold), reported against the
cold per-campaign baseline. ``--campaign-width auto`` lets the Δ-volume DP
(``optimal_campaigns``) choose the partition and prints the modeled
slide/anchor/padding volumes of the plan it picked (docs/STREAMING.md).

``--shard`` places the batched executors' lane axis (snapshots for
dhb/wsb, windows for --window-batch) over a 1-D ``data`` mesh spanning all
local devices (launch/mesh.py::make_snapshot_mesh) — on one CPU device it
is a no-op, on a multi-chip host each launch's lanes split across chips.

``--fused-k K`` runs every sliding-window/stream launch with the engine's
fused-chunk option: up to K frontier-masked sweeps per fused kernel
dispatch (kernels/edge_relax_multi), bit-identical results at any K.
``--calibrate`` (with ``--stream``) fits a measured :class:`SweepCostModel`
(core/costmodel.py) from timed sweeps at two edge scales, prints the fitted
per-edge/per-sweep prices, and hands the model to the timed stream's
Δ-volume planner — the ``campaign_width="auto"`` DP then minimizes modeled
nanoseconds instead of discounted edge counts (docs/BENCHMARKS.md).

``--ingest`` builds the store by replaying the generated sequence as a
seeded edge-event firehose instead of loading it precomputed: every
snapshot is born from a ``Watermark.cut`` over an ``EdgeLog``
(core/ingest.py), asserted bit-identical to the precomputed sequence, and
every mode below then runs over the cut-born store (docs/INGESTION.md).
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import (
    EdgeLog,
    IngestMetrics,
    LiveSequence,
    SnapshotStore,
    Watermark,
    events_from_sequence,
    optimal_plan,
    plan_added_edges,
    run_direct_hop,
    run_direct_hop_batched,
    run_kickstarter_stream,
    run_plan,
    run_plan_batched,
    replay_events,
    run_window_slide,
    run_window_slide_batched,
    run_window_stream_batched,
    slide_windows,
)
from repro.graph import make_evolving_sequence, run_to_fixpoint
from repro.graph.semiring import ALL_SEMIRINGS
from repro.launch.mesh import make_snapshot_mesh


def _campaign_width(arg: str):
    """argparse type for --campaign-width: positive int or the 'auto'
    sentinel resolved by optimal_campaigns (core/window.py)."""
    if arg == "auto":
        return arg
    try:
        width = int(arg)
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"expected a positive integer or 'auto', got {arg!r}") from None
    if width < 1:
        raise argparse.ArgumentTypeError(
            f"campaign width must be >= 1, got {width}")
    return width


def _ingest_store(seq) -> SnapshotStore:
    """Replay ``seq`` as a timestamped edge firehose and return the live
    store its watermark cuts materialize — bit-identical to
    ``SnapshotStore(seq)`` (asserted), so every downstream mode is
    oblivious to how its snapshots were born (docs/INGESTION.md)."""
    metrics = IngestMetrics()
    store = SnapshotStore(LiveSequence(seq.num_nodes,
                                       weight_seed=seq.weight_seed))
    log = EdgeLog(seq.num_nodes, metrics=metrics)
    watermark = Watermark(log, store)
    t0 = time.perf_counter()
    cuts = replay_events(log, watermark, events_from_sequence(seq))
    wall = time.perf_counter() - t0
    for i in range(seq.num_snapshots):
        assert np.array_equal(store.seq.snapshot_keys[i],
                              seq.snapshot_keys[i]), f"cut {i} diverged"
    print(f"[evolve] ingest: replayed {metrics.events} events -> "
          f"{len(cuts)} cuts in {wall:.2f}s "
          f"(+{metrics.applied_additions}/-{metrics.applied_deletions} "
          f"applied, common-shrinkage {metrics.common_shrinkage}); "
          f"snapshots bit-identical to the precomputed sequence")
    return store


def _shard_report(mesh, label: str,
                  lane_layout: "list[tuple[int, int]]") -> None:
    """Per-launch lane placement, from the (lanes, bucket) pairs the batched
    executor recorded for what it actually launched: every lane axis buckets
    to a pow2 count divisible by the data axis, so each launch shards — the
    padding overhead is the price of never running replicated."""
    extent = mesh.shape["data"]
    if not lane_layout:
        print(f"[evolve]   shard[{label}]: no batched launches "
              "(single-snapshot leaf plan)")
        return
    lanes = [c for c, _ in lane_layout]
    buckets = [b for _, b in lane_layout]
    pad = sum(buckets) / sum(lanes) - 1
    print(f"[evolve]   shard[{label}]: lanes {lanes} -> buckets "
          f"{buckets} over {extent} devices "
          f"({[b // extent for b in buckets]} lanes/device, "
          f"padding overhead {pad:.0%})")


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--nodes", type=int, default=20_000)
    p.add_argument("--edges", type=int, default=200_000)
    p.add_argument("--snapshots", type=int, default=10)
    p.add_argument("--changes", type=int, default=10_000)
    p.add_argument("--alg", default="sssp", choices=list(ALL_SEMIRINGS))
    p.add_argument("--source", type=int, default=0)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--verify", action="store_true")
    p.add_argument("--shard", action="store_true",
                   help="shard the batched executors' lane axis (snapshots, "
                        "or windows with --window-batch) over a 1-D data "
                        "mesh of all local devices")
    p.add_argument("--window", type=int, default=None, metavar="W",
                   help="also run the sliding-window executor: slide a "
                        "width-W window over the sequence, answering every "
                        "window by an addition-only hop from the shared "
                        "super-window anchor (core/window.py)")
    p.add_argument("--window-step", type=int, default=1, metavar="S",
                   help="slide stride for --window (default 1)")
    p.add_argument("--window-batch", action="store_true",
                   help="with --window: also run the batched slide — every "
                        "window hop as one lane of a single stacked launch "
                        "(composes with --shard)")
    p.add_argument("--stream", action="store_true",
                   help="with --window: run the streaming-campaign scheduler "
                        "too — the slide windows consumed as campaigns with "
                        "incremental anchor maintenance (core/window.py "
                        "run_window_stream_batched; composes with --shard)")
    p.add_argument("--ingest", action="store_true",
                   help="build the store by replaying the sequence as a "
                        "seeded edge-event firehose (core/ingest.py) — "
                        "snapshots are born from watermark cuts, asserted "
                        "bit-identical, and serve every mode below")
    p.add_argument("--campaign-width", type=_campaign_width, default=4,
                   metavar="C",
                   help="windows per streaming campaign for --stream "
                        "(default 4), or 'auto' to let the Δ-volume DP "
                        "(core/window.py optimal_campaigns) choose the "
                        "partition — see docs/STREAMING.md")
    p.add_argument("--fused-k", type=int, default=1, metavar="K",
                   help="fused-chunk size for the sliding-window/stream "
                        "launches: up to K frontier-masked sweeps per fused "
                        "kernel dispatch (kernels/edge_relax_multi; "
                        "bit-identical results at any K, default 1)")
    p.add_argument("--calibrate", action="store_true",
                   help="with --stream: fit a measured SweepCostModel "
                        "(core/costmodel.py) from timed sweeps, print it, "
                        "and hand it to the timed stream's campaign planner "
                        "(campaign-width 'auto' prices in modeled ns)")
    args = p.parse_args(argv)
    if args.window_batch and args.window is None:
        p.error("--window-batch requires --window W")
    if args.stream and args.window is None:
        p.error("--stream requires --window W")
    if args.calibrate and not args.stream:
        p.error("--calibrate requires --stream")
    if args.fused_k < 1:
        p.error(f"--fused-k must be >= 1, got {args.fused_k}")
    mesh = make_snapshot_mesh() if args.shard else None

    sr = ALL_SEMIRINGS[args.alg]
    print(f"[evolve] generating {args.snapshots} snapshots of "
          f"~{args.edges} edges ({args.changes} changes each) ...")
    seq = make_evolving_sequence(args.nodes, args.edges, args.snapshots,
                                 args.changes, seed=args.seed)
    store = _ingest_store(seq) if args.ingest else SnapshotStore(seq)

    t0 = time.perf_counter()
    ks_res, ks_stats = run_kickstarter_stream(store, sr, args.source)
    t_ks = time.perf_counter() - t0
    print(f"[evolve] KickStarter streaming: {t_ks:.2f}s "
          f"(tainted/step: {[s.tainted for s in ks_stats[1:]]})")

    dh = run_direct_hop(store, sr, args.source)
    print(f"[evolve] Direct-Hop:            {dh.wall_s:.2f}s  "
          f"speedup {t_ks / dh.wall_s:.2f}x")

    dhb = run_direct_hop_batched(store, sr, args.source, mesh=mesh)
    print(f"[evolve] Direct-Hop (batched):  {dhb.wall_s:.2f}s  "
          f"speedup {t_ks / dhb.wall_s:.2f}x")
    if mesh is not None:
        _shard_report(mesh, "dhb", dhb.lane_layout)

    plan = optimal_plan(store)
    ws = run_plan(store, plan, sr, args.source)
    print(f"[evolve] Work-Sharing (TG/DP):  {ws.wall_s:.2f}s  "
          f"speedup {t_ks / ws.wall_s:.2f}x  "
          f"(Δ-edges {ws.added_edges} vs DH "
          f"{plan_added_edges(store, _dh_plan(args.snapshots))})")

    wsb = run_plan_batched(store, plan, sr, args.source, mesh=mesh)
    print(f"[evolve] Work-Sharing (batched):{wsb.wall_s:.2f}s  "
          f"speedup {t_ks / wsb.wall_s:.2f}x  "
          f"({len(wsb.hop_stats)} level launches vs "
          f"{len(ws.hop_stats)} sequential hops)")
    if mesh is not None:
        _shard_report(mesh, "wsb", wsb.lane_layout)

    if args.window is not None:
        windows = slide_windows(args.snapshots, args.window,
                                step=args.window_step)
        sl = run_window_slide(store, sr, args.source, args.window,
                              step=args.window_step, fused_k=args.fused_k)
        print(f"[evolve] Window slide (seq):   {sl.wall_s:.2f}s  "
              f"({len(windows)} windows of width {args.window}, "
              f"anchor T{sl.anchor}, Δ-edges {sl.added_edges})")
        slb = None
        if args.window_batch:
            slb = run_window_slide_batched(store, sr, args.source,
                                           args.window, step=args.window_step,
                                           mesh=mesh, fused_k=args.fused_k)
            print(f"[evolve] Window slide (batch): {slb.wall_s:.2f}s  "
                  f"speedup {sl.wall_s / slb.wall_s:.2f}x  "
                  f"(1 stacked launch vs {len(sl.hop_stats)} hops)")
            if mesh is not None:
                _shard_report(mesh, "windows", slb.lane_layout)
        stm = None
        if args.stream:
            # Warm-up: compiles the campaign-shaped traces and builds the
            # blocks BOTH paths touch, then the anchor cache is dropped so
            # the timed stream pays its real 1-rebuild + hops cost — without
            # this the stream eats all compile time and the cold baseline
            # free-rides on its traces (see benchmarks/window_stream.py).
            warm = run_window_stream_batched(store, sr, args.source,
                                             args.window,
                                             step=args.window_step,
                                             campaign_width=args.campaign_width,
                                             mesh=mesh, fused_k=args.fused_k)
            store.release(("AS",))
            cost_model = None
            if args.calibrate:
                # Fit measured per-edge/per-sweep prices on the exact store
                # and launch options the timed run uses, folding in the
                # warm-up's measured stable fraction as the hop discount.
                from repro.core.costmodel import calibrate
                cost_model = calibrate(store, sr, args.source,
                                       stable_milli=warm.stable_milli,
                                       fused_k=args.fused_k)
                print(f"[evolve] calibrated sweep cost: "
                      f"{cost_model.per_edge_nanos}ns/edge + "
                      f"{cost_model.per_sweep_nanos}ns/sweep "
                      f"(hops discounted {cost_model.stable_milli}‰ stable)")
            # the warm-up's measured stable fraction becomes the Δ-volume
            # DP's instability discount for the timed run (deterministic
            # load: the warm-up saw the exact hops the plan will price);
            # with --calibrate the fitted model replaces the raw-count
            # objective outright
            stm = run_window_stream_batched(store, sr, args.source,
                                            args.window, step=args.window_step,
                                            campaign_width=args.campaign_width,
                                            stable_milli=warm.stable_milli,
                                            mesh=mesh, cost_model=cost_model,
                                            fused_k=args.fused_k)
            # the cold baseline rebuilds its anchor per campaign: one
            # slide-batched call per campaign with the stream's own anchors
            t0 = time.perf_counter()
            cold = [run_window_slide_batched(store, sr, args.source,
                                             windows=c, anchor=a, mesh=mesh,
                                             fused_k=args.fused_k)
                    for c, a in zip(stm.campaigns, stm.anchors)]
            t_cold = time.perf_counter() - t0
            shape = (f"widths {[len(c) for c in stm.campaigns]}"
                     if args.campaign_width == "auto"
                     else f"of <={args.campaign_width}")
            print(f"[evolve] Window stream:        {stm.wall_s:.2f}s  "
                  f"vs cold campaigns {t_cold:.2f}s  "
                  f"({len(stm.campaigns)} campaigns "
                  f"{shape}: {stm.anchor_rebuilds} rebuilds "
                  f"+ {stm.anchor_hops} anchor hops + {stm.anchor_hits} hits "
                  f"vs {len(cold)} rebuilds; anchor-Δ "
                  f"{stm.anchor_delta_edges} edges; "
                  f"stable {stm.stable_milli}‰)")
            if stm.plan is not None:
                unit = ("modeled ns" if stm.plan.cost_model is not None
                        else "modeled Δ-edges")
                pricing = ("calibrated SweepCostModel"
                           if stm.plan.cost_model is not None
                           else f"{stm.plan.stable_milli}‰ stable")
                print(f"[evolve]   campaign plan (auto, lane_budget "
                      f"{stm.plan.lane_budget}): "
                      f"slide {stm.plan.slide_edges} + anchor "
                      f"{stm.plan.anchor_edges} + pad "
                      f"{stm.plan.padding_edges} = {stm.plan.total_edges} "
                      f"{unit} (priced at {pricing})")
            if mesh is not None:
                _shard_report(mesh, "stream", stm.lane_layout)

    if args.verify:
        for i in range(args.snapshots):
            ref = run_to_fixpoint(store.snapshot_view(i), sr, args.source).values
            for label, res in (("ks", ks_res[i]), ("dh", dh.results[i]),
                               ("dhb", dhb.results[i]), ("ws", ws.results[i]),
                               ("wsb", wsb.results[i])):
                np.testing.assert_allclose(np.asarray(res), np.asarray(ref),
                                           rtol=1e-6, err_msg=f"{label} snap {i}")
        print("[evolve] verify: all modes match from-scratch on every snapshot")
        if args.window is not None:
            from repro.graph import EdgeView
            for wnd in windows:
                ref = run_to_fixpoint(
                    EdgeView((store.window_block(*wnd),), store.num_nodes),
                    sr, args.source).values
                np.testing.assert_allclose(np.asarray(sl.results[wnd]),
                                           np.asarray(ref), rtol=1e-6,
                                           err_msg=f"window slide {wnd}")
                if slb is not None:
                    np.testing.assert_array_equal(
                        np.asarray(slb.results[wnd]),
                        np.asarray(sl.results[wnd]),
                        err_msg=f"batched window slide {wnd}")
            if stm is not None:
                for cold_run, campaign in zip(cold, stm.campaigns):
                    for wnd in campaign:
                        np.testing.assert_array_equal(
                            np.asarray(stm.results[wnd]),
                            np.asarray(cold_run.results[wnd]),
                            err_msg=f"stream vs cold campaign {wnd}")
            print("[evolve] verify: window slide exact on every window"
                  + (" (batched bit-identical)" if slb is not None else "")
                  + (" (stream bit-identical to cold campaigns)"
                     if stm is not None else ""))


def _dh_plan(n):
    from repro.core import direct_hop_plan
    return direct_hop_plan(n=n)


if __name__ == "__main__":
    main()

"""Serving drivers: the evolving-graph query service + LM prefill/decode.

Graph query service (``core/service.py``) — a deterministic seeded load
generator simulates many concurrent clients issuing heterogeneous window
queries (mixed semirings, sources, window extents, campaign widths) as an
open-loop arrival schedule, and drives a :class:`QueryService` one
scheduler turn per tick:

    PYTHONPATH=src python -m repro.launch.serve --service \\
        --nodes 400 --edges 3000 --snaps 6 --changes 200 \\
        --clients 4 --seed 7

LM serving-loop idiom (the original driver — batched prefill + decode):

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \\
        --reduced --batch 4 --prompt-len 16 --decode-steps 8

``benchmarks/serve.py`` reuses ``generate_load``/``run_service_load`` to
gate the service's exact counters and throughput/latency ratios in CI.
"""

from __future__ import annotations

import argparse
import random
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced_config
from repro.core.service import QueryService
from repro.core.snapshots import SnapshotStore
from repro.core.window import slide_windows
from repro.graph.engine import host_sync
from repro.graph.generators import make_evolving_sequence
from repro.graph.semiring import ALL_SEMIRINGS
from repro.models.transformer import (
    init_kv_cache,
    init_lm_params,
    lm_decode_step,
    lm_prefill,
)


def generate_load(num_snapshots, *, num_clients=6, seed=0,
                  algs=("sssp", "bfs"), num_sources=2, width_range=(2, 3),
                  campaign_widths=(1, 2, 3), bursts=3):
    """Deterministic seeded open-loop load plan for the query service.

    Draws per-client query specs from small pools (``algs`` semirings ×
    ``num_sources`` sources — small so concurrent clients collide on query
    keys and exercise anchor sharing), a sliding-window plan of a seeded
    width starting at a seeded offset, and a campaign width; then cuts
    each client's window sequence into ``bursts`` arrival chunks. Clients
    with later window starts arrive in later bursts (arrivals track query
    time), so service anchors stay nested under open-loop admission.

    Returns ``(specs, schedule)``: ``specs`` is one dict per client
    (``name``/``alg``/``source``/``campaign_width``/``windows``) and
    ``schedule`` is a list of ticks, each a list of
    ``(client_index, windows)`` arrival bursts. Everything is derived from
    ``random.Random(seed)`` — same seed, same plan, on any machine.
    """
    rng = random.Random(seed)
    specs = []
    for idx in range(num_clients):
        alg = algs[rng.randrange(len(algs))]
        source = rng.randrange(num_sources)
        width = rng.randint(*width_range)
        start = rng.randint(0, max(0, num_snapshots - width - 2))
        windows = slide_windows(num_snapshots, width, start=start)
        specs.append({
            "name": f"load-{seed}-{idx}",
            "alg": alg,
            "source": source,
            "campaign_width": campaign_widths[
                rng.randrange(len(campaign_widths))],
            "windows": windows,
        })
    order = sorted(range(num_clients),
                   key=lambda i: (specs[i]["windows"][0][0], i))
    schedule = [[] for _ in range(bursts)]
    for rank, idx in enumerate(order):
        windows = specs[idx]["windows"]
        first = min(rank * bursts // max(1, num_clients), bursts - 1)
        cut = max(1, -(-len(windows) // (bursts - first)))
        for chunk_no, lo in enumerate(range(0, len(windows), cut)):
            tick = min(first + chunk_no, bursts - 1)
            schedule[tick].append((idx, windows[lo:lo + cut]))
    return specs, schedule


def run_service_load(store, specs, schedule, *, lane_budget=8,
                     turn_budget=None, mesh=None):
    """Drive a :class:`QueryService` with an open-loop load plan.

    Registers one client per spec, then per tick admits that tick's
    arrival bursts and runs ONE scheduler turn (open loop: arrivals do
    not wait for completions), then drains the backlog. Returns
    ``(service, clients)`` — results/latencies live on the clients, the
    launch log and aggregate metrics on the service.
    """
    service = QueryService(store, lane_budget=lane_budget,
                           turn_budget=turn_budget, mesh=mesh)
    clients = [service.register(ALL_SEMIRINGS[s["alg"]], s["source"],
                                campaign_width=s["campaign_width"],
                                name=s["name"])
               for s in specs]
    for tick in schedule:
        for idx, windows in tick:
            service.submit(clients[idx], windows)
        service.turn()
    service.drain()
    return service, clients


def _serve_graph(args):
    """CLI path for ``--service``: seeded load over a generated sequence."""
    store = SnapshotStore(make_evolving_sequence(
        args.nodes, args.edges, args.snaps, args.changes, seed=args.seed))
    specs, schedule = generate_load(args.snaps, num_clients=args.clients,
                                    seed=args.seed)
    t0 = time.perf_counter()
    service, _clients = run_service_load(store, specs, schedule,
                                         lane_budget=args.lane_budget,
                                         turn_budget=args.turn_budget)
    wall = time.perf_counter() - t0
    m = service.metrics()
    print(f"[serve] {args.clients} clients over {args.snaps} snapshots: "
          f"{m.completed}/{m.admitted} queries in {m.turns} turns / "
          f"{m.launches} launches ({wall:.2f}s)")
    print(f"[serve] occupancy {m.batch_occupancy:.2f} lanes/launch "
          f"({m.padded_lanes} padded), anchors {m.anchor_rebuilds} rebuilds "
          f"+ {m.anchor_hops} hops + {m.anchor_hits} hits")
    print(f"[serve] {m.queries_per_sec:.1f} queries/s, "
          f"p50 {m.latency_us(50) / 1e3:.1f}ms, "
          f"p99 {m.latency_us(99) / 1e3:.1f}ms")
    return service


def _serve_lm(args):
    """CLI path for ``--arch``: batched LM prefill + decode loop."""
    cfg, family = (reduced_config(args.arch) if args.reduced
                   else get_arch(args.arch))
    if family != "lm":
        raise SystemExit(f"--arch {args.arch} is not an LM; serve.py serves LMs")

    params = init_lm_params(jax.random.PRNGKey(args.seed), cfg)
    max_seq = args.prompt_len + args.decode_steps
    toks = jax.random.randint(jax.random.PRNGKey(1), (args.batch, args.prompt_len),
                              0, cfg.vocab, dtype=jnp.int32)

    prefill = jax.jit(lambda p, t: lm_prefill(cfg, p, t))
    decode = jax.jit(lambda p, c, t, pos: lm_decode_step(cfg, p, c, t, pos))

    t0 = time.perf_counter()
    logits, pcache = prefill(params, toks)
    cache = init_kv_cache(cfg, args.batch, max_seq, dtype=pcache["k"].dtype)
    cache = {
        "k": cache["k"].at[:, :, :args.prompt_len].set(pcache["k"]),
        "v": cache["v"].at[:, :, :args.prompt_len].set(pcache["v"]),
    }
    next_tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    out_tokens = [next_tok]
    for i in range(args.decode_steps - 1):
        logits, cache = decode(params, cache, next_tok,
                               jnp.int32(args.prompt_len + i))
        next_tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        out_tokens.append(next_tok)
    out = jnp.concatenate(out_tokens, axis=1)
    host_sync(out)
    dt = time.perf_counter() - t0
    print(f"[serve] {args.arch}: prefill {args.batch}x{args.prompt_len} + "
          f"{args.decode_steps} decode steps in {dt:.2f}s")
    print("[serve] sampled token ids:", out[0].tolist())
    assert not bool(jnp.any(jnp.isnan(logits))), "NaN logits"
    return out


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--service", action="store_true",
                   help="serve seeded graph query load (core/service.py)")
    p.add_argument("--arch", help="LM architecture to serve (prefill+decode)")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--decode-steps", type=int, default=8)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--nodes", type=int, default=400)
    p.add_argument("--edges", type=int, default=3000)
    p.add_argument("--snaps", type=int, default=6)
    p.add_argument("--changes", type=int, default=200)
    p.add_argument("--clients", type=int, default=4)
    p.add_argument("--lane-budget", type=int, default=8)
    p.add_argument("--turn-budget", type=int, default=None)
    args = p.parse_args(argv)

    if args.service:
        return _serve_graph(args)
    if args.arch:
        return _serve_lm(args)
    raise SystemExit("pass --service (graph query load) or --arch <lm>")


if __name__ == "__main__":
    main()

"""Serving driver: batched prefill + decode on the local mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
        --reduced --batch 4 --prompt-len 16 --decode-steps 8
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced_config
from repro.graph.engine import host_sync
from repro.models.transformer import (
    init_kv_cache,
    init_lm_params,
    lm_decode_step,
    lm_prefill,
)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--decode-steps", type=int, default=8)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg, family = reduced_config(args.arch) if args.reduced else get_arch(args.arch)
    if family != "lm":
        raise SystemExit(f"--arch {args.arch} is not an LM; serve.py serves LMs")

    params = init_lm_params(jax.random.PRNGKey(args.seed), cfg)
    max_seq = args.prompt_len + args.decode_steps
    toks = jax.random.randint(jax.random.PRNGKey(1), (args.batch, args.prompt_len),
                              0, cfg.vocab, dtype=jnp.int32)

    prefill = jax.jit(lambda p, t: lm_prefill(cfg, p, t))
    decode = jax.jit(lambda p, c, t, pos: lm_decode_step(cfg, p, c, t, pos))

    t0 = time.perf_counter()
    logits, pcache = prefill(params, toks)
    cache = init_kv_cache(cfg, args.batch, max_seq, dtype=pcache["k"].dtype)
    cache = {
        "k": cache["k"].at[:, :, :args.prompt_len].set(pcache["k"]),
        "v": cache["v"].at[:, :, :args.prompt_len].set(pcache["v"]),
    }
    next_tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    out_tokens = [next_tok]
    for i in range(args.decode_steps - 1):
        logits, cache = decode(params, cache, next_tok,
                               jnp.int32(args.prompt_len + i))
        next_tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        out_tokens.append(next_tok)
    out = jnp.concatenate(out_tokens, axis=1)
    host_sync(out)
    dt = time.perf_counter() - t0
    print(f"[serve] {args.arch}: prefill {args.batch}x{args.prompt_len} + "
          f"{args.decode_steps} decode steps in {dt:.2f}s")
    print("[serve] sampled token ids:", out[0].tolist())
    assert not bool(jnp.any(jnp.isnan(logits))), "NaN logits"
    return out


if __name__ == "__main__":
    main()

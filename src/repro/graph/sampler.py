"""Fanout neighbor sampler (GraphSAGE-style) for sampled-training shapes.

``minibatch_lg`` (232,965 nodes / 114M edges / batch_nodes=1,024 /
fanout 15-10) requires a real sampler: this one builds an in-neighbor CSR
once, then per batch samples a fixed fanout per hop with replacement
(padding with sentinel edges when a vertex's in-degree is 0), producing
**fixed-shape** subgraph tensors so the jitted train step never retraces.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SampledSubgraph:
    """Fixed-shape k-hop sampled subgraph (host arrays, device-put by the data feeder).

    Layout: ``nodes[0:n_seeds]`` are the seeds; each hop appends its sampled
    frontier. Edges point hop-(k+1) -> hop-k (message direction), expressed in
    *local* indices into ``nodes``. Padded edges have ``local_dst == n_local``.
    """

    nodes: np.ndarray       # int32 [n_local] global ids (padded with 0)
    node_valid: np.ndarray  # bool  [n_local]
    src: np.ndarray         # int32 [n_edges] local ids
    dst: np.ndarray         # int32 [n_edges] local ids (== n_local for padding)
    n_seeds: int

    @property
    def n_local(self) -> int:
        return int(self.nodes.shape[0])


class NeighborSampler:
    def __init__(self, src: np.ndarray, dst: np.ndarray, num_nodes: int, seed: int = 0):
        # in-neighbor CSR: for each v, the list of u with (u -> v)
        order = np.argsort(dst, kind="stable")
        self._nbr = src[order].astype(np.int32)
        counts = np.bincount(dst, minlength=num_nodes)
        self._offsets = np.zeros(num_nodes + 1, dtype=np.int64)
        np.cumsum(counts, out=self._offsets[1:])
        self._num_nodes = num_nodes
        self._rng = np.random.default_rng(seed)

    def _sample_one_hop(self, frontier: np.ndarray, fanout: int):
        """Sample ``fanout`` in-neighbors per frontier vertex (fixed shape)."""
        deg = self._offsets[frontier + 1] - self._offsets[frontier]
        # uniform with replacement; degree-0 vertices yield padded edges
        r = self._rng.integers(0, np.maximum(deg, 1)[:, None],
                               size=(frontier.shape[0], fanout))
        idx = self._offsets[frontier][:, None] + r
        nbrs = self._nbr[np.minimum(idx, self._nbr.shape[0] - 1)]
        valid = (deg > 0)[:, None] & np.ones_like(r, bool)
        return nbrs.astype(np.int32), valid

    def sample(self, seeds: np.ndarray, fanouts: tuple[int, ...]) -> SampledSubgraph:
        seeds = np.asarray(seeds, dtype=np.int32)
        nodes = [seeds]
        valids = [np.ones(seeds.shape[0], bool)]
        srcs, dsts = [], []
        frontier = seeds
        frontier_valid = np.ones(seeds.shape[0], bool)
        base = 0
        for fanout in fanouts:
            nbrs, valid = self._sample_one_hop(frontier, fanout)
            flat_nbrs = nbrs.reshape(-1)
            # a sample is valid only if its parent frontier slot was valid
            flat_valid = valid.reshape(-1) & np.repeat(frontier_valid, fanout)
            new_base = base + frontier.shape[0]
            # local edges: sampled neighbor (at new_base + i) -> frontier vertex (at base + i//fanout)
            e_src = new_base + np.arange(flat_nbrs.shape[0], dtype=np.int32)
            e_dst = base + (np.arange(flat_nbrs.shape[0], dtype=np.int32) // fanout)
            srcs.append(e_src)
            dsts.append(np.where(flat_valid, e_dst, np.int32(-1)))
            nodes.append(np.where(flat_valid, flat_nbrs, 0).astype(np.int32))
            valids.append(flat_valid)
            frontier = flat_nbrs  # fixed shape: sample next hop from all slots
            frontier_valid = flat_valid
            base = new_base
        nodes_arr = np.concatenate(nodes)
        valid_arr = np.concatenate(valids)
        n_local = nodes_arr.shape[0]
        src_arr = np.concatenate(srcs)
        dst_arr = np.concatenate(dsts)
        dst_arr = np.where(dst_arr < 0, n_local, dst_arr).astype(np.int32)
        return SampledSubgraph(
            nodes=nodes_arr, node_valid=valid_arr,
            src=src_arr.astype(np.int32), dst=dst_arr, n_seeds=seeds.shape[0],
        )


def subgraph_shapes(n_seeds: int, fanouts: tuple[int, ...]) -> tuple[int, int]:
    """(n_local_nodes, n_edges) for the fixed-shape sampled subgraph."""
    n_local, n_edges, frontier = n_seeds, 0, n_seeds
    for f in fanouts:
        n_edges += frontier * f
        frontier *= f
        n_local += frontier
    return n_local, n_edges

"""Frontier-masked edge-relaxation fixpoint engine (TPU-native KickStarter core).

One *sweep* is a dense Bellman-Ford-style round over an edge view:

    cand[e]  = combine(values[src[e]], w[e])        (masked to the frontier)
    best[v]  = segment_reduce(cand, dst)            (min or max semiring)
    values'  = meet(values, best);  frontier' = strictly-improved vertices

Monotone semirings make the dense sweep idempotent and order-free, which is
what lets us replace the CPU papers' per-vertex worklists + atomics with
segment reductions (DESIGN.md §2). ``parent[v]`` tracks the dependence edge
source that produced ``values[v]`` — the KickStarter trimming baseline
(core/kickstarter.py) consumes it on deletions.

The engine operates on *tuples of edge blocks* rather than one concatenated
array: a CommonGraph view is (CG block, Δ block, Δ block, …) and blocks are
physically shared between snapshots (the paper's mutation-free
representation executes as-is — no concatenation copies, and jit traces are
keyed only on the tuple of block shapes). Everything is shape-static and
jit/vmap/pjit-friendly: the snapshot axis of the CommonGraph executor vmaps
directly over the value/frontier state (and over stacked per-snapshot Δ
blocks).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.edgeset import EdgeBlock, EdgeView
from repro.graph.semiring import Semiring

INT_MAX = jnp.iinfo(jnp.int32).max
NO_PARENT = jnp.int32(-1)

Blocks = tuple[EdgeBlock, ...]


class FixpointResult(NamedTuple):
    """Final state of a fixpoint run plus its iteration/work accounting."""

    values: jnp.ndarray      # float32 [num_nodes]
    parent: jnp.ndarray      # int32  [num_nodes], -1 = none/source
    iterations: jnp.ndarray  # int32 scalar — sweeps executed
    edge_work: jnp.ndarray   # float32 scalar — frontier-masked edge relaxations
    # int32 scalar (per-lane when batched): |instability seed set| from the
    # stability analysis (graph/stability.py), None for from-scratch runs
    # where no Δ seeding happened. Identical under both seed modes.
    unstable: jnp.ndarray | None = None


class QueryState(NamedTuple):
    """A converged query state detached from its run statistics.

    The cross-launch unit of reuse: a ``(values, parent)`` pair extracted
    from a :class:`FixpointResult` can be cached (SnapshotStore's anchor
    family), re-seeded into a later incremental launch, or broadcast into
    batched lanes via :func:`gather_lane_states`. Values are a pure function
    of ``(edge set, semiring, source)`` — the monotone rounded fixpoint is
    unique, so a state reached by warm hops equals the from-scratch one
    bit-for-bit. Parents are dependence-valid but tie-break by construction
    path (only the deletion-trimming baseline consumes them).

    Cache-lifecycle hooks: :attr:`nbytes` is what the SnapshotStore LRU
    charges a cached state against its byte budget, and pin/release of a
    cached state is managed at the store layer (``SnapshotStore.pin`` /
    ``unpin`` / ``release(("AS",))``) — the state itself stays an immutable
    value, so pinning can never change what a launch computes.
    """

    values: jnp.ndarray      # float32 [num_nodes]
    parent: jnp.ndarray      # int32  [num_nodes]

    @property
    def nbytes(self) -> int:
        """Device footprint the store's LRU accounts for this state."""
        return sum(int(a.size) * a.dtype.itemsize for a in self)


def extract_state(res: FixpointResult) -> QueryState:
    """Detach the reusable (values, parent) state from a fixpoint result."""
    return QueryState(res.values, res.parent)


def host_sync(x):
    """Block until ``x`` (any array/pytree leaf holder) is computed on
    device, returning it — THE sanctioned host-sync point.

    Wall-clock numbers in run records are only honest if the device work
    they bracket has finished, but a stray ``block_until_ready`` inside a
    jitted function fails at trace time (and near the hot path it forces a
    host round-trip per sweep). graphlint rule G004 therefore bans bare
    syncs outside ``benchmarks/``; drivers and executors time through this
    helper instead, keeping every legal sync greppable from one name.
    """
    for leaf in jax.tree_util.tree_leaves(x):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()
    return x


def init_values(num_nodes: int, semiring: Semiring, source: int) -> jnp.ndarray:
    """Fresh value vector: identity everywhere, source_value at source."""
    values = jnp.full((num_nodes,), semiring.identity, dtype=jnp.float32)
    return values.at[source].set(semiring.source_value)


def _segment_reduce(sr: Semiring, data, segment_ids, num_segments):
    if sr.is_min:
        return jax.ops.segment_min(data, segment_ids, num_segments)
    return jax.ops.segment_max(data, segment_ids, num_segments)


def _block_sweep(semiring: Semiring, num_nodes: int, values, frontier,
                 src, dst, w, track_parents: bool = True):
    """One block's (best, winner_src, work) against the current frontier.

    ``track_parents=False`` (CommonGraph mode): dependence parents exist
    solely so KickStarter can trim on *deletions*. Deletion-free schedules
    (Direct-Hop / TG work-sharing) never trim, so the winner-src segment
    reduce — half the per-sweep segment ops — is skipped entirely. This is
    the paper's "deletions are what make streaming expensive" claim showing
    up inside the engine itself (EXPERIMENTS.md §Perf).
    """
    ident = jnp.float32(semiring.identity)
    active = frontier[src]  # pad edges read frontier[PAD_SRC]; their dst is the sentinel
    cand = jnp.where(active, semiring.combine(values[src], w), ident)
    blk_best = _segment_reduce(semiring, cand, dst, num_nodes + 1)[:num_nodes]
    work = jnp.sum(active & (dst < num_nodes), dtype=jnp.float32)
    if not track_parents:
        return blk_best, None, work
    # smallest src achieving this block's best (merged across blocks by caller)
    best_pad = jnp.concatenate([blk_best, jnp.float32([ident])])
    is_win = active & (cand == best_pad[dst])
    parent_cand = jnp.where(is_win, src, INT_MAX)
    blk_winner = jax.ops.segment_min(parent_cand, dst, num_nodes + 1)[:num_nodes]
    return blk_best, blk_winner, work


def relax_sweep(
    semiring: Semiring,
    num_nodes: int,
    values: jnp.ndarray,
    parent: jnp.ndarray,
    frontier: jnp.ndarray,
    blocks: Blocks,
    gated: bool = False,
    track_parents: bool = True,
):
    """One frontier-masked relaxation sweep over all blocks.

    ``gated`` (beyond-paper optimization, EXPERIMENTS.md §Perf): a block
    whose sources contain no frontier vertex is skipped entirely via
    lax.cond — the TPU-dense analogue of the CPU papers' per-vertex
    worklists at edge-block granularity. Exactness is unaffected (skipped
    blocks can only produce identity candidates).

    Returns (values, parent, improved, work).
    """
    ident = jnp.float32(semiring.identity)
    best = jnp.full((num_nodes,), ident)
    bests = []
    work = jnp.float32(0)
    for src, dst, w in blocks:
        if gated:
            none_winner = (jnp.full((num_nodes,), INT_MAX, dtype=jnp.int32)
                           if track_parents else None)
            blk_best, blk_winner, dw = jax.lax.cond(
                jnp.any(frontier[src]),
                lambda s=src, d=dst, ww=w: _block_sweep(
                    semiring, num_nodes, values, frontier, s, d, ww,
                    track_parents),
                lambda: (jnp.full((num_nodes,), ident), none_winner,
                         jnp.float32(0)),
            )
        else:
            blk_best, blk_winner, dw = _block_sweep(
                semiring, num_nodes, values, frontier, src, dst, w,
                track_parents)
        best = semiring.better(best, blk_best)
        bests.append((blk_best, blk_winner))
        work = work + dw

    improved = semiring.strictly_better(best, values)
    new_values = semiring.better(values, best)

    if not track_parents:
        return new_values, parent, improved, work

    # Dependence parent: the smallest src among edges achieving the global
    # best (per-block winners merged; only blocks matching the global best
    # contribute, which preserves the ungated tie-break exactly).
    winner = jnp.full((num_nodes,), INT_MAX, dtype=jnp.int32)
    for blk_best, blk_winner in bests:
        winner = jnp.where(blk_best == best,
                           jnp.minimum(winner, blk_winner), winner)
    new_parent = jnp.where(improved, winner, parent)
    return new_values, new_parent, improved, work


def relax_sweep_fused(
    semiring: Semiring,
    num_nodes: int,
    values: jnp.ndarray,
    parent: jnp.ndarray,
    frontier: jnp.ndarray,
    blocks: Blocks,
    k: int = 1,
    allowed: jnp.ndarray | None = None,
    gated: bool = False,
    track_parents: bool = True,
    use_pallas: bool = False,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Up to ``k`` frontier-masked sweeps as one fused chunk.

    The fused unit of execution ``_fixpoint`` consumes: a chunk runs sweeps
    until the frontier empties or ``min(k, allowed)`` is reached (``allowed``
    is a traced int32 cap, default ``k`` — the fixpoint driver uses it to
    respect ``max_iters`` exactly), and convergence checks inside the chunk
    never surface to the host. Two implementations, bit-identical by the
    differential harness (tests/test_kernels_diff.py):

    * reference (default): an inner ``lax.while_loop`` over
      :func:`relax_sweep` — the portable path and the engine's CPU default;
    * ``use_pallas=True``: the fused pallas kernel
      (``kernels/edge_relax_multi``), which keeps values/frontier
      VMEM-resident across all sweeps with an on-chip early exit — the TPU
      path (``interpret=True`` validates it in this CPU-only container).

    graphlint rule G010 grants this call to ``graph.stability`` seeding and
    the engine's ``_fixpoint`` only — everything else reaches fused sweeps
    through the launch stack's ``fused_k`` option.

    Returns ``(values, parent, frontier, sweeps, work)``.
    """
    if allowed is None:
        allowed = jnp.int32(k)
    if use_pallas:
        from repro.kernels import relax_multi
        from repro.kernels.edge_relax.edge_relax import KERNEL_OP_FOR
        src = jnp.concatenate([b[0] for b in blocks])
        dst = jnp.concatenate([b[1] for b in blocks])
        w = jnp.concatenate([b[2] for b in blocks])
        return relax_multi(values, parent, frontier, src, dst, w, allowed,
                           op=KERNEL_OP_FOR[semiring.name],
                           num_nodes=num_nodes, k=k,
                           track_parents=track_parents, interpret=interpret)

    def cond(state):
        _, _, frontier, s, _ = state
        return jnp.logical_and(s < allowed, jnp.any(frontier))

    def body(state):
        values, parent, frontier, s, work = state
        values, parent, improved, dw = relax_sweep(
            semiring, num_nodes, values, parent, frontier, blocks,
            gated=gated, track_parents=track_parents)
        return values, parent, improved, s + 1, work + dw

    init = (values, parent, frontier, jnp.int32(0), jnp.float32(0))
    return jax.lax.while_loop(cond, body, init)


def _fixpoint(semiring: Semiring, num_nodes: int, max_iters: int,
              values, parent, frontier, blocks: Blocks,
              gated: bool = False, track_parents: bool = True,
              fused_k: int = 1) -> FixpointResult:
    def cond(state):
        _, _, frontier, it, _ = state
        return jnp.logical_and(it < max_iters, jnp.any(frontier))

    if fused_k > 1:
        # Consume fused chunks: each outer step advances up to fused_k
        # sweeps via relax_sweep_fused, so the host-visible convergence
        # check runs once per chunk instead of once per sweep. The sweep
        # sequence (and therefore values/parent/iterations/edge_work) is
        # bit-identical to the unfused loop: the chunk's dynamic cap
        # min(fused_k, max_iters - it) never overruns max_iters, and the
        # chunk stops early the moment the frontier empties.
        def chunk_body(state):
            values, parent, frontier, it, work = state
            cap = jnp.minimum(jnp.int32(fused_k), max_iters - it)
            values, parent, frontier, s, dw = relax_sweep_fused(
                semiring, num_nodes, values, parent, frontier, blocks,
                k=fused_k, allowed=cap, gated=gated,
                track_parents=track_parents)
            return values, parent, frontier, it + s, work + dw

        init = (values, parent, frontier, jnp.int32(0), jnp.float32(0))
        values, parent, _, it, work = jax.lax.while_loop(cond, chunk_body,
                                                         init)
        return FixpointResult(values, parent, it, work)

    def body(state):
        values, parent, frontier, it, work = state
        values, parent, improved, dw = relax_sweep(
            semiring, num_nodes, values, parent, frontier, blocks, gated=gated,
            track_parents=track_parents)
        return values, parent, improved, it + 1, work + dw

    init = (values, parent, frontier, jnp.int32(0), jnp.float32(0))
    values, parent, _, it, work = jax.lax.while_loop(cond, body, init)
    return FixpointResult(values, parent, it, work)


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 7, 8, 9))
def _fixpoint_jit(semiring, num_nodes, max_iters, values, parent, frontier,
                  blocks, gated=False, track_parents=True, fused_k=1):
    return _fixpoint(semiring, num_nodes, max_iters, values, parent, frontier,
                     blocks, gated, track_parents, fused_k)


def run_to_fixpoint(
    view: EdgeView,
    semiring: Semiring,
    source: int,
    max_iters: int = 10_000,
    values: jnp.ndarray | None = None,
    parent: jnp.ndarray | None = None,
    frontier: jnp.ndarray | None = None,
    gated: bool = False,
    track_parents: bool = True,
    fused_k: int = 1,
) -> FixpointResult:
    """Run the query to fixpoint on ``view`` (from scratch or a warm state).

    ``fused_k`` > 1 makes the fixpoint consume fused chunks of up to that
    many sweeps per convergence check (:func:`relax_sweep_fused`) — a pure
    launch-shape knob, bit-identical results at any value.
    """
    n = view.num_nodes
    fresh = values is None
    if fresh:
        values = init_values(n, semiring, source)
    if parent is None:
        parent = jnp.full((n,), NO_PARENT, dtype=jnp.int32)
    if frontier is None:
        # Fresh start: only the source can seed improvements. Warm start with
        # an unknown perturbation: every vertex may need to re-propagate.
        frontier = (jnp.zeros((n,), bool).at[source].set(True) if fresh
                    else jnp.ones((n,), bool))
    return _fixpoint_jit(semiring, n, max_iters, values, parent, frontier,
                         tuple(view.blocks), gated, track_parents, fused_k)


def incremental_additions(
    view: EdgeView,
    added: EdgeView | EdgeBlock,
    semiring: Semiring,
    values: jnp.ndarray,
    parent: jnp.ndarray,
    max_iters: int = 10_000,
    gated: bool = False,
    track_parents: bool = True,
    seed: str = "instability",
    fused_k: int = 1,
) -> FixpointResult:
    """Addition-only incremental update (the cheap KickStarter direction).

    ``view`` must already include the added blocks; ``added`` is just the new
    edges. Seeds the frontier from the stable-vertex analysis
    (graph/stability.py): the Δ edges are relaxed once against the anchor
    state and only the vertices they strictly improved — the instability
    set — enter the fixpoint frontier. ``seed="delta"`` keeps the full-Δ
    baseline seeding (identical values/parents, more seed work; see
    ``stability.seed_state``). Monotonicity guarantees the exact
    from-scratch fixpoint is reached either way.
    """
    from repro.graph.stability import seed_state
    n = view.num_nodes
    add_blocks = (added,) if isinstance(added, EdgeBlock) else tuple(added.blocks)
    seeded = seed_state(semiring, n, values, parent, add_blocks,
                        mode=seed, track_parents=track_parents)
    res = _fixpoint_jit(semiring, n, max_iters, seeded.values, seeded.parent,
                        seeded.frontier, tuple(view.blocks), gated,
                        track_parents, fused_k)
    return FixpointResult(res.values, res.parent, res.iterations + 1,
                          res.edge_work + seeded.seed_work, seeded.unstable)


# ---------------------------------------------------------------------------
# Batched (snapshot-axis) execution: the paper's "breaks the sequential
# dependency" parallelism, realized as one extra tensor axis. Shared blocks
# broadcast; per-snapshot Δ blocks are stacked on axis 0.
# ---------------------------------------------------------------------------

def gather_lane_states(values: jnp.ndarray, parent: jnp.ndarray,
                       lane_to_parent) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Gather per-lane warm-start states for a batched launch.

    ``values``/``parent`` are the previous level's stacked states
    ``[P, N]``; ``lane_to_parent[l]`` names the parent lane whose state
    seeds lane ``l``. Both batched executors route through here: the
    level-synchronous TG executor gathers each sibling's parent state
    (a permutation-with-repeats of the previous level), and the
    sliding-window executor broadcasts the single anchor state to every
    window lane (``lane_to_parent == zeros``). One gather instead of a
    host-side stack keeps the states on-device (and sharded, if they are).
    """
    idx = jnp.asarray(np.asarray(lane_to_parent, dtype=np.int32))
    return values[idx], parent[idx]

def batched_incremental(semiring, num_nodes, max_iters,
                        values, parent, shared_blocks, delta_blocks,
                        track_parents=True, gated=False, seed_blocks=None,
                        lane_valid=None, seed="instability", fused_k=1):
    """vmapped incremental additions (unjitted; launch/dryrun jits with shardings).

    values/parent: [S, N]; shared_blocks: tuple of EdgeBlock (broadcast);
    delta_blocks: tuple of EdgeBlock with leading S axis (stacked).

    ``seed_blocks`` (stacked like delta_blocks, default: all of them): the
    blocks the frontier is seeded from. The level-synchronous TG executor
    carries each lane's *cumulative* Δ from the apex in delta_blocks but
    seeds only from the lane's final parent→child hop, matching the
    sequential executor's per-hop seeding (and its edge-work accounting)
    exactly.

    ``seed`` selects the per-lane seeding mode (graph/stability.py):
    ``"instability"`` masks each lane's seed sweep to its reached vertices
    — the stable-vertex analysis — and ``"delta"`` is the full-Δ baseline.
    Bit-identical results either way; the lane's ``unstable`` count and
    ``edge_work`` are what differ.

    ``lane_valid`` ([S] bool, default: all valid): marks padding lanes the
    executors appended to reach a ``lane_bucket`` (pow2, mesh-divisible)
    lane count. A masked lane carries an all-sentinel Δ and a copied anchor
    state, so its values stay inert by construction; the mask additionally
    zeroes its ``iterations``/``edge_work``/``unstable`` so work and
    stability accounting stay bit-equal to the sequential executors
    regardless of padding.
    """
    from repro.graph.stability import seed_state
    seeds = delta_blocks if seed_blocks is None else seed_blocks

    def one(values, parent, delta_blocks, seed_blocks):
        seeded = seed_state(semiring, num_nodes, values, parent, seed_blocks,
                            mode=seed, track_parents=track_parents)
        res = _fixpoint(semiring, num_nodes, max_iters, seeded.values,
                        seeded.parent, seeded.frontier,
                        shared_blocks + delta_blocks, gated=gated,
                        track_parents=track_parents, fused_k=fused_k)
        return FixpointResult(res.values, res.parent, res.iterations + 1,
                              res.edge_work + seeded.seed_work,
                              seeded.unstable)

    res = jax.vmap(one, in_axes=(0, 0, 0, 0))(values, parent,
                                              delta_blocks, seeds)
    if lane_valid is None:
        return res
    return FixpointResult(
        res.values, res.parent,
        jnp.where(lane_valid, res.iterations, 0),
        jnp.where(lane_valid, res.edge_work, jnp.float32(0)),
        jnp.where(lane_valid, res.unstable, 0))


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 7, 8, 11, 12))
def _batched_incremental_jit(semiring, num_nodes, max_iters,
                             values, parent, shared_blocks, delta_blocks,
                             track_parents=True, gated=False,
                             seed_blocks=None, lane_valid=None,
                             seed="instability", fused_k=1):
    return batched_incremental(semiring, num_nodes, max_iters,
                               values, parent, shared_blocks, delta_blocks,
                               track_parents, gated, seed_blocks, lane_valid,
                               seed, fused_k)


def incremental_additions_batched(
    num_nodes: int,
    semiring: Semiring,
    values: jnp.ndarray,          # [S, N]
    parent: jnp.ndarray,          # [S, N]
    shared_blocks: Blocks,        # broadcast to all snapshots
    delta_blocks: Blocks,         # each with leading [S] axis
    max_iters: int = 10_000,
    track_parents: bool = True,
    gated: bool = False,
    seed_blocks: Blocks | None = None,
    lane_valid: jnp.ndarray | None = None,  # [S] bool; False = padding lane
    seed: str = "instability",
    fused_k: int = 1,
) -> FixpointResult:
    """Batched addition-only updates, one lane per Δ (see batched_incremental).

    Bit-identical per lane to :func:`incremental_additions`; ``fused_k``
    sets the sweeps-per-dispatch chunk size, a pure launch-shape knob.
    """
    return _batched_incremental_jit(semiring, num_nodes, max_iters,
                                    values, parent, tuple(shared_blocks),
                                    tuple(delta_blocks), track_parents, gated,
                                    None if seed_blocks is None
                                    else tuple(seed_blocks), lane_valid, seed,
                                    fused_k)

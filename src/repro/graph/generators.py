"""Evolving-graph generators (host-side, deterministic).

The paper evaluates 50 snapshots, each separated by a batch of 75K edge
changes split evenly between additions and deletions. We reproduce that
protocol with R-MAT graphs sized to this container (DESIGN.md §7.4): an
:class:`EvolvingSequence` holds the initial edge set and, per transition,
the (additions, deletions) batches — from which core/ derives the
CommonGraph and Δ-batches.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.edgeset import edge_keys, keys_to_edges, merge_changes


def rmat_edges(
    num_nodes: int,
    num_edges: int,
    seed: int = 0,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
) -> tuple[np.ndarray, np.ndarray]:
    """R-MAT power-law edge generator (Chakrabarti et al., SDM'04).

    ``num_nodes`` is rounded up to a power of two internally; emitted vertex
    ids are taken modulo ``num_nodes``. Duplicate edges and self-loops are
    removed, so the returned count may be slightly below ``num_edges``.
    """
    rng = np.random.default_rng(seed)
    scale = max(1, int(np.ceil(np.log2(num_nodes))))
    # Oversample: dedup + self-loop removal loses a few percent.
    n_draw = int(num_edges * 1.3) + 16
    src = np.zeros(n_draw, dtype=np.int64)
    dst = np.zeros(n_draw, dtype=np.int64)
    p_ab = a + b
    p_abc = a + b + c
    for _ in range(scale):
        r = rng.random(n_draw)
        right = r >= p_ab  # quadrant c or d -> src bit 1
        bottom = ((r >= a) & (r < p_ab)) | (r >= p_abc)  # b or d -> dst bit 1
        src = (src << 1) | right
        dst = (dst << 1) | bottom
    src %= num_nodes
    dst %= num_nodes
    keep = src != dst
    src, dst = src[keep], dst[keep]
    keys = edge_keys(src, dst, num_nodes)
    keys = np.unique(keys)
    rng.shuffle(keys)
    keys = keys[:num_edges]
    return keys_to_edges(keys, num_nodes)


def edge_weights(keys: np.ndarray, seed: int = 0) -> np.ndarray:
    """Deterministic per-edge weight in (0, 1], a pure function of the edge key.

    Weights must be stable across snapshots (an edge deleted and re-added
    keeps its weight), so they are hashed from the key, not drawn in sequence.
    """
    mult = np.uint64(0x9E3779B97F4A7C15)
    h = (keys.astype(np.uint64) * mult + np.uint64(seed)) >> np.uint64(1)
    u = (h % np.int64(1 << 24)).astype(np.float64) / float(1 << 24)
    return (u * (1.0 - 1e-3) + 1e-3).astype(np.float32)


@dataclasses.dataclass(frozen=True)
class EvolvingSequence:
    """n snapshots over a fixed vertex set, as key-sets + change batches."""

    num_nodes: int
    snapshot_keys: tuple[np.ndarray, ...]       # sorted int64 keys per snapshot
    additions: tuple[np.ndarray, ...]           # keys added at transition i -> i+1
    deletions: tuple[np.ndarray, ...]           # keys deleted at transition i -> i+1
    weight_seed: int = 0

    @property
    def num_snapshots(self) -> int:
        return len(self.snapshot_keys)

    def weights_for(self, keys: np.ndarray) -> np.ndarray:
        return edge_weights(keys, self.weight_seed)


def make_evolving_sequence(
    num_nodes: int,
    num_edges: int,
    num_snapshots: int,
    batch_changes: int,
    seed: int = 0,
    weight_seed: int = 0,
) -> EvolvingSequence:
    """Paper protocol: per transition, batch_changes/2 adds + batch_changes/2 dels."""
    rng = np.random.default_rng(seed + 1)
    src, dst = rmat_edges(num_nodes, num_edges, seed=seed)
    keys = np.sort(edge_keys(src, dst, num_nodes))

    half = batch_changes // 2
    snaps = [keys]
    adds, dels = [], []
    current = keys
    for _ in range(num_snapshots - 1):
        # deletions: sample existing edges
        del_idx = rng.choice(current.shape[0], size=min(half, current.shape[0]),
                             replace=False)
        del_keys = np.sort(current[del_idx])
        # additions: sample fresh edges not currently present
        add_keys = np.empty(0, dtype=np.int64)
        while add_keys.shape[0] < half:
            s = rng.integers(0, num_nodes, size=2 * half)
            d = rng.integers(0, num_nodes, size=2 * half)
            ok = s != d
            cand = np.unique(edge_keys(s[ok], d[ok], num_nodes))
            cand = cand[~np.isin(cand, current)]
            add_keys = np.unique(np.concatenate([add_keys, cand]))
        add_keys = np.sort(rng.permutation(add_keys)[:half])
        nxt = merge_changes(current, add_keys, del_keys)
        snaps.append(nxt)
        adds.append(add_keys)
        dels.append(del_keys)
        current = nxt
    return EvolvingSequence(
        num_nodes=num_nodes,
        snapshot_keys=tuple(snaps),
        additions=tuple(adds),
        deletions=tuple(dels),
        weight_seed=weight_seed,
    )

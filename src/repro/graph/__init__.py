"""Graph substrate: edge sets, semirings, fixpoint engine, generators, sampler.

This layer is the TPU-native re-derivation of the vertex-centric CPU machinery
used by KickStarter/CommonGraph (see DESIGN.md §2): dense, frontier-masked
edge-relaxation sweeps over immutable edge blocks, with monotone-semiring
segment reductions instead of per-vertex worklists and atomics.
"""

from repro.graph.semiring import (
    Semiring,
    BFS,
    SSSP,
    SSWP,
    SSNP,
    VITERBI,
    ALL_SEMIRINGS,
)
from repro.graph.edgeset import (
    EdgeBlock,
    EdgeView,
    PAD_SRC,
    concat_views,
    lane_bucket,
)
from repro.graph.engine import (
    FixpointResult,
    QueryState,
    extract_state,
    host_sync,
    init_values,
    relax_sweep,
    run_to_fixpoint,
    incremental_additions,
    incremental_additions_batched,
)
from repro.graph.generators import rmat_edges, EvolvingSequence, make_evolving_sequence
from repro.graph.sampler import NeighborSampler, SampledSubgraph
from repro.graph.stability import (
    SEED_MODES,
    SeededState,
    seed_mask,
    seed_state,
    stable_fraction_milli,
)

__all__ = [
    "Semiring",
    "BFS",
    "SSSP",
    "SSWP",
    "SSNP",
    "VITERBI",
    "ALL_SEMIRINGS",
    "EdgeBlock",
    "EdgeView",
    "PAD_SRC",
    "concat_views",
    "lane_bucket",
    "FixpointResult",
    "QueryState",
    "extract_state",
    "host_sync",
    "init_values",
    "relax_sweep",
    "run_to_fixpoint",
    "incremental_additions",
    "incremental_additions_batched",
    "rmat_edges",
    "EvolvingSequence",
    "make_evolving_sequence",
    "NeighborSampler",
    "SampledSubgraph",
    "SEED_MODES",
    "SeededState",
    "seed_mask",
    "seed_state",
    "stable_fraction_milli",
]

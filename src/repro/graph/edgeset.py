"""Immutable, mutation-free edge containers (DESIGN.md §1.3).

A graph (or any Triangular-Grid node) is never materialized by mutating a
CSR. It is an :class:`EdgeView`: an ordered tuple of immutable
:class:`EdgeBlock` s — the CommonGraph block plus whichever Δ-batches the
view needs. Blocks are physically shared between snapshots; realizing a
snapshot costs zero copies.

Padding convention: blocks are padded to a fixed granularity so that jit
traces are reused across views of similar size. A padding edge has
``dst == num_nodes`` (it lands in a sentinel segment that every reduction
drops) and ``src == PAD_SRC == 0`` (gathers stay in-bounds).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax.numpy as jnp
import numpy as np

PAD_SRC = 0
DEFAULT_GRANULE = 4096


class EdgeBlock(NamedTuple):
    """One immutable, padded block of edges (a pytree of three arrays)."""

    src: jnp.ndarray  # int32 [n_padded]
    dst: jnp.ndarray  # int32 [n_padded]  (== num_nodes for padding)
    w: jnp.ndarray    # float32 [n_padded]

    @property
    def n_padded(self) -> int:
        return int(self.src.shape[0])


def _pad_to(n: int, granule: int, pow2: bool = False) -> int:
    if pow2:
        # Power-of-two bucket padding bounds the number of distinct block
        # shapes (→ bounded jit trace count) at ≤2× memory overhead.
        m = granule
        while m < n:
            m *= 2
        return m
    if n == 0:
        return granule
    return ((n + granule - 1) // granule) * granule


def lane_bucket(num_lanes: int, data_extent: int = 1) -> int:
    """Lane-axis bucket: pow2 lanes-per-device times ``data_extent``.

    The batched executors pad their lane (snapshot/window) axis to this
    count so (a) jit trace keys depend only on ``(lane bucket, width
    bucket)`` — not the exact lane count of a level — and (b) the lane axis
    always divides a ``data`` mesh axis of ``data_extent`` devices, so a
    mesh launch shards instead of falling back to replicated execution.
    For pow2 device counts (the only shapes real meshes use) the bucket is
    itself pow2. Padding is < 2x ``num_lanes`` whenever the level has at
    least one lane per device; below that the bucket is exactly
    ``data_extent`` — the minimum divisible lane count.
    """
    if num_lanes < 1:
        raise ValueError(f"need at least one lane, got {num_lanes}")
    if data_extent < 1:
        raise ValueError(f"data_extent must be >= 1, got {data_extent}")
    per_device = -(-num_lanes // data_extent)
    b = 1
    while b < per_device:
        b *= 2
    return b * data_extent


def pad_edges(
    src: np.ndarray,
    dst: np.ndarray,
    w: np.ndarray | None,
    num_nodes: int,
    granule: int = DEFAULT_GRANULE,
    pad_pow2: bool = False,
) -> tuple[np.ndarray, np.ndarray, np.ndarray | None]:
    """Pad host edge arrays to the granule bucket (sentinel dst, PAD_SRC src).

    The single place the padding convention lives: every consumer that needs
    shape-bucketed edge arrays (block construction, the KickStarter deletion
    batches) routes through here so jit trace shapes stay bounded the same
    way everywhere.
    """
    n = src.shape[0]
    pad = _pad_to(n, granule, pow2=pad_pow2) - n
    if pad:
        src = np.concatenate([src, np.full(pad, PAD_SRC, np.int32)])
        dst = np.concatenate([dst, np.full(pad, num_nodes, np.int32)])
        if w is not None:
            w = np.concatenate([w, np.zeros(pad, np.float32)])
    return src, dst, w


def make_block(
    src: np.ndarray,
    dst: np.ndarray,
    w: np.ndarray | None,
    num_nodes: int,
    granule: int = DEFAULT_GRANULE,
    sort_by_dst: bool = True,
    pad_pow2: bool = False,
) -> EdgeBlock:
    """Build a padded (optionally dst-sorted) EdgeBlock from host arrays.

    dst-sorting gives segment reductions monotone segment ids, which is what
    the Pallas edge_relax kernel's blocked scatter relies on, and improves
    locality for XLA's segment lowering too.
    """
    src = np.asarray(src, dtype=np.int32)
    dst = np.asarray(dst, dtype=np.int32)
    if w is None:
        w = np.ones(src.shape[0], dtype=np.float32)
    w = np.asarray(w, dtype=np.float32)
    if not (src.shape == dst.shape == w.shape):
        raise ValueError(f"edge array shape mismatch: {src.shape}, {dst.shape}, {w.shape}")
    if sort_by_dst and src.shape[0] > 0:
        order = np.argsort(dst, kind="stable")
        src, dst, w = src[order], dst[order], w[order]
    src, dst, w = pad_edges(src, dst, w, num_nodes, granule=granule,
                            pad_pow2=pad_pow2)
    return EdgeBlock(jnp.asarray(src), jnp.asarray(dst), jnp.asarray(w))


@dataclasses.dataclass(frozen=True)
class EdgeView:
    """A logical graph = ordered tuple of shared immutable blocks."""

    blocks: tuple[EdgeBlock, ...]
    num_nodes: int

    @property
    def n_padded(self) -> int:
        return sum(b.n_padded for b in self.blocks)

    def arrays(self) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Concatenated (src, dst, w). Pure; safe inside jit traces."""
        if len(self.blocks) == 1:
            b = self.blocks[0]
            return b.src, b.dst, b.w
        src = jnp.concatenate([b.src for b in self.blocks])
        dst = jnp.concatenate([b.dst for b in self.blocks])
        w = jnp.concatenate([b.w for b in self.blocks])
        return src, dst, w

    def extended(self, *extra: EdgeBlock) -> "EdgeView":
        """A new view sharing this view's blocks plus ``extra`` (no copies)."""
        return EdgeView(self.blocks + tuple(extra), self.num_nodes)


def stack_delta_blocks(
    edge_lists: Sequence[tuple[np.ndarray, np.ndarray, np.ndarray | None]],
    num_nodes: int,
    granule: int = DEFAULT_GRANULE,
    pad_pow2: bool = True,
    sort_by_dst: bool = True,
    num_lanes: int | None = None,
) -> EdgeBlock:
    """Stack ragged per-lane edge lists into one EdgeBlock with a leading
    lane (snapshot) axis.

    Every lane is padded to ONE shared width — the granule bucket of the
    largest lane (power-of-two bucketed by default) — so the stacked shape,
    and therefore the jit trace of any executor consuming it, depends only on
    ``(num_lanes, bucket)`` and not on the exact ragged sizes. This is the
    shared stacking path of the batched executors (level-synchronous TG and
    Direct-Hop): sibling Δ-batches become lanes of a single launch.

    ``num_lanes`` (default: ``len(edge_lists)``) pads the LANE axis too:
    trailing masked lanes are all-sentinel (empty Δ — every edge is a
    padding edge), so they relax nothing, seed no frontier, and contribute
    zero ``edge_work``. The batched executors pass a ``lane_bucket`` here so
    the lane axis always divides the mesh's ``data`` extent; the matching
    validity mask is ``lane index < len(edge_lists)`` (see
    ``graph/engine.py`` ``lane_valid``).
    """
    if not edge_lists:
        raise ValueError("stack_delta_blocks needs at least one lane")
    if num_lanes is not None and num_lanes < len(edge_lists):
        raise ValueError(f"num_lanes={num_lanes} < {len(edge_lists)} lanes")
    width = _pad_to(max(np.asarray(s).shape[0] for s, _, _ in edge_lists),
                    granule, pow2=pad_pow2)
    # granule=width + pad_pow2=False pads each lane to exactly `width`.
    blocks = [make_block(s, d, w, num_nodes, granule=width,
                         sort_by_dst=sort_by_dst, pad_pow2=False)
              for s, d, w in edge_lists]
    if num_lanes is not None and num_lanes > len(blocks):
        empty = np.empty(0, np.int32)
        masked = make_block(empty, empty, None, num_nodes, granule=width,
                            sort_by_dst=sort_by_dst, pad_pow2=False)
        blocks.extend([masked] * (num_lanes - len(blocks)))
    return EdgeBlock(jnp.stack([b.src for b in blocks]),
                     jnp.stack([b.dst for b in blocks]),
                     jnp.stack([b.w for b in blocks]))


def concat_views(a: EdgeView, b: EdgeView) -> EdgeView:
    if a.num_nodes != b.num_nodes:
        raise ValueError("views over different node sets")
    return EdgeView(a.blocks + b.blocks, a.num_nodes)


# ---------------------------------------------------------------------------
# Host-side edge-set algebra (int64 keys). Used by core/ to compute the
# CommonGraph intersection and Δ-batches; never inside a jit trace.
# ---------------------------------------------------------------------------

def edge_keys(src: np.ndarray, dst: np.ndarray, num_nodes: int) -> np.ndarray:
    """Injective int64 key for (src, dst) pairs."""
    return src.astype(np.int64) * np.int64(num_nodes) + dst.astype(np.int64)


def keys_to_edges(keys: np.ndarray, num_nodes: int) -> tuple[np.ndarray, np.ndarray]:
    src = (keys // num_nodes).astype(np.int32)
    dst = (keys % num_nodes).astype(np.int32)
    return src, dst


def merge_changes(keys: np.ndarray, add_keys: np.ndarray,
                  del_keys: np.ndarray) -> np.ndarray:
    """Apply one change batch to a sorted key set: ``(keys ∖ del) ∪ add``.

    The one transition rule shared by the offline generator
    (``make_evolving_sequence``) and the live ingestion cut
    (``core/ingest.py``) — sharing it is what makes a replayed event trace
    bit-identical to its precomputed counterpart. All three inputs must be
    sorted unique key arrays with ``del_keys ⊆ keys`` and
    ``add_keys ∩ keys = ∅`` already enforced by the caller.
    """
    out = np.setdiff1d(keys, del_keys, assume_unique=True)
    return np.union1d(out, add_keys)

"""Monotone path semirings for the five paper algorithms.

Every algorithm in the paper (BFS, SSSP, SSWP, SSNP, Viterbi) is a fixpoint of

    val[v]  =  reduce_{(u,v,w) in E}  combine(val[u], w)      (+ source anchor)

where ``reduce`` is ``min`` or ``max`` and ``combine`` is monotone w.r.t. the
reduce order. Monotonicity is the property KickStarter exploits for cheap
*addition* increments (the state can only improve; re-sweeping from the
current state converges to the exact new fixpoint) and what makes deletions
expensive (state may be stale-optimistic and must be trimmed). CommonGraph
removes the deletion path entirely.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class Semiring:
    """A monotone path semiring.

    Attributes:
      name: short identifier (matches the paper's algorithm names).
      reduce: "min" or "max" — the vertex-side reduction order.
      identity: the "unreached" value (absorbing for reduce).
      source_value: value anchored at the source vertex.
      combine: (val_u, w) -> candidate value along edge (u, v, w).
      needs_weights: False for BFS (weights ignored).
    """

    name: str
    reduce: str
    identity: float
    source_value: float
    combine: Callable[[Array, Array], Array]
    needs_weights: bool = True

    @property
    def is_min(self) -> bool:
        return self.reduce == "min"

    def better(self, a: Array, b: Array) -> Array:
        """Elementwise meet: the better of two values under the reduce order."""
        return jnp.minimum(a, b) if self.is_min else jnp.maximum(a, b)

    def strictly_better(self, a: Array, b: Array) -> Array:
        """True where ``a`` is strictly better than ``b``."""
        return (a < b) if self.is_min else (a > b)


_INF = float(jnp.inf)

BFS = Semiring(
    name="bfs",
    reduce="min",
    identity=_INF,
    source_value=0.0,
    combine=lambda val_u, w: val_u + 1.0,
    needs_weights=False,
)

SSSP = Semiring(
    name="sssp",
    reduce="min",
    identity=_INF,
    source_value=0.0,
    combine=lambda val_u, w: val_u + w,
)

# Single-source widest path: maximize, over paths, the minimum edge weight.
SSWP = Semiring(
    name="sswp",
    reduce="max",
    identity=-_INF,
    source_value=_INF,
    combine=lambda val_u, w: jnp.minimum(val_u, w),
)

# Single-source narrowest path: minimize, over paths, the maximum edge weight.
SSNP = Semiring(
    name="ssnp",
    reduce="min",
    identity=_INF,
    source_value=-_INF,
    combine=lambda val_u, w: jnp.maximum(val_u, w),
)

# Viterbi: maximize the product of edge probabilities in (0, 1].
VITERBI = Semiring(
    name="viterbi",
    reduce="max",
    identity=0.0,
    source_value=1.0,
    combine=lambda val_u, w: val_u * w,
)

ALL_SEMIRINGS: dict[str, Semiring] = {
    s.name: s for s in (BFS, SSSP, SSWP, SSNP, VITERBI)
}

"""Stable-vertex analysis: seed incremental sweeps from the instability set.

The follow-up paper to CommonGraph ("Analysis of Stable Vertex Values:
Fast Query Evaluation Over An Evolving Graph", PAPERS.md) observes that
most converged vertex values are *stable* across a window: no Δ edge can
improve them, so an incremental sweep that starts from the full Δ edge
endpoint set wastes its seed relaxation on edges that provably cannot
destabilize anything. This module is the one place that analysis lives —
every executor's frontier seeding routes through :func:`seed_state`
(graphlint rule G008 forbids raw ``relax_sweep`` seeding elsewhere).

The instability test is the semiring's own monotone-improvement predicate:
a Δ edge ``(u, v, w)`` destabilizes ``v`` iff ``combine(values[u], w)``
strictly beats ``values[v]``. Two facts make the pruned seed exact for
every registered semiring (tests/test_stability.py property-checks all
five):

* **Unreached sources are inert.** ``combine(identity, w) == identity``
  for all five semirings (∞+w=∞ for BFS/SSSP, min(-∞,w)=-∞ for SSWP,
  max(∞,w)=∞ for SSNP, 0·w=0 for Viterbi), and ``identity`` never
  strictly beats any value. Masking the seed sweep's frontier to *reached*
  sources (:func:`seed_mask`) therefore changes no candidate the segment
  reduction can win with — values, parents and the improved set are
  bit-identical to full-Δ seeding; only the frontier-masked ``edge_work``
  drops (strictly, whenever some Δ edge leaves an unreached vertex).
* **Propagation self-prunes.** The seed sweep's ``improved`` output *is*
  the instability region's boundary: the subsequent frontier-masked
  fixpoint only ever expands through vertices that strictly improved, so
  the dependence-region walk stops exactly where no improvement is
  possible. Stable vertices are never visited again.

Both seeding modes converge to the same unique monotone rounded fixpoint;
``mode="delta"`` (the faithful full-Δ baseline every prior PR shipped) is
kept for baselines and property tests, and is what the KickStarter
comparison baseline uses so its measured cost stays that of the published
algorithm.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.graph.semiring import Semiring

SEED_MODES = ("instability", "delta")


class SeededState(NamedTuple):
    """The stability analysis' verdict on one Δ batch against one state.

    ``values``/``parent`` are the anchor state with the Δ edges' direct
    improvements applied; ``frontier`` is the instability seed set (the
    vertices a Δ edge strictly improved — identical under both seeding
    modes); ``seed_work`` is the frontier-masked edge work the seed sweep
    spent; ``unstable`` is ``sum(frontier)`` as an int32 scalar (per-lane
    under vmap), the numerator of :func:`stable_fraction_milli`.
    """

    values: jnp.ndarray    # float32 [num_nodes]
    parent: jnp.ndarray    # int32  [num_nodes]
    frontier: jnp.ndarray  # bool   [num_nodes] — the instability seed set
    seed_work: jnp.ndarray  # float32 scalar
    unstable: jnp.ndarray  # int32 scalar — |frontier|


def seed_mask(semiring: Semiring, values: jnp.ndarray) -> jnp.ndarray:
    """Reached-vertex mask: the sources whose Δ edges can destabilize.

    A vertex still at ``semiring.identity`` is unreached; every candidate
    its out-edges produce is ``combine(identity, w) == identity``, which
    never strictly beats an incumbent value under a monotone semiring. The
    instability analysis therefore masks the seed sweep to this set — the
    Δ edges it drops are exactly the ones the monotone-improvement test
    ``combine(values[u], w) beats values[v]`` already rejects.
    """
    return values != jnp.float32(semiring.identity)


def seed_state(
    semiring: Semiring,
    num_nodes: int,
    values: jnp.ndarray,
    parent: jnp.ndarray,
    seed_blocks,
    *,
    mode: str = "instability",
    track_parents: bool = True,
) -> SeededState:
    """Seed an incremental launch from the stable-vertex analysis.

    Relaxes ``seed_blocks`` (the Δ edges) against the anchor state once,
    with the seed frontier chosen by ``mode``: ``"instability"`` masks to
    :func:`seed_mask` (reached sources only — the pruned dependence-region
    boundary), ``"delta"`` uses the all-on frontier (full-Δ baseline).
    Returns a :class:`SeededState` whose ``frontier`` seeds the fixpoint;
    both modes yield bit-identical values/parents/frontier (unique
    monotone fixpoint; see the module docstring), differing only in
    ``seed_work``. Safe under jit/vmap — ``mode`` must be static.
    """
    if mode not in SEED_MODES:
        raise ValueError(
            f"unknown seed mode {mode!r}: expected one of {SEED_MODES}")
    from repro.graph.engine import relax_sweep
    if mode == "instability":
        frontier = seed_mask(semiring, values)
    else:
        frontier = jnp.ones((num_nodes,), bool)
    new_values, new_parent, improved, seed_work = relax_sweep(
        semiring, num_nodes, values, parent, frontier, tuple(seed_blocks),
        track_parents=track_parents)
    return SeededState(new_values, new_parent, improved, seed_work,
                       jnp.sum(improved, dtype=jnp.int32))


def stable_fraction_milli(unstable, num_nodes: int, lane_valid=None) -> int:
    """Aggregate per-lane instability counts into a stable fraction (‰).

    ``unstable`` is one int count per lane (a scalar, an array, or any
    sequence of them — e.g. the ``FixpointResult.unstable`` of several
    launches concatenated); ``lane_valid`` masks out padding lanes so the
    pow2 lane buckets never dilute the measurement. Returns
    ``round(1000 * stable_vertex_lanes / total_vertex_lanes)`` as an int —
    a machine-independent integer, which is what lets the benches gate it
    as a schema-v2 exact field. Returns 0 when no valid lanes exist.
    """
    counts = np.asarray(unstable, dtype=np.int64).reshape(-1)
    if lane_valid is not None:
        counts = counts[np.asarray(lane_valid, dtype=bool).reshape(-1)]
    total = int(counts.size) * int(num_nodes)
    if total == 0:
        return 0
    return round(1000 * (total - int(counts.sum())) / total)

"""MeshGraphNet [arXiv:2010.03409]: 15 MP blocks d=128, sum agg, 2-layer MLPs."""
from repro.models.gnn import GNNConfig

CONFIG = GNNConfig(
    name="meshgraphnet", arch="meshgraphnet", n_layers=15, d_hidden=128,
    d_in=0, d_out=3, task="node_reg", aggregator="sum", mlp_layers=2,
)
FAMILY = "gnn"

"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B]: 48L d=2048 32H(kv=4) MoE 128e top-8 d_ff=768."""
import jax.numpy as jnp
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="qwen3-moe-30b-a3b",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_head=128,
    d_ff=768, moe_d_ff=768, vocab=151_936,
    moe_every=1, n_experts=128, top_k=8,
    activation="swiglu", param_dtype=jnp.bfloat16,
)
FAMILY = "lm"

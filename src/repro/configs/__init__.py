"""Architecture registry: ``--arch <id>`` resolution for launchers/tests.

10 assigned architectures + the paper's own engine (``commongraph``).
"""

from __future__ import annotations

import importlib

from repro.configs.base import Cell

ARCH_IDS = [
    "qwen3-moe-30b-a3b",
    "llama4-maverick-400b-a17b",
    "llama3.2-3b",
    "nemotron-4-340b",
    "stablelm-1.6b",
    "pna",
    "graphcast",
    "gcn-cora",
    "meshgraphnet",
    "dien",
]

_MODULES = {
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "llama3.2-3b": "llama3_2_3b",
    "nemotron-4-340b": "nemotron_4_340b",
    "stablelm-1.6b": "stablelm_1_6b",
    "pna": "pna",
    "graphcast": "graphcast",
    "gcn-cora": "gcn_cora",
    "meshgraphnet": "meshgraphnet",
    "dien": "dien",
}


def get_arch(arch_id: str):
    """Returns (config, family) for an architecture id."""
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG, mod.FAMILY


def shapes_for(arch_id: str) -> list[str]:
    _, family = get_arch(arch_id)
    if family == "lm":
        from repro.configs.lm_family import LM_SHAPES
        return list(LM_SHAPES)
    if family == "gnn":
        from repro.configs.gnn_family import GNN_SHAPES
        return list(GNN_SHAPES)
    if family == "recsys":
        from repro.configs.recsys_family import RECSYS_SHAPES
        return list(RECSYS_SHAPES)
    raise ValueError(family)


def make_cell(arch_id: str, shape_id: str, mesh) -> Cell:
    cfg, family = get_arch(arch_id)
    if family == "lm":
        from repro.configs.lm_family import make_lm_cell
        return make_lm_cell(cfg, shape_id, mesh)
    if family == "gnn":
        from repro.configs.gnn_family import make_gnn_cell
        return make_gnn_cell(cfg, shape_id, mesh)
    if family == "recsys":
        from repro.configs.recsys_family import make_recsys_cell
        return make_recsys_cell(cfg, shape_id, mesh)
    raise ValueError(family)


def reduced_config(arch_id: str):
    cfg, family = get_arch(arch_id)
    if family == "lm":
        from repro.configs.lm_family import reduced_lm_config
        return reduced_lm_config(cfg), family
    if family == "gnn":
        from repro.configs.gnn_family import reduced_gnn_config
        return reduced_gnn_config(cfg), family
    if family == "recsys":
        from repro.configs.recsys_family import reduced_recsys_config
        return reduced_recsys_config(cfg), family
    raise ValueError(family)


def all_cells(mesh) -> list[tuple[str, str]]:
    return [(a, s) for a in ARCH_IDS for s in shapes_for(a)]

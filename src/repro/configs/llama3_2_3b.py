"""Llama-3.2-3B [hf:meta-llama/Llama-3.2-3B]: 28L d=3072 24H(kv=8) d_ff=8192."""
import jax.numpy as jnp
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="llama3.2-3b",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab=128_256,
    activation="swiglu", param_dtype=jnp.bfloat16,
    attn_chunk=1024,  # head_dim-TP: scores replicate over model; chunking is load-bearing
)
FAMILY = "lm"

"""StableLM-2-1.6B [hf:stabilityai/stablelm-2-1_6b]: 24L d=2048 32H(kv=32) d_ff=5632."""
import jax.numpy as jnp
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="stablelm-1.6b",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
    d_ff=5632, vocab=100_352,
    activation="swiglu", param_dtype=jnp.bfloat16,
)
FAMILY = "lm"

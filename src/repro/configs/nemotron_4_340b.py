"""Nemotron-4-340B [arXiv:2402.16819]: 96L d=18432 96H(kv=8) d_ff=73728,
squared-ReLU FFN, vocab 256000."""
import jax.numpy as jnp
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="nemotron-4-340b",
    n_layers=96, d_model=18_432, n_heads=96, n_kv_heads=8, d_head=192,
    d_ff=73_728, vocab=256_000,
    activation="squared_relu", param_dtype=jnp.bfloat16,
)
FAMILY = "lm"

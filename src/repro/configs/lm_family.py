"""LM-family cells: train_4k / prefill_32k / decode_32k / long_500k.

Sharding plan (DESIGN.md §5):
* params — FSDP over `data` × tensor-parallel over `model` (Megatron
  row/col splits); experts over `model` (EP); embeddings vocab over `model`.
* train activations — batch over (pod, data); the residual carry is
  re-annotated with sequence over `model` (Megatron-SP) so the L× saved
  activations of the remat'd scan stay sharded.
* decode — KV cache: batch over (pod, data), sequence over `model`
  (flash-style partial-softmax combine is one all-reduce). long_500k
  (batch=1) relies on the sequence shards entirely; O(S) per step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import Cell, MeshAxes, make_constrainer
from repro.models.transformer import (
    LMConfig,
    init_kv_cache,
    init_lm_params,
    lm_decode_step,
    lm_loss,
    lm_prefill,
)
from repro.optim import adamw_init, adamw_update
from repro.optim.adamw import AdamWState

LM_SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


# -- param specs --------------------------------------------------------------

def lm_param_specs(cfg: LMConfig, ax: MeshAxes, tp_size: int = 16):
    f, m = ax.fsdp, ax.model
    if cfg.n_heads % tp_size == 0:
        # Megatron head-parallel attention
        attn = {
            "wq": P(None, f, m, None),
            "wk": P(None, f, None, None),
            "wv": P(None, f, None, None),
            "wo": P(None, m, None, f),
        }
    else:
        # head_dim-parallel fallback (llama4 40H, llama3.2 24H): contractions
        # over Dh produce partial sums + one all-reduce; interleaved RoPE
        # keeps the rotation shard-local.
        attn = {
            "wq": P(None, f, None, m),
            "wk": P(None, f, None, m),
            "wv": P(None, f, None, m),
            "wo": P(None, None, m, f),
        }
    attn.update({
        "ln1": P(None, None),
        "ln2": P(None, None),
    })
    specs = {
        "embed": P(m, f),
        "attn": attn,
        "final_ln": P(None),
        "lm_head": P(f, m),
    }
    kinds = cfg.layer_kinds()
    if any(k == "dense" for k in kinds):
        ffn = {"w_up": P(None, f, m), "w_down": P(None, m, f)}
        if cfg.activation == "swiglu":
            ffn["w_gate"] = P(None, f, m)
        specs["ffn"] = ffn
    if any(k == "moe" for k in kinds):
        if cfg.expert_zero1:
            # §Perf hillclimb B iter-2: expert weights over model only (no
            # per-layer FSDP gathers); optimizer state keeps the data dim
            # sharded (see lm_opt_specs) = ZeRO-1, one gather per step.
            moe = {
                "router": P(None, f, None),
                "w_gate": P(None, m, None, None),
                "w_up": P(None, m, None, None),
                "w_down": P(None, m, None, None),
            }
        else:
            moe = {
                "router": P(None, f, None),
                "w_gate": P(None, m, f, None),
                "w_up": P(None, m, f, None),
                "w_down": P(None, m, None, f),
            }
        if cfg.n_shared_experts:
            moe["shared"] = {"w_gate": P(None, f, m), "w_up": P(None, f, m),
                             "w_down": P(None, m, f)}
        specs["moe"] = moe
    return specs


def lm_opt_specs(param_specs, cfg: LMConfig | None = None, ax: MeshAxes | None = None):
    state_specs = param_specs
    if cfg is not None and cfg.expert_zero1 and "moe" in param_specs:
        # fp32 m/v for experts re-shard the D dim over data (ZeRO-1)
        state_specs = dict(param_specs)
        moe = dict(param_specs["moe"])
        for k in ("w_gate", "w_up"):
            moe[k] = P(None, ax.model, ax.fsdp, None)
        moe["w_down"] = P(None, ax.model, None, ax.fsdp)
        state_specs["moe"] = moe
    return AdamWState(m=state_specs, v=state_specs, count=P())


def abstract_lm_state(cfg: LMConfig, with_opt: bool):
    params = jax.eval_shape(lambda: init_lm_params(jax.random.PRNGKey(0), cfg))
    if not with_opt:
        return params, None
    opt = jax.eval_shape(lambda: adamw_init(params))
    return params, opt


# -- cells --------------------------------------------------------------------

def make_lm_cell(cfg: LMConfig, shape_id: str, mesh) -> Cell:
    ax = MeshAxes.for_mesh(mesh)
    sh = LM_SHAPES[shape_id]
    b, s = sh["batch"], sh["seq"]
    pspecs = lm_param_specs(cfg, ax, tp_size=mesh.shape[ax.model])
    bd = ax.batch
    n_groups = ax.n_batch_shards(mesh)
    # Residual carry stays sequence-sharded (Megatron-SP posture). A
    # "block_in" re-gather constraint was tried and REFUTED (§Perf hillclimb
    # B iter-3: it duplicates activations through remat, +8% collective,
    # +48% memory) — the partitioner's own placement wins; hook left in place.
    # "weights"/"logits" constraints are §Perf hillclimb C (nemotron).
    def _degather(spec: P) -> P:
        dims = list(spec)[1:]  # drop the stacked-layer dim
        return P(*[None if d == ax.fsdp else d for d in dims])

    _wspecs = {}
    for grp in ("attn", "ffn"):
        for k2, spec in pspecs.get(grp, {}).items():
            _wspecs[k2] = _degather(spec)
    _wcons = {k2: make_constrainer(mesh, s) for k2, s in _wspecs.items()}

    def weights_con(lp: dict):
        return {k2: (_wcons[k2](v) if k2 in _wcons else v)
                for k2, v in lp.items()}

    constrain = {
        "residual": make_constrainer(mesh, P(bd, ax.model, None)),
        "weights": weights_con,
        "logits": make_constrainer(mesh, P(bd, None, ax.model)),
    }

    if sh["kind"] == "train":
        params, opt = abstract_lm_state(cfg, with_opt=True)
        batch = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
        ospecs = lm_opt_specs(pspecs, cfg, ax)

        def train_step(params, opt_state, batch):
            def loss_fn(p):
                return lm_loss(cfg, p, batch["tokens"], batch["labels"],
                               n_groups=n_groups, constrain=constrain)
            loss, grads = jax.value_and_grad(loss_fn)(params)
            # (bf16 grad-sync was tried and REFUTED here — §Perf hillclimb C
            # iter-2: the fp32 dW reductions happen inside the backward
            # contraction, before any post-hoc cast can narrow them.)
            new_p, new_o, gnorm = adamw_update(grads, opt_state, params)
            return new_p, new_o, {"loss": loss, "grad_norm": gnorm}

        return Cell(
            name=f"{cfg.name}/{shape_id}",
            fn=train_step,
            args=(params, opt, batch),
            in_specs=(pspecs, ospecs, {"tokens": P(bd, None), "labels": P(bd, None)}),
            out_specs=(pspecs, ospecs, {"loss": P(), "grad_norm": P()}),
            donate=(0, 1),
        )

    if sh["kind"] == "prefill":
        if cfg.attn_chunk == 0:
            # 32k prefill cannot materialize [S, S] scores (17 GB/device):
            # online-softmax chunking is load-bearing here, not an optimization.
            import dataclasses as _dc
            cfg = _dc.replace(cfg, attn_chunk=2048)
        params, _ = abstract_lm_state(cfg, with_opt=False)
        tokens = jax.ShapeDtypeStruct((b, s), jnp.int32)
        cache_spec = {"k": P(None, bd, ax.model, None, None),
                      "v": P(None, bd, ax.model, None, None)}

        def prefill_step(params, tokens):
            return lm_prefill(cfg, params, tokens, n_groups=n_groups,
                              constrain=constrain)

        return Cell(
            name=f"{cfg.name}/{shape_id}",
            fn=prefill_step,
            args=(params, tokens),
            in_specs=(pspecs, P(bd, None)),
            out_specs=(P(bd, ax.model), cache_spec),
        )

    # decode
    params, _ = abstract_lm_state(cfg, with_opt=False)
    cache = jax.eval_shape(lambda: init_kv_cache(cfg, b, s))
    batch_sharded = b % ax.n_batch_shards(mesh) == 0
    if batch_sharded:
        cbatch, cseq = bd, ax.model
    else:  # long_500k: batch=1 — spend both axes on the sequence dim
        cbatch, cseq = None, (ax.fsdp, ax.model)
    cache_spec = {"k": P(None, cbatch, cseq, None, None),
                  "v": P(None, cbatch, cseq, None, None)}
    tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def decode_step(params, cache, tokens, pos):
        return lm_decode_step(cfg, params, cache, tokens, pos)

    return Cell(
        name=f"{cfg.name}/{shape_id}",
        fn=decode_step,
        args=(params, cache, tokens, pos),
        in_specs=(pspecs, cache_spec, P(cbatch, None), P()),
        out_specs=(P(cbatch, ax.model), cache_spec),
        donate=(1,),
    )


def reduced_lm_config(cfg: LMConfig) -> LMConfig:
    """Same family, smoke-testable on one CPU core."""
    import dataclasses as dc
    return dc.replace(
        cfg,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2),
        d_head=16,
        d_ff=128,
        moe_d_ff=64 if cfg.is_moe else 0,
        n_experts=4 if cfg.is_moe else 0,
        top_k=min(cfg.top_k, 2) if cfg.is_moe else 0,
        vocab=256,
        param_dtype=jnp.float32,
        # drop-free routing so decode == forward exactly in equivalence tests
        capacity_factor=8.0,
    )

"""DIEN [arXiv:1809.03672]: embed 18, seq 100, GRU 108, MLP 200-80, AUGRU."""
from repro.models.dien import DIENConfig

CONFIG = DIENConfig(
    name="dien", n_items=1 << 23, n_cats=10_000, embed_dim=18,
    seq_len=100, gru_dim=108, mlp_dims=(200, 80),
)
FAMILY = "recsys"

"""Recsys (DIEN) cells: train_batch / serve_p99 / serve_bulk / retrieval_cand.

Sharding plan: embedding tables row-shard over `model` (the classic recsys
table sharding — lookups become cross-shard gathers); request batch over
(pod, data); the 10⁶-candidate retrieval axis shards over (data, model) with
the user's GRU states computed once and broadcast (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import Cell, MeshAxes
from repro.models.dien import (
    DIENConfig,
    dien_forward,
    dien_loss,
    dien_score_candidates,
    init_dien_params,
)
from repro.optim import adamw_init, adamw_update
from repro.optim.adamw import AdamWState

RECSYS_SHAPES = {
    "train_batch": dict(kind="train", batch=65_536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262_144),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_candidates=1_000_000),
}


def dien_param_specs(cfg: DIENConfig, params, ax: MeshAxes):
    specs = jax.tree.map(lambda a: P(*((None,) * a.ndim)), params)
    specs["item_emb"] = P(ax.model, None)     # 2²³ rows — row-sharded
    specs["cat_emb"] = P(None, None)          # 10⁴ rows — replicated
    return specs


def _batch_specs(ax: MeshAxes):
    bd = ax.batch
    return {
        "hist_items": P(bd, None), "hist_cats": P(bd, None),
        "hist_mask": P(bd, None),
        "target_item": P(bd), "target_cat": P(bd),
        "label": P(bd),
    }


def _abstract_batch(cfg: DIENConfig, b: int, with_label=True):
    S = jax.ShapeDtypeStruct
    i32 = jnp.int32
    d = {
        "hist_items": S((b, cfg.seq_len), i32),
        "hist_cats": S((b, cfg.seq_len), i32),
        "hist_mask": S((b, cfg.seq_len), jnp.bool_),
        "target_item": S((b,), i32),
        "target_cat": S((b,), i32),
    }
    if with_label:
        d["label"] = S((b,), i32)
    return d


def make_recsys_cell(cfg: DIENConfig, shape_id: str, mesh) -> Cell:
    ax = MeshAxes.for_mesh(mesh)
    sh = RECSYS_SHAPES[shape_id]
    params = jax.eval_shape(lambda: init_dien_params(jax.random.PRNGKey(0), cfg))
    pspecs = dien_param_specs(cfg, params, ax)
    name = f"{cfg.name}/{shape_id}"

    if sh["kind"] == "train":
        opt = jax.eval_shape(lambda: adamw_init(params))
        ospecs = AdamWState(m=pspecs, v=pspecs, count=P())
        batch = _abstract_batch(cfg, sh["batch"])

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(lambda p: dien_loss(cfg, p, batch))(params)
            new_p, new_o, gnorm = adamw_update(grads, opt_state, params, lr=1e-3,
                                               weight_decay=0.0)
            return new_p, new_o, {"loss": loss, "grad_norm": gnorm}

        return Cell(name, train_step, (params, opt, batch),
                    in_specs=(pspecs, ospecs, _batch_specs(ax)),
                    out_specs=(pspecs, ospecs, {"loss": P(), "grad_norm": P()}),
                    donate=(0, 1))

    if sh["kind"] == "serve":
        batch = _abstract_batch(cfg, sh["batch"], with_label=False)
        bspecs = {k: v for k, v in _batch_specs(ax).items() if k != "label"}

        def serve_step(params, batch):
            logits, *_ = dien_forward(cfg, params, batch)
            return logits

        return Cell(name, serve_step, (params, batch),
                    in_specs=(pspecs, bspecs), out_specs=P(ax.batch, None))

    # retrieval: 1 user × n_candidates (padded to the 512-way sharding;
    # pad-candidate scores are discarded by the caller)
    c = ((sh["n_candidates"] + 511) // 512) * 512
    S = jax.ShapeDtypeStruct
    batch = _abstract_batch(cfg, 1, with_label=False)
    batch["cand_items"] = S((c,), jnp.int32)
    batch["cand_cats"] = S((c,), jnp.int32)
    bspecs = {k: P(None, None) if v.ndim == 2 else P(None)
              for k, v in batch.items() if k.startswith("hist") or k.startswith("target")}
    bspecs["cand_items"] = P((ax.fsdp, ax.model))
    bspecs["cand_cats"] = P((ax.fsdp, ax.model))

    def retrieval_step(params, batch):
        return dien_score_candidates(cfg, params, batch)

    return Cell(name, retrieval_step, (params, batch),
                in_specs=(pspecs, bspecs), out_specs=P((ax.fsdp, ax.model)))


def reduced_recsys_config(cfg: DIENConfig) -> DIENConfig:
    return dataclasses.replace(cfg, n_items=1_000, n_cats=50, seq_len=10)

"""GNN-family cells: full_graph_sm / minibatch_lg / ogb_products / molecule.

Sharding plan (DESIGN.md §5): edge arrays shard over every mesh axis
(message compute is embarrassingly edge-parallel); node arrays shard over
(data, model); tiny MLP params replicate; the minibatch feature table
row-shards like an embedding. The segment-sum scatter across node shards is
the collective the roofline sees (the same pattern as the paper engine's
semiring reduce).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import Cell, MeshAxes, make_constrainer
from repro.graph.sampler import subgraph_shapes
from repro.models.gnn import (
    GNNConfig,
    gnn_loss,
    init_gnn_params,
    latent_constrainer,
)
from repro.optim import adamw_init, adamw_update
from repro.optim.adamw import AdamWState

GNN_SHAPES = {
    "full_graph_sm": dict(kind="full", n_nodes=2_708, n_edges=10_556, d_feat=1_433),
    "minibatch_lg": dict(kind="minibatch", n_nodes=232_965, n_edges=114_615_892,
                         batch_nodes=1_024, fanout=(15, 10), d_feat=602),
    "ogb_products": dict(kind="full", n_nodes=2_449_029, n_edges=61_859_140,
                         d_feat=100),
    "molecule": dict(kind="molecule", n_nodes=30, n_edges=64, batch=128, d_feat=32),
}

# jit-boundary shardings need even divisibility: node arrays pad to 1024
# (so the derived graphcast mesh n/4 still divides the 256-way node sharding)
# and edge arrays to 512 (the multi-pod edge sharding degree). Padded edges
# carry the sentinel dst == n (the substrate's standard convention); padded
# labels are -1 (masked by the CE loss).
NODE_PAD, EDGE_PAD = 1024, 512


def _pad(n: int, g: int) -> int:
    return ((n + g - 1) // g) * g


def _arch_shape_cfg(cfg: GNNConfig, shape_id: str) -> GNNConfig:
    """Bind the generic shape's feature dims into the arch config."""
    sh = GNN_SHAPES[shape_id]
    d_in = cfg.n_vars if cfg.arch == "graphcast" else sh["d_feat"]
    d_out = {"full_graph_sm": 7, "minibatch_lg": 41, "ogb_products": 47,
             "molecule": 1}[shape_id]
    task = cfg.task
    if cfg.arch == "graphcast":
        d_out, task = cfg.n_vars, "node_reg"
    elif shape_id == "molecule":
        task = "graph_reg"
    feature_table = (_pad(sh["n_nodes"], NODE_PAD)
                     if sh["kind"] == "minibatch" else 0)
    return dataclasses.replace(cfg, d_in=d_in, d_out=d_out, task=task,
                               feature_table=feature_table)


def _graph_input_specs(cfg: GNNConfig, shape_id: str, ax: MeshAxes):
    """(abstract batch, batch PartitionSpecs) for one shape cell."""
    sh = GNN_SHAPES[shape_id]
    all_axes = ax.batch + (ax.model,)
    nodeP = P((ax.fsdp, ax.model))
    edgeP = P(all_axes)
    f32, i32 = jnp.float32, jnp.int32
    S = jax.ShapeDtypeStruct

    if sh["kind"] == "minibatch":
        n_local, n_edges = subgraph_shapes(sh["batch_nodes"], sh["fanout"])
        n_local, n_edges = _pad(n_local, NODE_PAD), _pad(n_edges, EDGE_PAD)
        batch = {
            "nodes": S((n_local,), i32),
            "node_valid": S((n_local,), jnp.bool_),
            "src": S((n_edges,), i32),
            "dst": S((n_edges,), i32),
            "edge_feat": S((n_edges, cfg.d_edge), f32),
            "n_seeds": S((), i32),
        }
        specs = {
            "nodes": nodeP, "node_valid": nodeP,
            "src": edgeP, "dst": edgeP, "edge_feat": P(all_axes, None),
            "n_seeds": P(),
        }
        if cfg.task == "node_class":
            batch["labels"] = S((sh["batch_nodes"],), i32)
            specs["labels"] = P((ax.fsdp,))
        else:
            batch["targets"] = S((sh["batch_nodes"], cfg.d_out), f32)
            specs["targets"] = P((ax.fsdp,), None)
        n_nodes_model = n_local
    elif sh["kind"] == "molecule":
        n = _pad(sh["batch"] * sh["n_nodes"], NODE_PAD)
        e = _pad(sh["batch"] * sh["n_edges"], EDGE_PAD)
        batch = {
            "x": S((n, cfg.d_in), f32),
            "src": S((e,), i32), "dst": S((e,), i32),
            "edge_feat": S((e, cfg.d_edge), f32),
            "graph_id": S((n,), i32),
            "graph_targets": S((sh["batch"], cfg.d_out), f32),
        }
        specs = {
            "x": P((ax.fsdp, ax.model), None),
            "src": edgeP, "dst": edgeP, "edge_feat": P(all_axes, None),
            "graph_id": nodeP,
            "graph_targets": P((ax.fsdp,), None),
        }
        n_nodes_model = n
    else:  # full graph
        n, e = _pad(sh["n_nodes"], NODE_PAD), _pad(sh["n_edges"], EDGE_PAD)
        batch = {
            "x": S((n, cfg.d_in), f32),
            "src": S((e,), i32), "dst": S((e,), i32),
            "edge_feat": S((e, cfg.d_edge), f32),
        }
        specs = {
            "x": P((ax.fsdp, ax.model), None),
            "src": edgeP, "dst": edgeP, "edge_feat": P(all_axes, None),
        }
        if cfg.task == "node_class":
            batch["labels"] = S((n,), i32)
            specs["labels"] = nodeP
        else:
            batch["targets"] = S((n, cfg.d_out), f32)
            specs["targets"] = P((ax.fsdp, ax.model), None)
        n_nodes_model = n

    if cfg.arch == "graphcast":
        # derived mesh graph (DESIGN.md §4): grid=the shape's graph
        m = max(n_nodes_model // 4, 42)
        em = 4 * m
        e_g2m = batch["src"].shape[0]
        batch.update({
            "mesh_valid": S((m,), jnp.bool_),
            "g2m_src": batch.pop("src"), "g2m_dst": batch.pop("dst"),
            "g2m_feat": batch.pop("edge_feat"),
            "mesh_src": S((em,), i32), "mesh_dst": S((em,), i32),
            "mesh_feat": S((em, cfg.d_edge), f32),
            "m2g_src": S((e_g2m,), i32), "m2g_dst": S((e_g2m,), i32),
            "m2g_feat": S((e_g2m, cfg.d_edge), f32),
        })
        specs.update({
            "mesh_valid": nodeP,
            "g2m_src": specs.pop("src"), "g2m_dst": specs.pop("dst"),
            "g2m_feat": specs.pop("edge_feat"),
            "mesh_src": edgeP, "mesh_dst": edgeP, "mesh_feat": P(all_axes, None),
            "m2g_src": edgeP, "m2g_dst": edgeP, "m2g_feat": P(all_axes, None),
        })
        # graphcast regresses grid vars; retarget shape-specific labels
        for k in ("labels", "targets"):
            batch.pop(k, None); specs.pop(k, None)
        batch["targets"] = S((n_nodes_model, cfg.n_vars), f32)
        specs["targets"] = P((ax.fsdp, ax.model), None)
    return batch, specs


def gnn_param_specs(cfg: GNNConfig, params, ax: MeshAxes):
    """Replicate MLP params; row-shard the feature table if present."""
    specs = jax.tree.map(lambda a: P(*((None,) * a.ndim)), params)
    if cfg.feature_table:
        specs["features"] = P((ax.fsdp, ax.model), None)
    return specs


def make_gnn_cell(cfg: GNNConfig, shape_id: str, mesh) -> Cell:
    ax = MeshAxes.for_mesh(mesh)
    cfg = _arch_shape_cfg(cfg, shape_id)
    batch, bspecs = _graph_input_specs(cfg, shape_id, ax)
    params = jax.eval_shape(lambda: init_gnn_params(jax.random.PRNGKey(0), cfg))
    opt = jax.eval_shape(lambda: adamw_init(params))
    pspecs = gnn_param_specs(cfg, params, ax)
    ospecs = AdamWState(m=pspecs, v=pspecs, count=P())

    # rows-over-(data, model) annotation for internal [rows, d] latents —
    # without it the partitioner replicates multi-GiB node/edge hidden
    # states per device at ogb_products scale (§Perf addendum D).
    lat_con = make_constrainer(mesh, P((ax.fsdp, ax.model), None))

    def train_step(params, opt_state, batch):
        with latent_constrainer(lat_con):
            loss, grads = jax.value_and_grad(
                lambda p: gnn_loss(cfg, p, batch))(params)
        new_p, new_o, gnorm = adamw_update(grads, opt_state, params, lr=1e-3,
                                           weight_decay=0.0)
        return new_p, new_o, {"loss": loss, "grad_norm": gnorm}

    return Cell(
        name=f"{cfg.name}/{shape_id}",
        fn=train_step,
        args=(params, opt, batch),
        in_specs=(pspecs, ospecs, bspecs),
        out_specs=(pspecs, ospecs, {"loss": P(), "grad_norm": P()}),
        donate=(0, 1),
    )


def reduced_gnn_config(cfg: GNNConfig) -> GNNConfig:
    return dataclasses.replace(
        cfg, n_layers=min(cfg.n_layers, 2), d_hidden=16,
        n_vars=8 if cfg.arch == "graphcast" else cfg.n_vars)

"""PNA [arXiv:2004.05718]: 4 layers d=75, aggregators mean/max/min/std,
scalers identity/amplification/attenuation."""
from repro.models.gnn import GNNConfig

CONFIG = GNNConfig(
    name="pna", arch="pna", n_layers=4, d_hidden=75,
    d_in=0, d_out=0, task="node_class",  # bound per shape
)
FAMILY = "gnn"

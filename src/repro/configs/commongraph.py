"""The paper's own engine as a production-mesh config (``--arch commongraph``).

The batched Direct-Hop/TG executor: snapshot axis over (pod, data) — the
parallelism CommonGraph unlocks by removing the sequential dependence — and
the node-state/segment-reduce axis over `model`. One dry-run cell per
protocol scale. This is the cell used for the paper-representative
hillclimb in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import Cell, MeshAxes
from repro.graph.edgeset import EdgeBlock, lane_bucket
from repro.graph.engine import batched_incremental
from repro.graph.semiring import SSSP

COMMONGRAPH_SHAPES = {
    # snapshots  nodes        CG edges      Δ edges (per snapshot)
    "window_64x": dict(n_snapshots=64, n_nodes=8_388_608, cg_edges=67_108_864,
                       delta_edges=1_048_576),
    "window_32x": dict(n_snapshots=32, n_nodes=1_048_576, cg_edges=16_777_216,
                       delta_edges=262_144),
}


def make_commongraph_cell(shape_id: str, mesh, max_iters: int = 64) -> Cell:
    ax = MeshAxes.for_mesh(mesh)
    sh = COMMONGRAPH_SHAPES[shape_id]
    s, n = sh["n_snapshots"], sh["n_nodes"]
    e_cg, e_d = sh["cg_edges"], sh["delta_edges"]
    S = jax.ShapeDtypeStruct
    f32, i32 = jnp.float32, jnp.int32
    semiring = SSSP

    # The executors' lane-bucketing invariant, applied on the production
    # mesh: pad the snapshot axis to a pow2 bucket divisible by the batch
    # extent, mask the padding lanes, and the cell shards for ANY protocol
    # snapshot count — not just counts that happen to divide the mesh.
    extent = ax.n_batch_shards(mesh)
    sb = lane_bucket(s, extent)

    values = S((sb, n), f32)
    parent = S((sb, n), i32)
    cg = EdgeBlock(S((e_cg,), i32), S((e_cg,), i32), S((e_cg,), f32))
    delta = EdgeBlock(S((sb, e_d), i32), S((sb, e_d), i32), S((sb, e_d), f32))
    lane_valid = S((sb,), jnp.bool_)

    bd = ax.batch
    # snapshots over (pod, data); node state replicated within a snapshot
    # shard; edges over model (partial segment-reduce + semiring all-reduce).
    state_spec = P(bd, None)
    cg_spec = EdgeBlock(P(ax.model), P(ax.model), P(ax.model))
    delta_spec = EdgeBlock(P(bd, ax.model), P(bd, ax.model), P(bd, ax.model))

    def evolve_step(values, parent, cg_block, delta_block, lane_valid):
        # track_parents=False: the deletion-free schedule never trims, so
        # dependence tracking is dead weight — measured −50% flops/bytes and
        # −49.9% collective per sweep on this cell (EXPERIMENTS.md §Perf A).
        res = batched_incremental(
            semiring, n, max_iters, values, parent, (cg_block,), (delta_block,),
            track_parents=False, lane_valid=lane_valid)
        return res.values, res.parent, res.iterations, res.edge_work

    return Cell(
        name=f"commongraph/{shape_id}",
        fn=evolve_step,
        args=(values, parent, cg, delta, lane_valid),
        in_specs=(state_spec, state_spec, cg_spec, delta_spec, P(bd)),
        out_specs=(state_spec, state_spec, P(bd), P(bd)),
        donate=(0, 1),
        meta={"lanes": s, "lane_bucket": sb,
              "lanes_per_device": sb // extent,
              "lane_padding_overhead": round(sb / s - 1, 4)},
    )

"""Config-layer plumbing: mesh-axis handles and dry-run cells.

A *cell* = (architecture × input shape): a step function, abstract arguments
(ShapeDtypeStructs — never allocated), and PartitionSpecs for every input /
output. launch/dryrun.py jits each cell with its specs and lower+compiles it
on the production mesh; launch/train.py runs the same cells concretely on
whatever mesh is actually available (1 CPU device in the smoke tests).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Logical axis handles; batch may span ('pod', 'data') or just ('data',)."""
    batch: tuple[str, ...] = ("data",)
    fsdp: str = "data"
    model: str = "model"

    @staticmethod
    def for_mesh(mesh) -> "MeshAxes":
        names = tuple(mesh.axis_names)
        if "pod" in names:
            return MeshAxes(batch=("pod", "data"))
        return MeshAxes(batch=("data",))

    def n_batch_shards(self, mesh) -> int:
        return math.prod(mesh.shape[a] for a in self.batch)


@dataclasses.dataclass
class Cell:
    """One dry-runnable (arch × shape) computation."""
    name: str
    fn: Callable                 # jit target
    args: tuple                  # abstract ShapeDtypeStructs (or concrete arrays)
    in_specs: Any                # pytree of PartitionSpec matching args
    out_specs: Any = None        # optional pytree of PartitionSpec
    donate: tuple[int, ...] = ()
    static_argnums: tuple[int, ...] = ()
    meta: dict = dataclasses.field(default_factory=dict)  # dryrun-reported extras


def named(mesh, spec_tree):
    """PartitionSpec pytree -> NamedSharding pytree on ``mesh``."""
    from jax.sharding import NamedSharding
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P) or x is None,
    )


def with_sharding(mesh, spec_tree, struct_tree):
    """Attach shardings to a ShapeDtypeStruct pytree (for .lower())."""
    from jax.sharding import NamedSharding
    return jax.tree.map(
        lambda struct, spec: jax.ShapeDtypeStruct(
            struct.shape, struct.dtype,
            sharding=NamedSharding(mesh, spec if spec is not None else P())),
        struct_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def make_constrainer(mesh, spec: P):
    """Residual-stream re-annotation (Megatron-SP posture) for layer scans."""
    from jax.sharding import NamedSharding
    ns = NamedSharding(mesh, spec)
    def con(x):
        return jax.lax.with_sharding_constraint(x, ns)
    return con

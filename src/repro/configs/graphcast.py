"""GraphCast [arXiv:2212.12794]: 16-layer processor d=512, sum aggregation,
n_vars=227, encode(grid->mesh)/process/decode(mesh->grid)."""
from repro.models.gnn import GNNConfig

CONFIG = GNNConfig(
    name="graphcast", arch="graphcast", n_layers=16, d_hidden=512,
    d_in=227, d_out=227, task="node_reg", aggregator="sum", n_vars=227,
)
FAMILY = "gnn"

"""GCN (Kipf & Welling) [arXiv:1609.02907]: 2 layers d=16, mean/sym-norm."""
from repro.models.gnn import GNNConfig

CONFIG = GNNConfig(
    name="gcn-cora", arch="gcn", n_layers=2, d_hidden=16,
    d_in=1433, d_out=7, task="node_class",
)
FAMILY = "gnn"

"""Llama-4 Maverick 400B-A17B [hf:meta-llama/Llama-4-*]: 48L d=5120 40H(kv=8),
interleaved MoE (every 2nd layer) 128e top-1 + 1 shared expert, d_ff=8192."""
import jax.numpy as jnp
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="llama4-maverick-400b-a17b",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_head=128,
    d_ff=8192, moe_d_ff=8192, vocab=202_048,
    moe_every=2, n_experts=128, top_k=1, n_shared_experts=1,
    activation="swiglu", param_dtype=jnp.bfloat16,
    attn_chunk=1024,  # head_dim-TP: scores replicate over model; chunking is load-bearing
)
FAMILY = "lm"

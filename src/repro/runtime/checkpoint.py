"""Step-scoped checkpointing with atomic publish and a versioned manifest.

Saves the full training state (params, optimizer, data cursor, and — for the
evolving-graph engine — the TG-scheduler cursor) as host numpy arrays. Writes
go to a temp file and are renamed into place so a crash mid-save never
corrupts the latest checkpoint (the restart path always reads the newest
*complete* step). At real cluster scale the same layout is written per-host
for its addressable shards; the manifest carries the mesh shape so elastic
restarts know what they are resharding from (runtime/fault.reshard_state).
"""

from __future__ import annotations

import contextlib
import json
import os
import pickle
import tempfile
import time

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _manifest_path(self):
        return os.path.join(self.dir, "manifest.json")

    def _read_manifest(self) -> dict:
        try:
            with open(self._manifest_path()) as f:
                return json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            return {"steps": []}

    def save(self, step: int, state: dict, extra_meta: dict | None = None):
        host_state = jax.tree.map(
            lambda x: np.asarray(x) if hasattr(x, "shape") else x, state)
        payload = pickle.dumps(host_state, protocol=pickle.HIGHEST_PROTOCOL)
        fname = f"step_{step:010d}.ckpt"
        # atomic publish: write temp, fsync, rename
        fd, tmp = tempfile.mkstemp(dir=self.dir, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(payload)
                f.flush()
                os.fsync(f.fileno())
            os.rename(tmp, os.path.join(self.dir, fname))
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        man = self._read_manifest()
        man["steps"] = sorted(set(man["steps"] + [step]))
        man["updated"] = time.time()
        if extra_meta:
            man.setdefault("meta", {})[str(step)] = extra_meta
        with open(self._manifest_path(), "w") as f:
            json.dump(man, f)
        # retention
        while len(man["steps"]) > self.keep:
            old = man["steps"].pop(0)
            with contextlib.suppress(FileNotFoundError):
                os.unlink(os.path.join(self.dir, f"step_{old:010d}.ckpt"))
        with open(self._manifest_path(), "w") as f:
            json.dump(man, f)

    def restore(self, step: int) -> dict | None:
        path = os.path.join(self.dir, f"step_{step:010d}.ckpt")
        if not os.path.exists(path):
            return None
        with open(path, "rb") as f:
            return pickle.load(f)

    def restore_latest(self) -> dict | None:
        man = self._read_manifest()
        if not man["steps"]:
            return None
        return self.restore(man["steps"][-1])

    def latest_step(self) -> int | None:
        man = self._read_manifest()
        return man["steps"][-1] if man["steps"] else None

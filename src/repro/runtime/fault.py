"""Fault tolerance, straggler mitigation, elastic re-sharding.

* :class:`FaultTolerantRunner` — step-level retry with checkpoint restore:
  a failed step (simulated node failure, preemption, NaN blow-up) rolls the
  state back to the last checkpoint and replays the data cursor
  deterministically (the data pipeline is a pure function of (seed, step),
  so replay is bit-identical).
* :class:`StragglerBalancer` — deterministic re-balancing of edge blocks
  across workers from measured per-block costs (the evolving-graph engine's
  work is edge-volume proportional, so cost-weighted longest-processing-time
  assignment fixes persistent stragglers; transient stragglers are absorbed
  by the batched executor's synchronous collectives).
* :func:`reshard_state` — elastic scaling: map a checkpointed state onto a
  smaller/larger data axis (params replicate; batch-linked leaves re-slice).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from repro.runtime.checkpoint import CheckpointManager


class StepFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FaultTolerantRunner:
    ckpt: CheckpointManager
    ckpt_every: int = 5
    max_retries: int = 3

    def run(self, state: dict, step_fn: Callable[[dict, int], dict],
            n_steps: int, start_step: int = 0,
            fail_at: set[int] | None = None) -> tuple[dict, list[int]]:
        """Run ``n_steps``; ``fail_at`` injects failures (for drills/tests).

        Returns (final state, list of steps that were retried/replayed).
        """
        fail_at = set(fail_at or ())
        replayed: list[int] = []
        step = start_step
        retries = 0
        while step < n_steps:
            try:
                if step in fail_at:
                    fail_at.discard(step)  # fail once, then heal
                    raise StepFailure(f"injected node failure at step {step}")
                state = step_fn(state, step)
                step += 1
                retries = 0
                if step % self.ckpt_every == 0:
                    self.ckpt.save(step, state)
            except StepFailure:
                retries += 1
                if retries > self.max_retries:
                    raise
                restored = self.ckpt.restore_latest()
                restore_step = self.ckpt.latest_step() or start_step
                if restored is not None:
                    state = restored
                # deterministic replay from the checkpointed cursor
                replayed.extend(range(restore_step, step + 1))
                step = restore_step
        return state, replayed


class StragglerBalancer:
    """Cost-weighted LPT assignment of work blocks to workers."""

    def __init__(self, n_workers: int):
        self.n_workers = n_workers
        self._costs: dict[int, float] = {}

    def observe(self, block_id: int, seconds: float, ema: float = 0.5):
        prev = self._costs.get(block_id)
        self._costs[block_id] = seconds if prev is None else \
            ema * seconds + (1 - ema) * prev

    def assign(self, block_ids: list[int]) -> dict[int, list[int]]:
        """Longest-processing-time-first over observed costs (1.0 default)."""
        loads = [0.0] * self.n_workers
        out: dict[int, list[int]] = {w: [] for w in range(self.n_workers)}
        for b in sorted(block_ids, key=lambda b: -self._costs.get(b, 1.0)):
            w = int(np.argmin(loads))
            out[w].append(b)
            loads[w] += self._costs.get(b, 1.0)
        return out

    def imbalance(self, assignment: dict[int, list[int]]) -> float:
        loads = [sum(self._costs.get(b, 1.0) for b in bs)
                 for bs in assignment.values()]
        return max(loads) / max(min(loads), 1e-9)


def reshard_state(state: dict, old_data: int, new_data: int,
                  batch_linked: tuple[str, ...] = ()) -> dict:
    """Elastic re-shard: adapt a host-side checkpoint to a new data-axis size.

    Model/optimizer leaves are data-parallel replicas — they carry over
    unchanged. Leaves named in ``batch_linked`` have a leading global-batch
    dim tied to the data axis; they re-slice (shrink) or tile (grow) so the
    per-shard batch stays constant. The data cursor is preserved —
    determinism comes from (seed, step), not from worker count.
    """
    if new_data == old_data:
        return state
    out = {}
    for k, v in state.items():
        if k in batch_linked and hasattr(v, "shape") and v.ndim >= 1:
            b = v.shape[0]
            per = b // old_data
            if new_data < old_data:
                out[k] = v[: per * new_data]
            else:
                reps = [new_data // old_data] + [1] * (v.ndim - 1)
                out[k] = np.tile(v, reps)[: per * new_data]
        else:
            out[k] = v
    return out

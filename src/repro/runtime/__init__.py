"""Distributed runtime: checkpointing, fault tolerance, stragglers, elasticity."""

from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.fault import (
    FaultTolerantRunner,
    StragglerBalancer,
    reshard_state,
)

__all__ = ["CheckpointManager", "FaultTolerantRunner", "StragglerBalancer",
           "reshard_state"]

"""Synthetic batch feeders (seed+step deterministic; see package docstring)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class DataCursor:
    """Checkpointable pipeline position."""
    seed: int
    step: int

    def key(self) -> jax.Array:
        return jax.random.fold_in(jax.random.PRNGKey(self.seed), self.step)


def lm_batch(cursor: DataCursor, batch: int, seq: int, vocab: int):
    """Token/label pair; labels are next-token shifted (last position masked)."""
    key = cursor.key()
    toks = jax.random.randint(key, (batch, seq), 0, vocab, dtype=jnp.int32)
    labels = jnp.concatenate(
        [toks[:, 1:], jnp.full((batch, 1), -1, jnp.int32)], axis=1)
    return {"tokens": toks, "labels": labels}


def gnn_full_batch(cursor: DataCursor, n_nodes: int, n_edges: int, d_feat: int,
                   d_out: int, task: str, d_edge: int = 4):
    key = cursor.key()
    ks = jax.random.split(key, 6)
    batch = {
        "x": jax.random.normal(ks[0], (n_nodes, d_feat), jnp.float32),
        "src": jax.random.randint(ks[1], (n_edges,), 0, n_nodes, jnp.int32),
        "dst": jax.random.randint(ks[2], (n_edges,), 0, n_nodes, jnp.int32),
        "edge_feat": jax.random.normal(ks[3], (n_edges, d_edge), jnp.float32),
    }
    if task == "node_class":
        batch["labels"] = jax.random.randint(ks[4], (n_nodes,), 0, d_out, jnp.int32)
    else:
        batch["targets"] = jax.random.normal(ks[4], (n_nodes, d_out), jnp.float32)
    return batch


def gnn_molecule_batch(cursor: DataCursor, n_graphs: int, nodes_per: int,
                       edges_per: int, d_feat: int, d_out: int, d_edge: int = 4):
    """Batched small graphs: node-batch representation with graph ids."""
    key = cursor.key()
    ks = jax.random.split(key, 6)
    n = n_graphs * nodes_per
    e = n_graphs * edges_per
    # edges stay within their graph
    base = (jnp.arange(e, dtype=jnp.int32) // edges_per) * nodes_per
    src = base + jax.random.randint(ks[0], (e,), 0, nodes_per, jnp.int32)
    dst = base + jax.random.randint(ks[1], (e,), 0, nodes_per, jnp.int32)
    return {
        "x": jax.random.normal(ks[2], (n, d_feat), jnp.float32),
        "src": src,
        "dst": dst,
        "edge_feat": jax.random.normal(ks[3], (e, d_edge), jnp.float32),
        "graph_id": jnp.arange(n, dtype=jnp.int32) // nodes_per,
        "graph_targets": jax.random.normal(ks[4], (n_graphs, d_out), jnp.float32),
    }


def dien_batch(cursor: DataCursor, batch: int, seq: int, n_items: int, n_cats: int):
    key = cursor.key()
    ks = jax.random.split(key, 6)
    return {
        "hist_items": jax.random.randint(ks[0], (batch, seq), 0, n_items, jnp.int32),
        "hist_cats": jax.random.randint(ks[1], (batch, seq), 0, n_cats, jnp.int32),
        "hist_mask": jnp.ones((batch, seq), bool),
        "target_item": jax.random.randint(ks[2], (batch,), 0, n_items, jnp.int32),
        "target_cat": jax.random.randint(ks[3], (batch,), 0, n_cats, jnp.int32),
        "label": jax.random.randint(ks[4], (batch,), 0, 2, jnp.int32),
    }

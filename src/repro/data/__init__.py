"""Deterministic synthetic data pipeline.

Every feeder is a pure function of (seed, step) so that (a) restarts resume
bit-identically from a checkpointed cursor and (b) every data-parallel shard
can regenerate its slice without host I/O — the property a 1000-node data
pipeline needs for elastic restarts (runtime/).
"""

from repro.data.pipeline import (
    lm_batch,
    gnn_full_batch,
    gnn_molecule_batch,
    dien_batch,
    DataCursor,
)

__all__ = ["lm_batch", "gnn_full_batch", "gnn_molecule_batch", "dien_batch",
           "DataCursor"]

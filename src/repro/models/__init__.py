"""Assigned-architecture model zoo (pure JAX, pytree params, pjit-shardable).

Families:
  transformer  decoder-only LMs (dense + MoE), GQA + RoPE, train/prefill/decode
  gnn          GCN / PNA / MeshGraphNet / GraphCast over segment-reduce message passing
  dien         DIEN recsys (embedding-bag + GRU + AUGRU + MLP)
"""

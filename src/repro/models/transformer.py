"""Decoder-only LM family: dense + MoE, GQA + RoPE, train/prefill/decode.

Covers all five assigned LM architectures through one config:

* layers are *scanned* with stacked params (compile-time O(1) in depth —
  essential for the 96-layer nemotron dry-run on a single-core compiler);
* GQA attention with RoPE; activation = SwiGLU or squared-ReLU (nemotron);
* MoE (qwen3 / llama4): per-group capacity dispatch with gather/scatter —
  group axis shards over (pod, data), expert axis over model (EP); the
  combine scatter-add is the all-reduce the roofline sees;
* ``moe_every``: 0 = dense model, 1 = every layer MoE (qwen3),
  2 = alternating dense/MoE super-layers (llama4 interleaved);
* decode (``serve_step``): single-token step against a [L, B, S, KV, Dh]
  KV cache — O(S) per step, so long_500k never materializes anything
  quadratic (DESIGN.md §4).

Params are bf16 by default (fp32 master-free; optimizer state fp32 —
see optim/). All matmuls accumulate in fp32 via preferred_element_type.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import (
    dense_init,
    embed_init,
    rms_norm,
    softmax_cross_entropy,
)

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int | None = None
    activation: str = "swiglu"          # "swiglu" | "squared_relu"
    # MoE
    moe_every: int = 0                   # 0 dense, 1 all-MoE, 2 alternating
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # numerics
    param_dtype: Any = jnp.bfloat16
    rope_theta: float = 10_000.0
    # beyond-paper perf knobs (hillclimb targets; see EXPERIMENTS.md §Perf)
    attn_chunk: int = 0                  # 0 = unchunked scores; else KV-chunked flash-style
    vocab_chunk: int = 0                 # 0 = full logits; else chunked CE loss
    scan_unroll: bool = False            # roofline mode: unroll layer scans so
                                         # cost_analysis counts every layer
    expert_zero1: bool = False           # experts shard over model only
                                         # (ZeRO-1: opt state still fully
                                         # sharded) — kills per-layer FSDP
                                         # all-gathers when experts fit HBM

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.moe_every > 0

    def layer_kinds(self) -> list[str]:
        if self.moe_every == 0:
            return ["dense"] * self.n_layers
        if self.moe_every == 1:
            return ["moe"] * self.n_layers
        # llama4-style: [dense, moe] pairs
        return ["dense", "moe"] * (self.n_layers // 2)

    def param_count(self) -> int:
        p = jax.eval_shape(lambda k: init_lm_params(k, self), jax.random.PRNGKey(0))
        return sum(int(math.prod(leaf.shape)) for leaf in jax.tree.leaves(p))


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def _attn_params(key, cfg: LMConfig, n: int):
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.head_dim
    dt = cfg.param_dtype
    return {
        "wq": dense_init(kq, d, cfg.n_heads * hd, dt).reshape(d, cfg.n_heads, hd)[None].repeat(n, 0),
        "wk": dense_init(kk, d, cfg.n_kv_heads * hd, dt).reshape(d, cfg.n_kv_heads, hd)[None].repeat(n, 0),
        "wv": dense_init(kv, d, cfg.n_kv_heads * hd, dt).reshape(d, cfg.n_kv_heads, hd)[None].repeat(n, 0),
        "wo": dense_init(ko, cfg.n_heads * hd, d, dt).reshape(cfg.n_heads, hd, d)[None].repeat(n, 0),
        "ln1": jnp.ones((n, d), dt),
        "ln2": jnp.ones((n, d), dt),
    }


def _dense_ffn_params(key, cfg: LMConfig, n: int):
    k1, k2, k3 = jax.random.split(key, 3)
    d, f, dt = cfg.d_model, cfg.d_ff, cfg.param_dtype
    p = {"w_up": dense_init(k1, d, f, dt)[None].repeat(n, 0),
         "w_down": dense_init(k2, f, d, dt)[None].repeat(n, 0)}
    if cfg.activation == "swiglu":
        p["w_gate"] = dense_init(k3, d, f, dt)[None].repeat(n, 0)
    return p


def _moe_params(key, cfg: LMConfig, n: int):
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    d, f, e, dt = cfg.d_model, cfg.moe_d_ff or cfg.d_ff, cfg.n_experts, cfg.param_dtype
    scale = 1.0 / math.sqrt(d)
    def ew(k, a, b):
        return (jax.random.normal(k, (n, e, a, b), jnp.float32) * scale).astype(dt)
    p = {
        "router": dense_init(kr, d, e, jnp.float32)[None].repeat(n, 0),
        "w_gate": ew(kg, d, f),
        "w_up": ew(ku, d, f),
        "w_down": ew(kd, f, d),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        k1, k2, k3 = jax.random.split(ks, 3)
        p["shared"] = {"w_gate": dense_init(k1, d, fs, dt)[None].repeat(n, 0),
                       "w_up": dense_init(k2, d, fs, dt)[None].repeat(n, 0),
                       "w_down": dense_init(k3, fs, d, dt)[None].repeat(n, 0)}
    return p


def init_lm_params(key, cfg: LMConfig):
    keys = jax.random.split(key, 6)
    kinds = cfg.layer_kinds()
    n_dense = sum(k == "dense" for k in kinds)
    n_moe = sum(k == "moe" for k in kinds)
    n_attn = len(kinds)
    params = {
        "embed": embed_init(keys[0], cfg.vocab, cfg.d_model, cfg.param_dtype),
        "attn": _attn_params(keys[1], cfg, n_attn),
        "final_ln": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "lm_head": dense_init(keys[4], cfg.d_model, cfg.vocab, cfg.param_dtype),
    }
    if n_dense:
        params["ffn"] = _dense_ffn_params(keys[2], cfg, n_dense)
    if n_moe:
        params["moe"] = _moe_params(keys[3], cfg, n_moe)
    return params


# ---------------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------------

def _rope(x: Array, positions: Array, theta: float) -> Array:
    """Interleaved (NeoX-style) RoPE. x: [..., S, H, Dh]; positions: [..., S].

    Pairs adjacent elements (2i, 2i+1) instead of half-splitting so that a
    head_dim-sharded tensor (the TP fallback for archs whose head count the
    model axis does not divide — llama4 40H, llama3.2 24H) rotates entirely
    shard-locally (DESIGN.md §7: TPU adaptation).
    """
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) * (math.log(theta) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    xr = x.astype(jnp.float32).reshape(x.shape[:-1] + (half, 2))
    e, o = xr[..., 0], xr[..., 1]
    out = jnp.stack([e * cos - o * sin, o * cos + e * sin], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


def _attention_train(cfg: LMConfig, lp, x: Array) -> tuple[Array, Array, Array]:
    """Causal GQA self-attention, [B, S, D] -> ([B, S, D], k, v).

    KV heads are repeated to full heads before the score einsum so that the
    head axis shards cleanly over `model` at any TP degree (TP > n_kv is
    common here: qwen3 kv=4, TP=16). k/v are also returned (pre-repeat) for
    prefill cache emission.
    """
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // kv
    xq = jnp.einsum("bsd,dhk->bshk", x, lp["wq"], preferred_element_type=jnp.float32).astype(x.dtype)
    xk = jnp.einsum("bsd,dhk->bshk", x, lp["wk"], preferred_element_type=jnp.float32).astype(x.dtype)
    xv = jnp.einsum("bsd,dhk->bshk", x, lp["wv"], preferred_element_type=jnp.float32).astype(x.dtype)
    pos = jnp.arange(s)
    xq = _rope(xq, pos, cfg.rope_theta)
    xk = _rope(xk, pos, cfg.rope_theta)
    kf = jnp.repeat(xk, g, axis=2)   # [B, S, H, Dh] — full heads, TP-shardable
    vf = jnp.repeat(xv, g, axis=2)
    scale = 1.0 / math.sqrt(hd)

    chunk = min(cfg.attn_chunk, s) if cfg.attn_chunk else 0
    if chunk and s % chunk == 0:
        out = _chunked_causal_attention(xq, kf, vf, scale, chunk,
                                        unroll=cfg.scan_unroll)
    else:
        scores = jnp.einsum("bqhk,bshk->bhqs", xq, kf,
                            preferred_element_type=jnp.float32) * scale
        causal = pos[None, :] <= pos[:, None]  # [q, s]
        scores = jnp.where(causal[None, None], scores, -jnp.inf)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhqs,bshk->bqhk", probs, vf,
                         preferred_element_type=jnp.float32).astype(x.dtype)
    proj = jnp.einsum("bshk,hkd->bsd", out, lp["wo"],
                      preferred_element_type=jnp.float32).astype(x.dtype)
    return proj, xk, xv


def _chunked_causal_attention(xq, kf, vf, scale, chunk: int,
                              unroll: bool = False) -> Array:
    """Flash-style online-softmax over KV chunks (beyond-paper memory optimization).

    xq/kf/vf: [B, S, H, Dh] (full heads). Never materializes the full
    [S, S] score matrix: peak extra memory is O(S · chunk) per head.
    """
    b, s, h, hd = xq.shape
    n_chunks = s // chunk
    q_pos = jnp.arange(s)

    def step(carry, ci):
        m, den, acc = carry                    # [B,H,S], [B,H,S], [B,S,H,Dh]
        k_blk = jax.lax.dynamic_slice_in_dim(kf, ci * chunk, chunk, axis=1)
        v_blk = jax.lax.dynamic_slice_in_dim(vf, ci * chunk, chunk, axis=1)
        sc = jnp.einsum("bqhk,bchk->bhqc", xq, k_blk,
                        preferred_element_type=jnp.float32) * scale
        k_pos = ci * chunk + jnp.arange(chunk)
        sc = jnp.where((k_pos[None, :] <= q_pos[:, None])[None, None],
                       sc, -jnp.inf)
        blk_m = jnp.max(sc, axis=-1)
        new_m = jnp.maximum(m, blk_m)
        p = jnp.exp(sc - new_m[..., None])
        corr = jnp.exp(m - new_m)
        new_den = den * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhqc,bchk->bqhk", p.astype(vf.dtype), v_blk,
                        preferred_element_type=jnp.float32)
        new_acc = acc * jnp.moveaxis(corr, 1, 2)[..., None] + pv
        return (new_m, new_den, new_acc), None

    m0 = jnp.full((b, h, s), -jnp.inf, jnp.float32)
    den0 = jnp.zeros((b, h, s), jnp.float32)
    a0 = jnp.zeros((b, s, h, hd), jnp.float32)
    (m, den, acc), _ = jax.lax.scan(step, (m0, den0, a0), jnp.arange(n_chunks),
                                    unroll=True if unroll else 1)
    out = acc / jnp.moveaxis(den, 1, 2)[..., None]
    return out.astype(vf.dtype)


def _dense_ffn(cfg: LMConfig, lp, x: Array) -> Array:
    if cfg.activation == "swiglu":
        g = jnp.dot(x, lp["w_gate"], preferred_element_type=jnp.float32)
        u = jnp.dot(x, lp["w_up"], preferred_element_type=jnp.float32)
        h = (jax.nn.silu(g) * u).astype(x.dtype)
    else:  # squared_relu (nemotron)
        h = jnp.dot(x, lp["w_up"], preferred_element_type=jnp.float32)
        h = jnp.square(jax.nn.relu(h)).astype(x.dtype)
    return jnp.dot(h, lp["w_down"], preferred_element_type=jnp.float32).astype(x.dtype)


def _moe_ffn(cfg: LMConfig, lp, x: Array, n_groups: int) -> Array:
    """Capacity-based top-k MoE with gather dispatch / scatter-add combine.

    x: [B, S, D] → groups [G, T, D]; G shards over (pod, data), experts over
    model. Dispatch gather is shard-local; the combine scatter-add reduces
    over the expert/model axis (one psum in SPMD).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    xg = x.reshape(n_groups, (b * s) // n_groups, d)
    g_sz = xg.shape[1]
    cap = int(math.ceil(k * g_sz / e * cfg.capacity_factor))
    cap = max(cap, k)

    logits = jnp.einsum("gtd,de->gte", xg, lp["router"],
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                      # [G, T, k]
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert — sort-based
    # (an [T*k, E] one-hot cumsum would be O(T·k·E) memory; the stable
    # argsort keeps token-major priority within each expert, matching
    # GShard capacity semantics, at O(T·k log) and no E-sized temporary)
    flat_i = top_i.reshape(n_groups, g_sz * k)                  # [G, T*k]

    def _positions(fi):
        order = jnp.argsort(fi, stable=True)
        se = fi[order]
        run_start = jnp.searchsorted(se, se, side="left")
        pos_sorted = jnp.arange(fi.shape[0], dtype=jnp.int32) - run_start.astype(jnp.int32)
        return jnp.zeros_like(fi).at[order].set(pos_sorted)

    pos = jax.vmap(_positions)(flat_i)                          # [G, T*k]
    ok = pos < cap

    # expert slot buffers: token index feeding slot [G, E, cap]
    slot = flat_i * cap + jnp.minimum(pos, cap - 1)             # [G, T*k]
    token_id = jnp.repeat(jnp.arange(g_sz, dtype=jnp.int32)[None, :, None],
                          k, 2).reshape(1, g_sz * k)
    token_id = jnp.broadcast_to(token_id, (n_groups, g_sz * k))
    slot_safe = jnp.where(ok, slot, e * cap)  # OOB for dropped -> mode="drop"
    slot_token = jnp.zeros((n_groups, e * cap), jnp.int32)
    slot_token = jax.vmap(lambda st, sl, ti: st.at[sl].set(ti, mode="drop"))(
        slot_token, slot_safe, token_id)
    slot_valid = jnp.zeros((n_groups, e * cap), bool)
    slot_valid = jax.vmap(lambda sv, sl: sv.at[sl].set(True, mode="drop"))(
        slot_valid, slot_safe)

    xe = jax.vmap(jnp.take, in_axes=(0, 0, None))(xg, slot_token, 0)
    xe = xe.reshape(n_groups, e, cap, d)
    xe = xe * slot_valid.reshape(n_groups, e, cap, 1).astype(xe.dtype)

    # expert FFN (einsum over stacked expert weights; E shards over model)
    gate = jnp.einsum("gecd,edf->gecf", xe, lp["w_gate"],
                      preferred_element_type=jnp.float32)
    up = jnp.einsum("gecd,edf->gecf", xe, lp["w_up"],
                    preferred_element_type=jnp.float32)
    h = (jax.nn.silu(gate) * up).astype(x.dtype)
    ye = jnp.einsum("gecf,efd->gecd", h, lp["w_down"],
                    preferred_element_type=jnp.float32).astype(x.dtype)

    # combine: segment-sum in SLOT space (beyond-paper optimization, §Perf
    # hillclimb B). The naive combine gathers yf[slot] into a [G, T·k, D]
    # tensor that (a) promotes to f32 via the gate probs and (b) forces an
    # all-to-all + all-reduce reshard — ~10 GB/device/layer at qwen3 scale.
    # Instead: scatter the gate prob onto each slot (tiny), scale the expert
    # outputs in-place, and segment-sum rows by their destination token —
    # the same gather+segment-reduce primitive as kernels/embedding_bag.
    gate_p = top_p.reshape(n_groups, g_sz * k).astype(x.dtype)  # bf16 gates
    slot_gate = jnp.zeros((n_groups, e * cap), x.dtype)
    slot_gate = jax.vmap(lambda sg, sl, gw: sg.at[sl].set(gw, mode="drop"))(
        slot_gate, slot_safe, gate_p)
    slot_to_token = jnp.where(slot_valid, slot_token, g_sz)     # sentinel drops
    yflat = ye.reshape(n_groups, e * cap, d)
    y = jax.vmap(lambda yf, sg, stt: jax.ops.segment_sum(
        yf * sg[:, None], stt, g_sz + 1)[:g_sz])(
            yflat, slot_gate, slot_to_token)

    if cfg.n_shared_experts:
        sp = lp["shared"]
        g2 = jnp.einsum("gtd,df->gtf", xg, sp["w_gate"], preferred_element_type=jnp.float32)
        u2 = jnp.einsum("gtd,df->gtf", xg, sp["w_up"], preferred_element_type=jnp.float32)
        y = y + jnp.einsum("gtf,fd->gtd", (jax.nn.silu(g2) * u2).astype(x.dtype),
                           sp["w_down"], preferred_element_type=jnp.float32).astype(x.dtype)
    return y.reshape(b, s, d)


# ---------------------------------------------------------------------------
# forward / losses / steps
# ---------------------------------------------------------------------------

def _layer_stack_scan(cfg: LMConfig, params, x: Array, n_groups: int,
                      remat: bool = True, constrain=None, with_cache: bool = False):
    """Scan over (stacked) layers; llama4-style supers scan (dense, moe) pairs.

    ``constrain`` (optional) re-annotates the residual carry each layer —
    the launcher passes a Megatron-SP style constraint (batch over
    (pod, data), sequence over model) so saved activations stay sharded.
    ``with_cache``: also emit per-layer (k, v) for prefill.
    """
    con = (constrain or {}).get("residual", lambda t: t)
    con_in = (constrain or {}).get("block_in", lambda t: t)
    # ZeRO-3 semantics done right: re-annotate each layer's weight slices as
    # gathered-over-data at point of use, so the partitioner streams one
    # layer's bf16 weights instead of all-reducing fp32 activation partials
    # (§Perf hillclimb C: 9.7 GB/layer -> 43 MB/layer for nemotron qkv).
    con_w = (constrain or {}).get("weights", lambda lp: lp)

    def attn_block(lp_attn, x):
        lp_attn = con_w(lp_attn)
        h = con_in(rms_norm(x, lp_attn["ln1"]))
        o, k, v = _attention_train(cfg, lp_attn, h)
        return x + o, k, v

    if cfg.moe_every == 0:
        def body(x, lp):
            lp_attn, lp_ffn = lp
            x, k, v = attn_block(lp_attn, x)
            h = con_in(rms_norm(x, lp_attn["ln2"]))
            return con(x + _dense_ffn(cfg, con_w(lp_ffn), h)), (k, v)
        stack = (params["attn"], params["ffn"])
    elif cfg.moe_every == 1:
        def body(x, lp):
            lp_attn, lp_moe = lp
            x, k, v = attn_block(lp_attn, x)
            h = con_in(rms_norm(x, lp_attn["ln2"]))
            return con(x + _moe_ffn(cfg, lp_moe, h, n_groups)), (k, v)
        stack = (params["attn"], params["moe"])
    else:  # alternating super-layers: attn+dense, attn+moe
        attn_d = jax.tree.map(lambda a: a[0::2], params["attn"])
        attn_m = jax.tree.map(lambda a: a[1::2], params["attn"])
        def body(x, lp):
            (la_d, lf), (la_m, lm) = lp
            x, k0, v0 = attn_block(la_d, x)
            h = con_in(rms_norm(x, la_d["ln2"]))
            x = con(x + _dense_ffn(cfg, con_w(lf), h))
            x, k1, v1 = attn_block(la_m, x)
            h = con_in(rms_norm(x, la_m["ln2"]))
            return con(x + _moe_ffn(cfg, lm, h, n_groups)), \
                (jnp.stack([k0, k1]), jnp.stack([v0, v1]))
        stack = ((attn_d, params["ffn"]), (attn_m, params["moe"]))

    fn = jax.checkpoint(body) if remat else body
    x, kv = jax.lax.scan(lambda c, lp: fn(c, lp), con(x), stack,
                         unroll=True if cfg.scan_unroll else 1)
    if not with_cache:
        return x, None
    k, v = kv
    if cfg.moe_every == 2:  # un-pair: [L/2, 2, ...] -> [L, ...]
        k = k.reshape((cfg.n_layers,) + k.shape[2:])
        v = v.reshape((cfg.n_layers,) + v.shape[2:])
    return x, {"k": k, "v": v}


def lm_forward(cfg: LMConfig, params, tokens: Array, n_groups: int = 1,
               constrain=None) -> Array:
    """tokens [B, S] -> final hidden [B, S, D]."""
    x = params["embed"][tokens]
    x, _ = _layer_stack_scan(cfg, params, x, n_groups, constrain=constrain)
    return rms_norm(x, params["final_ln"])


def lm_prefill(cfg: LMConfig, params, tokens: Array, n_groups: int = 1,
               constrain=None):
    """Prefill: last-position logits + the full KV cache [L, B, S, KV, Dh]."""
    x = params["embed"][tokens]
    x, cache = _layer_stack_scan(cfg, params, x, n_groups, remat=False,
                                 constrain=constrain, with_cache=True)
    x = rms_norm(x, params["final_ln"])
    logits = jnp.einsum("bd,dv->bv", x[:, -1], params["lm_head"],
                        preferred_element_type=jnp.float32)
    return logits, cache


def lm_loss(cfg: LMConfig, params, tokens: Array, labels: Array,
            n_groups: int = 1, constrain=None) -> Array:
    x = lm_forward(cfg, params, tokens, n_groups, constrain=constrain)
    if cfg.vocab_chunk:
        # chunked CE: never materializes [B, S, V] fp32 at once
        n_chunks = max(1, x.shape[1] // cfg.vocab_chunk)
        xs = x.reshape(x.shape[0], n_chunks, cfg.vocab_chunk, x.shape[-1])
        ls = labels.reshape(labels.shape[0], n_chunks, cfg.vocab_chunk)
        def step(c, inp):
            xc, lc = inp
            logits = jnp.einsum("bcd,dv->bcv", xc, params["lm_head"],
                                preferred_element_type=jnp.float32)
            return c + softmax_cross_entropy(logits, lc), None
        tot, _ = jax.lax.scan(step, jnp.float32(0),
                              (jnp.moveaxis(xs, 1, 0), jnp.moveaxis(ls, 1, 0)),
                              unroll=True if cfg.scan_unroll else 1)
        return tot / n_chunks
    con_l = (constrain or {}).get("logits", lambda t: t)
    logits = con_l(jnp.einsum("bsd,dv->bsv", x, params["lm_head"],
                              preferred_element_type=jnp.float32))
    return softmax_cross_entropy(logits, labels)


# -- decode -----------------------------------------------------------------

def init_kv_cache(cfg: LMConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def lm_decode_step(cfg: LMConfig, params, cache, tokens: Array, pos: Array):
    """One decode step. tokens [B, 1]; pos scalar int32 (current length).

    Returns (logits [B, vocab], new_cache). O(S) per step — the whole cache
    is read once; no quadratic term (this is why long_500k runs for
    full-attention archs, DESIGN.md §4).
    """
    b = tokens.shape[0]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // kv
    s_max = cache["k"].shape[2]
    x = params["embed"][tokens[:, 0]]          # [B, D]

    scale = 1.0 / math.sqrt(hd)
    valid = (jnp.arange(s_max) <= pos)[None, :]  # [1, S]
    posb = jnp.full((b,), pos)

    def attn_step(x, lp, k_l, v_l):
        """One decode attention block. k_l/v_l: [B, S, KV, Dh]."""
        hn = rms_norm(x, lp["ln1"])
        q = jnp.einsum("bd,dhk->bhk", hn, lp["wq"], preferred_element_type=jnp.float32).astype(x.dtype)
        kx = jnp.einsum("bd,dhk->bhk", hn, lp["wk"], preferred_element_type=jnp.float32).astype(x.dtype)
        vx = jnp.einsum("bd,dhk->bhk", hn, lp["wv"], preferred_element_type=jnp.float32).astype(x.dtype)
        q = _rope(q[:, None], posb[:, None], cfg.rope_theta)[:, 0]
        kx = _rope(kx[:, None], posb[:, None], cfg.rope_theta)[:, 0]
        k_l = jax.lax.dynamic_update_slice_in_dim(k_l, kx[:, None].astype(k_l.dtype),
                                                  pos, axis=1)
        v_l = jax.lax.dynamic_update_slice_in_dim(v_l, vx[:, None].astype(v_l.dtype),
                                                  pos, axis=1)
        qg = q.reshape(b, kv, g, hd)
        sc = jnp.einsum("bkgh,bskh->bkgs", qg, k_l,
                        preferred_element_type=jnp.float32) * scale
        sc = jnp.where(valid[:, None, None], sc, -jnp.inf)
        p = jax.nn.softmax(sc, axis=-1)
        o = jnp.einsum("bkgs,bskh->bkgh", p.astype(v_l.dtype), v_l,
                       preferred_element_type=jnp.float32).astype(x.dtype)
        o = o.reshape(b, h, hd)
        x = x + jnp.einsum("bhk,hkd->bd", o, lp["wo"],
                           preferred_element_type=jnp.float32).astype(x.dtype)
        return x, k_l, v_l

    if cfg.moe_every == 0:
        def body(x, inp):
            lp, lf, k_l, v_l = inp
            x, k_l, v_l = attn_step(x, lp, k_l, v_l)
            x = x + _dense_ffn(cfg, lf, rms_norm(x, lp["ln2"]))
            return x, (k_l, v_l)
        xs = (params["attn"], params["ffn"], cache["k"], cache["v"])
    elif cfg.moe_every == 1:
        def body(x, inp):
            lp, lm, k_l, v_l = inp
            x, k_l, v_l = attn_step(x, lp, k_l, v_l)
            x = x + _moe_ffn(cfg, lm, rms_norm(x, lp["ln2"])[:, None, :], 1)[:, 0]
            return x, (k_l, v_l)
        xs = (params["attn"], params["moe"], cache["k"], cache["v"])
    else:
        # super-layers of (dense, moe): pair up caches on a length-2 axis
        n_sup = cfg.n_layers // 2
        attn_d = jax.tree.map(lambda a: a[0::2], params["attn"])
        attn_m = jax.tree.map(lambda a: a[1::2], params["attn"])
        pair = lambda a: a.reshape((n_sup, 2) + a.shape[1:])
        def body(x, inp):
            (la_d, lf), (la_m, lm), k_p, v_p = inp
            x, k0, v0 = attn_step(x, la_d, k_p[0], v_p[0])
            x = x + _dense_ffn(cfg, lf, rms_norm(x, la_d["ln2"]))
            x, k1, v1 = attn_step(x, la_m, k_p[1], v_p[1])
            x = x + _moe_ffn(cfg, lm, rms_norm(x, la_m["ln2"])[:, None, :], 1)[:, 0]
            return x, (jnp.stack([k0, k1]), jnp.stack([v0, v1]))
        xs = ((attn_d, params["ffn"]), (attn_m, params["moe"]),
              pair(cache["k"]), pair(cache["v"]))

    x, (new_k, new_v) = jax.lax.scan(body, x, xs,
                                     unroll=True if cfg.scan_unroll else 1)
    if cfg.moe_every == 2:
        new_k = new_k.reshape((cfg.n_layers,) + new_k.shape[2:])
        new_v = new_v.reshape((cfg.n_layers,) + new_v.shape[2:])

    x = rms_norm(x, params["final_ln"])
    logits = jnp.einsum("bd,dv->bv", x, params["lm_head"],
                        preferred_element_type=jnp.float32)
    return logits, {"k": new_k, "v": new_v}

"""GNN family: GCN / PNA / MeshGraphNet / GraphCast on segment-reduce message passing.

All four assigned GNN archs share one substrate — gather(h[src]) → combine →
``segment_{sum,max,min}`` by dst — which is exactly the edge-relaxation
primitive of the paper's engine (graph/engine.py) minus the semiring
fixpoint. Message-passing over evolving-graph EdgeViews therefore reuses the
paper's mutation-free blocks directly (DESIGN.md §4).

Batch format: a dict of arrays (pjit-friendly). Padded edges have
``dst == n_nodes`` (sentinel segment, dropped). GraphCast uses its own
encode(grid→mesh) / process(mesh) / decode(mesh→grid) edge sets.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import (
    mlp_apply,
    mlp_params,
    mse_loss,
    softmax_cross_entropy,
)

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    arch: str                     # "gcn" | "pna" | "meshgraphnet" | "graphcast"
    n_layers: int
    d_hidden: int
    d_in: int
    d_out: int
    task: str                     # "node_class" | "node_reg" | "graph_reg"
    aggregator: str = "sum"
    d_edge: int = 4
    mlp_layers: int = 2
    feature_table: int = 0        # >0: node features gathered from a table (sampled training)
    n_vars: int = 0               # graphcast in/out channel count
    param_dtype: Any = jnp.float32


# Latent-sharding hook — lives in models/common.py so mlp_apply hiddens are
# covered too; re-exported here for the cell builders (§Perf addendum D).
from repro.models.common import _lat, latent_constrainer  # noqa: E402,F401


def _seg(op: str, data: Array, seg: Array, num: int) -> Array:
    if op == "sum":
        return jax.ops.segment_sum(data, seg, num)
    if op == "mean":
        s = jax.ops.segment_sum(data, seg, num)
        c = jax.ops.segment_sum(jnp.ones((data.shape[0], 1), data.dtype), seg, num)
        return s / jnp.maximum(c, 1.0)
    if op == "max":
        return jax.ops.segment_max(data, seg, num)
    if op == "min":
        return jax.ops.segment_min(data, seg, num)
    raise ValueError(op)


# ---------------------------------------------------------------------------
# GCN (Kipf & Welling) — SpMM with symmetric normalization
# ---------------------------------------------------------------------------

def init_gcn(key, cfg: GNNConfig):
    dims = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.d_out]
    keys = jax.random.split(key, cfg.n_layers)
    return {"w": [
        (jax.random.normal(k, (a, b), jnp.float32) / jnp.sqrt(a)).astype(cfg.param_dtype)
        for k, a, b in zip(keys, dims[:-1], dims[1:])]}


def gcn_forward(cfg: GNNConfig, params, batch):
    x, src, dst = batch["x"], batch["src"], batch["dst"]
    n = x.shape[0]
    valid = (dst < n).astype(jnp.float32)  # padded edges must not count
    deg_in = jax.ops.segment_sum(valid, dst, n + 1)[:n]
    deg_out = jax.ops.segment_sum(valid, src, n + 1)[:n]
    norm = (jax.lax.rsqrt(jnp.maximum(deg_out, 1.0))[src]
            * jax.lax.rsqrt(jnp.maximum(deg_in, 1.0))[
                jnp.minimum(dst, n - 1)])
    for i, w in enumerate(params["w"]):
        h = jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)
        msg = h[src] * norm[:, None].astype(h.dtype)
        x = _lat(_seg("sum", msg, dst, n + 1)[:n])
        if i < len(params["w"]) - 1:
            x = jax.nn.relu(x)
    return x


# ---------------------------------------------------------------------------
# PNA (Corso et al.) — multi-aggregator (mean/max/min/std) × degree scalers
# ---------------------------------------------------------------------------

PNA_AGGS = ("mean", "max", "min", "std")
PNA_SCALERS = ("identity", "amplification", "attenuation")


def init_pna(key, cfg: GNNConfig):
    keys = jax.random.split(key, cfg.n_layers + 2)
    d = cfg.d_hidden
    n_cat = len(PNA_AGGS) * len(PNA_SCALERS) * d + d
    layers = [{"post": mlp_params(k, (n_cat, d, d))} for k in keys[:cfg.n_layers]]
    return {
        "enc": mlp_params(keys[-2], (cfg.d_in, d)),
        "layers": layers,
        "dec": mlp_params(keys[-1], (d, d, cfg.d_out)),
    }


def pna_forward(cfg: GNNConfig, params, batch):
    x, src, dst = batch["x"], batch["src"], batch["dst"]
    n = x.shape[0]
    h = mlp_apply(params["enc"], x.astype(cfg.param_dtype))
    ones = jnp.ones((src.shape[0], 1), jnp.float32)
    deg = jax.ops.segment_sum(ones, dst, n + 1)[:n, 0]
    logd = jnp.log1p(deg)
    delta = jnp.mean(logd) + 1e-6
    scalers = jnp.stack([jnp.ones_like(logd), logd / delta,
                         delta / jnp.maximum(logd, 1e-6)], 1)  # [N, 3]
    for lyr in params["layers"]:
        msg = h[src]
        mean = _seg("mean", msg, dst, n + 1)[:n]
        mx = _seg("max", jnp.where((dst < n)[:, None], msg, -jnp.inf), dst, n + 1)[:n]
        mn = _seg("min", jnp.where((dst < n)[:, None], msg, jnp.inf), dst, n + 1)[:n]
        m2 = _seg("mean", jnp.square(msg), dst, n + 1)[:n]
        std = jnp.sqrt(jax.nn.relu(m2 - jnp.square(mean)) + 1e-5)
        has_deg = (deg > 0)[:, None]
        mx = jnp.where(has_deg, mx, 0.0)
        mn = jnp.where(has_deg, mn, 0.0)
        aggs = jnp.stack([mean, mx, mn, std], 1)               # [N, 4, D]
        scaled = aggs[:, :, None, :] * scalers[:, None, :, None]  # [N, 4, 3, D]
        cat = jnp.concatenate([h, scaled.reshape(n, -1).astype(h.dtype)], -1)
        h = _lat(h + mlp_apply(lyr["post"], cat))
    return mlp_apply(params["dec"], h)


# ---------------------------------------------------------------------------
# MeshGraphNet (Pfaff et al.) — edge+node MLP blocks with residuals
# ---------------------------------------------------------------------------

def _mgn_mlp(key, d_in, d_h, d_out, n_hidden=2):
    dims = (d_in,) + (d_h,) * n_hidden + (d_out,)
    return mlp_params(key, dims, norm=True)


def init_meshgraphnet(key, cfg: GNNConfig):
    keys = jax.random.split(key, 2 * cfg.n_layers + 3)
    d = cfg.d_hidden
    blocks = []
    for i in range(cfg.n_layers):
        blocks.append({
            "edge": _mgn_mlp(keys[2 * i], 3 * d, d, d, cfg.mlp_layers),
            "node": _mgn_mlp(keys[2 * i + 1], 2 * d, d, d, cfg.mlp_layers),
        })
    return {
        "node_enc": _mgn_mlp(keys[-3], cfg.d_in, d, d, cfg.mlp_layers),
        "edge_enc": _mgn_mlp(keys[-2], cfg.d_edge, d, d, cfg.mlp_layers),
        "dec": mlp_params(keys[-1], (d, d, cfg.d_out)),
        "blocks": blocks,
    }


def _mgn_process(blocks, h, e, src, dst, n, aggregator="sum"):
    # (per-block jax.checkpoint was tried and REFUTED here: the recompute
    # peak overlaps the checkpointed carries in XLA's buffer assignment and
    # temp grew 58->75 GiB/device. The working mitigation for the [E, 3d]
    # backward-saved concats at 62M-edge scale is edge-chunked processing —
    # EXPERIMENTS.md §Perf addendum D.)
    for blk in blocks:
        he = jnp.concatenate([e, h[src], h[jnp.minimum(dst, n - 1)]], -1)
        e = _lat(e + mlp_apply(blk["edge"], he))
        agg = _seg(aggregator, e, dst, n + 1)[:n]
        h = _lat(h + mlp_apply(blk["node"], jnp.concatenate([h, agg], -1)))
    return h, e


def meshgraphnet_forward(cfg: GNNConfig, params, batch):
    x, src, dst = batch["x"], batch["src"], batch["dst"]
    ef = batch["edge_feat"]
    n = x.shape[0]
    h = _lat(mlp_apply(params["node_enc"], x.astype(cfg.param_dtype)))
    e = _lat(mlp_apply(params["edge_enc"], ef.astype(cfg.param_dtype)))
    h, _ = _mgn_process(params["blocks"], h, e, src, dst, n, cfg.aggregator)
    return mlp_apply(params["dec"], h)


# ---------------------------------------------------------------------------
# GraphCast (Lam et al.) — encode(grid→mesh) / process(mesh) / decode(mesh→grid)
# ---------------------------------------------------------------------------

def init_graphcast(key, cfg: GNNConfig):
    keys = jax.random.split(key, 2 * cfg.n_layers + 7)
    d = cfg.d_hidden
    blocks = []
    for i in range(cfg.n_layers):
        blocks.append({
            "edge": _mgn_mlp(keys[2 * i], 3 * d, d, d, 1),
            "node": _mgn_mlp(keys[2 * i + 1], 2 * d, d, d, 1),
        })
    return {
        "grid_enc": _mgn_mlp(keys[-7], cfg.n_vars, d, d, 1),
        "g2m_edge": _mgn_mlp(keys[-6], cfg.d_edge, d, d, 1),
        "mesh_edge": _mgn_mlp(keys[-5], cfg.d_edge, d, d, 1),
        "mesh_up": _mgn_mlp(keys[-2], d, d, d, 1),
        "blocks": blocks,
        "m2g_edge": _mgn_mlp(keys[-4], cfg.d_edge, d, d, 1),
        "grid_up": _mgn_mlp(keys[-3], 2 * d, d, d, 1),
        "dec": mlp_params(keys[-1], (d, d, cfg.n_vars)),
    }


def graphcast_forward(cfg: GNNConfig, params, batch):
    xg = batch["x"]                              # [N_grid, n_vars]
    n_grid = xg.shape[0]
    n_mesh = batch["mesh_valid"].shape[0]

    hg = _lat(mlp_apply(params["grid_enc"], xg.astype(cfg.param_dtype)))

    # encode: grid -> mesh
    e = _lat(mlp_apply(params["g2m_edge"], batch["g2m_feat"].astype(cfg.param_dtype)))
    msg = _lat(e + hg[batch["g2m_src"]])
    hm = _lat(_seg("sum", msg, batch["g2m_dst"], n_mesh + 1)[:n_mesh])
    hm = _lat(mlp_apply(params["mesh_up"], hm))

    # process on the (multi-)mesh
    em = _lat(mlp_apply(params["mesh_edge"], batch["mesh_feat"].astype(cfg.param_dtype)))
    hm, _ = _mgn_process(params["blocks"], hm, em,
                         batch["mesh_src"], batch["mesh_dst"], n_mesh, "sum")

    # decode: mesh -> grid
    e2 = _lat(mlp_apply(params["m2g_edge"], batch["m2g_feat"].astype(cfg.param_dtype)))
    msg2 = _lat(e2 + hm[batch["m2g_src"]])
    hg2 = _lat(_seg("sum", msg2, batch["m2g_dst"], n_grid + 1)[:n_grid])
    hg = _lat(mlp_apply(params["grid_up"], jnp.concatenate([hg, hg2], -1)))
    return mlp_apply(params["dec"], hg)


# ---------------------------------------------------------------------------
# uniform interface
# ---------------------------------------------------------------------------

_INIT = {"gcn": init_gcn, "pna": init_pna,
         "meshgraphnet": init_meshgraphnet, "graphcast": init_graphcast}
_FWD = {"gcn": gcn_forward, "pna": pna_forward,
        "meshgraphnet": meshgraphnet_forward, "graphcast": graphcast_forward}


def init_gnn_params(key, cfg: GNNConfig):
    k1, k2 = jax.random.split(key)
    p = _INIT[cfg.arch](k1, cfg)
    if cfg.feature_table:
        p["features"] = (jax.random.normal(
            k2, (cfg.feature_table, cfg.d_in), jnp.float32) * 0.1).astype(cfg.param_dtype)
    return p


def gnn_forward(cfg: GNNConfig, params, batch):
    if cfg.feature_table:
        batch = dict(batch)
        x = params["features"][batch["nodes"]]
        batch["x"] = x * batch["node_valid"][:, None].astype(x.dtype)
    return _FWD[cfg.arch](cfg, params, batch)


def gnn_loss(cfg: GNNConfig, params, batch) -> Array:
    out = gnn_forward(cfg, params, batch)
    if cfg.task == "node_class":
        labels = batch["labels"]
        if "n_seeds" in batch:   # sampled training: loss on seeds only
            out = out[: labels.shape[0]]
        return softmax_cross_entropy(out, labels)
    if cfg.task == "node_reg":
        t = batch["targets"]
        if "n_seeds" in batch and out.shape[0] != t.shape[0]:
            out = out[: t.shape[0]]   # sampled training: loss on seeds only
        return mse_loss(out, t)
    if cfg.task == "graph_reg":  # molecule: pool by graph id then regress
        gid = batch["graph_id"]
        n_graphs = batch["graph_targets"].shape[0]
        pooled = jax.ops.segment_sum(out, gid, n_graphs + 1)[:n_graphs]
        return mse_loss(pooled, batch["graph_targets"])
    raise ValueError(cfg.task)

"""Shared NN building blocks: norms, initializers, MLPs, losses.

Plain pytree params (dicts of jnp arrays) — no framework dependency. Every
init function has a matching ``jax.eval_shape``-compatible signature so the
dry-run can materialize abstract params without allocation.
"""

from __future__ import annotations

import contextlib
from typing import Callable

import jax
import jax.numpy as jnp

Array = jnp.ndarray

# Latent-sharding hook (§Perf addendum D): internal [rows, d] activations
# (GNN node/edge hidden states, MLP hiddens over huge row counts) have no
# sharding anchor of their own; the GNN cell builder installs a
# rows-over-(data, model) annotator here so the partitioner keeps them
# sharded through forward AND the saved-for-backward set.
_LATENT = {"con": None}


@contextlib.contextmanager
def latent_constrainer(fn):
    prev = _LATENT["con"]
    _LATENT["con"] = fn
    try:
        yield
    finally:
        _LATENT["con"] = prev


def _lat(x: Array) -> Array:
    c = _LATENT["con"]
    return c(x) if (c is not None and x.ndim == 2) else x


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale: float | None = None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d_in)).astype(jnp.float32)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def rms_norm(x: Array, gamma: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (y * gamma.astype(jnp.float32)).astype(dt)


def layer_norm(x: Array, gamma: Array, beta: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dt)


def swiglu(x: Array, w_gate: Array, w_up: Array, w_down: Array) -> Array:
    g = jnp.dot(x, w_gate, preferred_element_type=jnp.float32)
    u = jnp.dot(x, w_up, preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    return jnp.dot(h, w_down, preferred_element_type=jnp.float32).astype(x.dtype)


def squared_relu_ffn(x: Array, w_up: Array, w_down: Array) -> Array:
    """Nemotron-4 style FFN: squared-ReLU activation (arXiv:2402.16819)."""
    h = jnp.dot(x, w_up, preferred_element_type=jnp.float32)
    h = jnp.square(jax.nn.relu(h)).astype(x.dtype)
    return jnp.dot(h, w_down, preferred_element_type=jnp.float32).astype(x.dtype)


def mlp_params(key, dims: tuple[int, ...], dtype=jnp.float32, norm: bool = False):
    keys = jax.random.split(key, len(dims) - 1)
    layers = []
    for k, (a, b) in zip(keys, zip(dims[:-1], dims[1:])):
        layers.append({"w": dense_init(k, a, b, dtype), "b": jnp.zeros((b,), dtype)})
    p = {"layers": layers}
    if norm:
        p["ln_g"] = jnp.ones((dims[-1],), dtype)
        p["ln_b"] = jnp.zeros((dims[-1],), dtype)
    return p


def mlp_apply(p, x: Array, act: Callable = jax.nn.relu, final_act: bool = False) -> Array:
    n = len(p["layers"])
    for i, lyr in enumerate(p["layers"]):
        x = _lat(jnp.dot(x, lyr["w"], preferred_element_type=jnp.float32
                         ).astype(x.dtype) + lyr["b"])
        if i < n - 1 or final_act:
            x = act(x)
    if "ln_g" in p:
        x = _lat(layer_norm(x, p["ln_g"], p["ln_b"]))
    return x


def softmax_cross_entropy(logits: Array, labels: Array) -> Array:
    """Mean CE over valid labels (label < 0 is masked). logits fp32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                             axis=-1)[..., 0]
    mask = labels >= 0
    nll = jnp.where(mask, lse - ll, 0.0)
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)


def mse_loss(pred: Array, target: Array) -> Array:
    return jnp.mean(jnp.square(pred.astype(jnp.float32) - target.astype(jnp.float32)))

"""DIEN (Zhou et al., arXiv:1809.03672): Deep Interest Evolution Network.

Pipeline: sparse embeddings (item 2²³ rows, category 10⁴ rows, dim 18) →
interest-extraction GRU over the 100-step behavior sequence → AUGRU
(attention-gated GRU conditioned on the target item) → MLP 200-80-2, plus
the auxiliary next-behavior loss on the GRU states.

Scale notes (DESIGN.md §4):
* embedding tables row-shard over `model`;
* the GRU layer is **target-independent** — for ``retrieval_cand`` (1 user ×
  10⁶ candidates) it runs once and only the AUGRU is batched over the
  candidate axis (sharded over `model`), turning retrieval into a scan over
  a [n_cand, d] state, not a loop.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, mlp_apply, mlp_params

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class DIENConfig:
    name: str
    n_items: int = 1 << 23
    n_cats: int = 10_000
    embed_dim: int = 18
    seq_len: int = 100
    gru_dim: int = 108
    mlp_dims: tuple[int, ...] = (200, 80)
    param_dtype: Any = jnp.float32
    scan_unroll: bool = False            # roofline mode (see transformer.py)

    @property
    def d_behavior(self) -> int:            # concat(item, cat) embedding
        return 2 * self.embed_dim


def _gru_params(key, d_in: int, d_h: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": dense_init(k1, d_in, 3 * d_h, dtype),   # update/reset/cand input
        "wh": dense_init(k2, d_h, 3 * d_h, dtype),
        "b": jnp.zeros((3 * d_h,), dtype),
    }


def _gru_cell(p, h, x, att: Array | None = None):
    """GRU cell; if ``att`` given, AUGRU: update gate scaled by attention."""
    gi = jnp.dot(x, p["wi"], preferred_element_type=jnp.float32)
    gh = jnp.dot(h, p["wh"], preferred_element_type=jnp.float32)
    d = p["wh"].shape[0]
    zi, ri, ci = gi[..., :d], gi[..., d:2 * d], gi[..., 2 * d:]
    zh, rh, ch = gh[..., :d], gh[..., d:2 * d], gh[..., 2 * d:]
    b = p["b"].astype(jnp.float32)
    z = jax.nn.sigmoid(zi + zh + b[:d])
    r = jax.nn.sigmoid(ri + rh + b[d:2 * d])
    c = jnp.tanh(ci + r * ch + b[2 * d:])
    if att is not None:
        z = z * att[..., None]               # AUGRU: attentional update gate
    h_new = (1.0 - z) * h.astype(jnp.float32) + z * c
    return h_new.astype(h.dtype)


def init_dien_params(key, cfg: DIENConfig):
    keys = jax.random.split(key, 7)
    d, dh = cfg.d_behavior, cfg.gru_dim
    dt = cfg.param_dtype
    d_final = dh + d + d                     # interest ++ target emb ++ sum-pooled history
    return {
        "item_emb": (jax.random.normal(keys[0], (cfg.n_items, cfg.embed_dim),
                                       jnp.float32) * 0.02).astype(dt),
        "cat_emb": (jax.random.normal(keys[1], (cfg.n_cats, cfg.embed_dim),
                                      jnp.float32) * 0.02).astype(dt),
        "gru1": _gru_params(keys[2], d, dh, dt),
        "augru": _gru_params(keys[3], d, dh, dt),
        "att": mlp_params(keys[4], (dh + d, 80, 1)),
        "mlp": mlp_params(keys[5], (d_final,) + cfg.mlp_dims + (2,)),
        "aux": mlp_params(keys[6], (dh + d, 100, 1)),
    }


def _behavior_embed(cfg, params, item_ids, cat_ids):
    it = jnp.take(params["item_emb"], item_ids, axis=0)
    ct = jnp.take(params["cat_emb"], cat_ids, axis=0)
    return jnp.concatenate([it, ct], -1)      # [..., 2*embed_dim]


def _interest_extraction(cfg, params, beh: Array, mask: Array):
    """GRU over the behavior sequence. beh: [B, S, D]. Returns states [B, S, dh]."""
    b = beh.shape[0]
    h0 = jnp.zeros((b, cfg.gru_dim), beh.dtype)

    def step(h, inp):
        x, m = inp
        h_new = _gru_cell(params["gru1"], h, x)
        h = jnp.where(m[:, None], h_new, h)
        return h, h

    _, states = jax.lax.scan(step, h0, (jnp.moveaxis(beh, 1, 0),
                                        jnp.moveaxis(mask, 1, 0)),
                             unroll=True if cfg.scan_unroll else 1)
    return jnp.moveaxis(states, 0, 1)          # [B, S, dh]


def _interest_evolution(cfg, params, states: Array, beh: Array, mask: Array,
                        target: Array):
    """AUGRU over GRU states with attention to the target item.

    states [B, S, dh]; target [B, D]. Returns final interest [B, dh].
    """
    b = states.shape[0]
    att_in = jnp.concatenate(
        [states, jnp.broadcast_to(target[:, None], states.shape[:2] + (target.shape[-1],))], -1)
    att_logit = mlp_apply(params["att"], att_in)[..., 0]   # [B, S]
    att_logit = jnp.where(mask, att_logit, -jnp.inf)
    att = jax.nn.softmax(att_logit.astype(jnp.float32), axis=-1).astype(states.dtype)

    h0 = jnp.zeros((b, cfg.gru_dim), states.dtype)

    def step(h, inp):
        x, a, m = inp
        h_new = _gru_cell(params["augru"], h, x, att=a)
        return jnp.where(m[:, None], h_new, h), None

    h, _ = jax.lax.scan(step, h0, (jnp.moveaxis(beh, 1, 0),
                                   jnp.moveaxis(att, 1, 0),
                                   jnp.moveaxis(mask, 1, 0)),
                        unroll=True if cfg.scan_unroll else 1)
    return h


def dien_forward(cfg: DIENConfig, params, batch):
    """batch: hist_items/hist_cats [B, S], hist_mask [B, S],
    target_item/target_cat [B]. Returns logits [B, 2]."""
    beh = _behavior_embed(cfg, params, batch["hist_items"], batch["hist_cats"])
    target = _behavior_embed(cfg, params, batch["target_item"], batch["target_cat"])
    mask = batch["hist_mask"]
    states = _interest_extraction(cfg, params, beh, mask)
    interest = _interest_evolution(cfg, params, states, beh, mask, target)
    pooled = jnp.sum(beh * mask[..., None].astype(beh.dtype), axis=1)
    x = jnp.concatenate([interest, target, pooled], -1)
    return mlp_apply(params["mlp"], x), states, beh, mask


def dien_loss(cfg: DIENConfig, params, batch) -> Array:
    logits, states, beh, mask = dien_forward(cfg, params, batch)
    labels = batch["label"]
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    ce = -jnp.mean(jnp.take_along_axis(lp, labels[:, None], 1))

    # auxiliary loss: state_t should predict behavior_{t+1} (positive) vs
    # a shuffled negative (we roll the batch as the negative sample).
    h_t = states[:, :-1]
    e_pos = beh[:, 1:]
    e_neg = jnp.roll(e_pos, 1, axis=0)
    m = mask[:, 1:].astype(jnp.float32)
    def aux_logit(e):
        return mlp_apply(params["aux"], jnp.concatenate([h_t, e], -1))[..., 0]
    pos = jax.nn.log_sigmoid(aux_logit(e_pos).astype(jnp.float32))
    neg = jax.nn.log_sigmoid(-aux_logit(e_neg).astype(jnp.float32))
    aux = -jnp.sum((pos + neg) * m) / jnp.maximum(jnp.sum(m), 1.0)
    return ce + 1.0 * aux


def dien_score_candidates(cfg: DIENConfig, params, batch):
    """Retrieval scoring: 1 user vs n_cand candidates.

    batch: hist_* [1, S]; cand_items/cand_cats [n_cand]. GRU runs once;
    the AUGRU and head are batched over candidates. Returns [n_cand] scores.
    """
    beh = _behavior_embed(cfg, params, batch["hist_items"], batch["hist_cats"])  # [1,S,D]
    mask = batch["hist_mask"]
    states = _interest_extraction(cfg, params, beh, mask)                        # [1,S,dh]
    cands = _behavior_embed(cfg, params, batch["cand_items"], batch["cand_cats"])  # [C,D]
    n_cand = cands.shape[0]

    statesC = jnp.broadcast_to(states, (n_cand,) + states.shape[1:])
    behC = jnp.broadcast_to(beh, (n_cand,) + beh.shape[1:])
    maskC = jnp.broadcast_to(mask, (n_cand,) + mask.shape[1:])
    interest = _interest_evolution(cfg, params, statesC, behC, maskC, cands)     # [C,dh]
    pooled = jnp.sum(behC * maskC[..., None].astype(behC.dtype), axis=1)
    x = jnp.concatenate([interest, cands, pooled], -1)
    logits = mlp_apply(params["mlp"], x)
    return logits[:, 1] - logits[:, 0]

"""EmbeddingBag for JAX (gather + segment-reduce) — recsys substrate.

JAX has no native ``nn.EmbeddingBag`` and no CSR sparse; per the assignment
this is part of the system. The reference path is ``jnp.take`` +
``jax.ops.segment_sum``; ``kernels/embedding_bag`` provides the fused Pallas
version for the TPU hot path (same signature, allclose-tested against this).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jnp.ndarray


def embedding_lookup(table: Array, ids: Array) -> Array:
    """Plain lookup: [..., ] int32 -> [..., D]. Row-sharded tables gather."""
    return jnp.take(table, ids, axis=0)


def embedding_bag(
    table: Array,        # [V, D]
    ids: Array,          # [n_lookups] int32
    bag_ids: Array,      # [n_lookups] int32, which output bag each lookup joins
    n_bags: int,
    weights: Array | None = None,   # optional per-lookup weights
    mode: str = "sum",
) -> Array:
    """Multi-hot bag reduction: out[b] = reduce_{i: bag_ids[i]==b} w_i * table[ids[i]].

    Padded lookups use ``bag_ids == n_bags`` (dropped via the sentinel row).
    """
    vals = jnp.take(table, ids, axis=0)
    if weights is not None:
        vals = vals * weights[:, None].astype(vals.dtype)
    if mode == "sum":
        return jax.ops.segment_sum(vals, bag_ids, n_bags + 1)[:n_bags]
    if mode == "mean":
        s = jax.ops.segment_sum(vals, bag_ids, n_bags + 1)[:n_bags]
        c = jax.ops.segment_sum(jnp.ones((ids.shape[0], 1), vals.dtype),
                                bag_ids, n_bags + 1)[:n_bags]
        return s / jnp.maximum(c, 1.0)
    if mode == "max":
        return jax.ops.segment_max(vals, bag_ids, n_bags + 1)[:n_bags]
    raise ValueError(mode)

"""AdamW with fp32 state over (possibly bf16) params + global-norm clipping.

ZeRO posture: the optimizer state pytree mirrors the param pytree, so
whatever sharding the params carry, the state shards identically (the
launcher passes the same PartitionSpecs). State is fp32 regardless of param
dtype (bf16 params get a stochastic-free fp32 update then cast back).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    m: Any
    v: Any
    count: jnp.ndarray


def adamw_init(params) -> AdamWState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(jax.tree.map(f32, params), jax.tree.map(f32, params),
                      jnp.zeros((), jnp.int32))


def global_norm_clip(grads, max_norm: float):
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def adamw_update(
    grads,
    state: AdamWState,
    params,
    lr: float = 1e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_norm: float = 1.0,
):
    grads, gnorm = global_norm_clip(grads, max_norm)
    count = state.count + 1
    t = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    new_m = jax.tree.map(
        lambda g, m: b1 * m + (1 - b1) * g.astype(jnp.float32), grads, state.m)
    new_v = jax.tree.map(
        lambda g, v: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        grads, state.v)
    new_params = jax.tree.map(
        lambda p, m, v: (p.astype(jnp.float32)
                         - lr * ((m / bc1) / (jnp.sqrt(v / bc2) + eps)
                                 + weight_decay * p.astype(jnp.float32))
                         ).astype(p.dtype),
        params, new_m, new_v)
    return new_params, AdamWState(new_m, new_v, count), gnorm

"""Gradient compression: int8 quantization with error feedback (1000-node trick).

At multi-pod scale, cross-pod gradient all-reduce over DCI links dominates;
int8 error-feedback compression cuts those bytes 4× with no asymptotic loss
(the residual is fed back next step — Karimireddy et al., arXiv:1901.09847).
The launcher applies this only on the `pod` axis reduction (cheap intra-pod
ICI stays fp32); runtime tests validate convergence parity on a small model.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_compress_update(grads, residuals):
    """Error-feedback compression of a gradient pytree.

    Returns (compressed-and-decompressed grads, new residuals). The caller
    all-reduces the (conceptually int8) payload; here we model the value
    path exactly so convergence tests are faithful.
    """
    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, s = compress_int8(g32)
        deq = decompress_int8(q, s)
        return deq.astype(g.dtype), g32 - deq

    out = jax.tree.map(one, grads, residuals)
    comp = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return comp, new_res


def init_residuals(grads_like):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)

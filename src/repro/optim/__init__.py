"""Optimizer substrate (no optax dependency — built per assignment scope)."""

from repro.optim.adamw import AdamWState, adamw_init, adamw_update, global_norm_clip
from repro.optim.compress import compress_int8, decompress_int8, ef_compress_update

__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "global_norm_clip",
    "compress_int8",
    "decompress_int8",
    "ef_compress_update",
]
